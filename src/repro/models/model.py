"""Unified decoder model covering all assigned families.

A model is ``num_layers`` blocks; each block = mixer (attention | mamba2) +
optional FFN (dense SwiGLU | sparse MoE).  Blocks are grouped into repeating
*periods* (Jamba: period 8) and the stack is a ``lax.scan`` over periods so
HLO size stays O(period), not O(depth) — essential for compiling the
126-layer llama3-405b dry-run.

Parameter tree:
  {"embed": {"tokens": (V,D) | (K,V,D)},
   "blocks": {"pos0": <stacked block tree, leading axis n_periods>, ...},
   "final_norm": (D,),
   "lm_head": (D,V) | (K,D,V)}            # absent when tie_embeddings

Trainable (federated) tree:
  {"lora": mirrors params with {"a","b"} factors on targeted matrices,
   "rescaler": {"pos{i}": (n_periods,)}}  # FLAME s_i, MoE positions only
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mamba2 as ssm_mod
from . import moe_layer as moe_mod
from .layers import apply_ffn, embed_init, init_ffn, rms_norm

PyTree = Any


# ==========================================================================
# init
# ==========================================================================

def _init_block(key, cfg, kind: str, is_moe: bool) -> dict:
    keys = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    p: dict = {"mixer_norm": jnp.ones((cfg.d_model,), dtype)}
    if kind == "attn":
        p["attn"] = attn_mod.init_attention(keys[0], cfg)
    else:
        p["ssm"] = ssm_mod.init_mamba(keys[0], cfg)
    if is_moe:
        p["ffn_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["moe"] = moe_mod.init_moe(keys[1], cfg)
    elif cfg.d_ff > 0:
        p["ffn_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["ffn"] = init_ffn(keys[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg) -> PyTree:
    cfg.validate()
    P = cfg.pattern_period
    n_periods = cfg.num_layers // P
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)

    if cfg.num_codebooks > 0:
        embed = embed_init(k_embed, (cfg.num_codebooks, cfg.vocab_size,
                                     cfg.d_model), dtype)
    else:
        embed = embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype)

    blocks = {}
    for pos in range(P):
        kind = cfg.layer_kind(pos)
        is_moe = cfg.layer_is_moe(pos)
        kp = jax.random.fold_in(k_blocks, pos)
        stacked = jax.vmap(
            lambda k: _init_block(k, cfg, kind, is_moe)
        )(jax.random.split(kp, n_periods))
        blocks[f"pos{pos}"] = stacked

    params = {
        "embed": {"tokens": embed},
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 0:
            params["lm_head"] = embed_init(
                k_head, (cfg.num_codebooks, cfg.d_model, cfg.vocab_size), dtype)
        else:
            params["lm_head"] = embed_init(
                k_head, (cfg.d_model, cfg.vocab_size), dtype)
    return params


# ==========================================================================
# embedding / head
# ==========================================================================

def embed_tokens(params, cfg, tokens: jnp.ndarray) -> jnp.ndarray:
    emb = params["embed"]["tokens"]
    if cfg.num_codebooks > 0:
        # tokens: (B, S, K); sum of per-codebook embeddings (MusicGen style)
        parts = [jnp.take(emb[k], tokens[..., k], axis=0)
                 for k in range(cfg.num_codebooks)]
        return sum(parts)
    return jnp.take(emb, tokens, axis=0)


def lm_head(params, cfg, h: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["embed"]["tokens"]
        if cfg.num_codebooks > 0:
            return jnp.einsum("bsd,kvd->bskv", h, w)
        return h @ w.T
    w = params["lm_head"]
    if cfg.num_codebooks > 0:
        return jnp.einsum("bsd,kdv->bskv", h, w)
    return h @ w


# ==========================================================================
# one block
# ==========================================================================

def _apply_block(cfg, kind: str, is_moe: bool, p: dict, x: jnp.ndarray,
                 positions, *, lora, rescaler, lora_scale, k,
                 cache=None, cache_pos=None, return_cache=False,
                 deterministic=True, num_groups=1, inner_act_fn=None,
                 outer_act_fn=None, moe_shard_fns=None, slot_mask=None,
                 block_table=None, page_span=None, dispatch=None,
                 suffix_readonly=False):
    def _reshard(t):
        # force the residual add's output back to the between-block
        # sharding so GSPMD lowers the partial-sum as a reduce-scatter
        # instead of all-reduce + re-gather
        return outer_act_fn(t) if outer_act_fn is not None else t
    lg = lora or {}
    new_cache = {}
    h = rms_norm(p["mixer_norm"], x, cfg.rms_eps)
    if inner_act_fn is not None:
        # Megatron-SP: the residual stream is sequence-sharded between
        # blocks; gather S here so attention/FFN see the full sequence
        # (GSPMD emits all-gather on entry + reduce-scatter at the
        # residual add — same bytes as the TP all-reduce, but the saved
        # carry is 1/TP the size)
        h = inner_act_fn(h)
    if kind == "attn":
        h, mc = attn_mod.apply_attention(
            p["attn"], cfg, h, positions, lora=lg.get("attn"),
            lora_scale=lora_scale,
            cache=(cache or {}).get("attn"), cache_pos=cache_pos,
            return_cache=return_cache, block_table=block_table,
            page_span=page_span, suffix_readonly=suffix_readonly)
        if mc is not None:
            new_cache["attn"] = mc
    else:
        h, mc = ssm_mod.apply_mamba(
            p["ssm"], cfg, h, lora=lg.get("ssm"), lora_scale=lora_scale,
            cache=(cache or {}).get("ssm"), return_cache=return_cache)
        if mc is not None:
            new_cache["ssm"] = mc
    x = _reshard(x + h)

    aux = None
    if is_moe:
        h2 = rms_norm(p["ffn_norm"], x, cfg.rms_eps)
        if inner_act_fn is not None:
            h2 = inner_act_fn(h2)
        h2, aux = moe_mod.apply_moe(
            p["moe"], cfg, h2, k=k, rescaler=rescaler,
            lora=lg.get("moe"), lora_scale=lora_scale,
            deterministic=deterministic, num_groups=num_groups,
            shard_fns=moe_shard_fns, slot_mask=slot_mask, dispatch=dispatch)
        x = _reshard(x + h2)
    elif cfg.d_ff > 0:
        h2 = rms_norm(p["ffn_norm"], x, cfg.rms_eps)
        if inner_act_fn is not None:
            h2 = inner_act_fn(h2)
        h2 = apply_ffn(p["ffn"], h2, lg.get("ffn"), lora_scale,
                       kernels=cfg.kernels)
        x = _reshard(x + h2)
    return x, aux, (new_cache if new_cache else None)


# ==========================================================================
# forward over the full stack (scan over periods)
# ==========================================================================

def _stack_scan(cfg, params, x, positions, *, trainable, k,
                cache=None, cache_pos=None, return_cache=False,
                remat=False, remat_chunk=0, deterministic=True,
                num_groups=1, act_fn=None, inner_act_fn=None,
                moe_shard_fns=None, slot_mask=None, block_table=None,
                page_span=None, dispatch=None, cache_readonly=False,
                suffix_readonly=False):
    P = cfg.pattern_period
    trainable = trainable or {}
    lora_blocks = (trainable.get("lora") or {}).get("blocks") or {}
    rescalers = trainable.get("rescaler") or {}
    lora_scale = cfg.lora.scale if cfg.lora.enabled else 0.0
    k = k if k is not None else cfg.moe.top_k

    xs = {"params": params["blocks"]}
    if lora_blocks:
        xs["lora"] = lora_blocks
    if rescalers:
        xs["rescaler"] = rescalers

    # Decode path: thread the cache through the scan CARRY (updated with
    # dynamic_update_index per period) instead of xs→ys.  While-loop carry
    # buffers alias in place; xs→ys would double-buffer the whole cache —
    # measured +20 GB/device on llama3-405b × decode_32k (EXPERIMENTS.md
    # §Perf H3).  ``cache_readonly`` opts out of the carry: the cache is
    # only read (xs) while the per-period NEW K/V — shaped like a
    # contiguous piece, not like the pool — still comes back via ys
    # (the suffix-prefill path).
    carry_cache = cache is not None and return_cache and not cache_readonly
    if cache is not None and not carry_cache:
        xs["cache"] = cache

    def body(h, sl):
        if act_fn is not None:
            # sharding constraint on the residual stream (= the remat'd
            # scan carry, i.e. the saved-activation footprint)
            h = act_fn(h)
        counts = {}
        new_caches = {}
        for pos in range(P):
            key = f"pos{pos}"
            kind = cfg.layer_kind(pos)
            is_moe = cfg.layer_is_moe(pos)
            r = sl.get("rescaler", {}).get(key)
            h, aux, nc = _apply_block(
                cfg, kind, is_moe, sl["params"][key], h, positions,
                lora=sl.get("lora", {}).get(key),
                rescaler=r, lora_scale=lora_scale, k=k,
                cache=(sl.get("cache") or {}).get(key),
                cache_pos=cache_pos, return_cache=return_cache,
                deterministic=deterministic, num_groups=num_groups,
                inner_act_fn=inner_act_fn,
                outer_act_fn=act_fn if inner_act_fn is not None else None,
                moe_shard_fns=moe_shard_fns, slot_mask=slot_mask,
                block_table=block_table, page_span=page_span,
                dispatch=dispatch, suffix_readonly=suffix_readonly)
            if aux is not None:
                counts[key] = aux.activation_counts
            if nc is not None:
                new_caches[key] = nc
        ys = {}
        if counts:
            ys["counts"] = counts
        if new_caches:
            ys["cache"] = new_caches
        return h, ys

    n_periods = cfg.num_layers // P
    if carry_cache:
        def body_cc(carry, sl):
            h, cache_c = carry
            i = sl["idx"]
            cache_slice = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, i, 0,
                                                       keepdims=False),
                cache_c)
            sl2 = {k2: v for k2, v in sl.items() if k2 != "idx"}
            sl2["cache"] = cache_slice
            h, ys = body(h, sl2)
            nc = ys.pop("cache")
            cache_c = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), i, 0), cache_c, nc)
            return (h, cache_c), ys

        xs_cc = dict(xs)
        xs_cc["idx"] = jnp.arange(n_periods)
        (h, new_cache), ys = jax.lax.scan(body_cc, (x, cache), xs_cc)
        ys = dict(ys)
        ys["cache"] = new_cache
        return h, ys

    if (remat and remat_chunk and 1 < remat_chunk < n_periods
            and cache is None and not return_cache):
        # two-level (√L) checkpointing: scan over groups of periods, remat
        # at both levels — saved residuals drop from n_periods·|h| to
        # (n_outer + chunk)·|h| at the cost of one extra re-forward.
        # This is what lets llama3-405b train with UNSHARDED activations
        # (no per-matmul activation collectives) — see EXPERIMENTS.md §Perf.
        g = remat_chunk
        while n_periods % g:
            g -= 1
        n_outer = n_periods // g
        xs2 = jax.tree.map(
            lambda t: t.reshape((n_outer, g) + t.shape[1:]), xs)
        inner = jax.checkpoint(body, prevent_cse=False)

        def outer_body(h, sl):
            return jax.lax.scan(inner, h, sl)

        outer = jax.checkpoint(outer_body, prevent_cse=False)
        h, ys = jax.lax.scan(outer, x, xs2)
        ys = jax.tree.map(
            lambda t: t.reshape((n_periods,) + t.shape[2:]), ys)
        return h, ys

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    h, ys = jax.lax.scan(body, x, xs)
    return h, ys


def forward_hidden(cfg, params, tokens, *, trainable=None, k=None,
                   positions=None, remat=False, remat_chunk=0,
                   deterministic=True, num_groups=1, act_fn=None,
                   inner_act_fn=None, moe_shard_fns=None):
    """tokens -> final hidden states (pre-head).  Returns (h, aux)."""
    B, S = tokens.shape[:2]
    if positions is None:
        positions = jnp.arange(S)
    x = embed_tokens(params, cfg, tokens)
    h, ys = _stack_scan(cfg, params, x, positions, trainable=trainable, k=k,
                        remat=remat, remat_chunk=remat_chunk,
                        deterministic=deterministic,
                        num_groups=num_groups, act_fn=act_fn,
                        inner_act_fn=inner_act_fn,
                        moe_shard_fns=moe_shard_fns)
    h = rms_norm(params["final_norm"], h, cfg.rms_eps)
    return h, ys.get("counts", {})


def forward(cfg, params, tokens, *, trainable=None, k=None, positions=None,
            remat=False, deterministic=True, num_groups=1, act_fn=None):
    """tokens -> logits.  Returns (logits, activation_counts)."""
    h, counts = forward_hidden(cfg, params, tokens, trainable=trainable,
                               k=k, positions=positions, remat=remat,
                               deterministic=deterministic,
                               num_groups=num_groups, act_fn=act_fn)
    return lm_head(params, cfg, h), counts


# ==========================================================================
# loss (seq-chunked cross-entropy so (B,S,V) logits never materialise)
# ==========================================================================

def chunked_ce_loss(cfg, params, h: jnp.ndarray, labels: jnp.ndarray,
                    mask: Optional[jnp.ndarray] = None,
                    chunk: int = 512) -> jnp.ndarray:
    """h: (B,S,D); labels: (B,S) or (B,S,K); mask: (B,S) 0/1."""
    B, S, _ = h.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)

    hc = jnp.moveaxis(h.reshape(B, nc, chunk, -1), 1, 0)
    lc = jnp.moveaxis(labels.reshape((B, nc, chunk) + labels.shape[2:]), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)

    def body(carry, inp):
        tot, cnt = carry
        hh, ll, mm = inp
        logits = lm_head(params, cfg, hh).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = lse - gold                       # (B,chunk[,K])
        if nll.ndim == 3:                      # audio codebooks: mean over K
            nll = nll.mean(-1)
        tot = tot + (nll * mm).sum()
        cnt = cnt + mm.sum()
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(cfg, params, tokens, labels, mask=None, *, trainable=None,
            k=None, remat=False, remat_chunk=0, num_groups=1, act_fn=None,
            inner_act_fn=None, moe_shard_fns=None):
    """Full LM loss.  Returns (loss, activation_counts)."""
    h, counts = forward_hidden(cfg, params, tokens, trainable=trainable,
                               k=k, remat=remat, remat_chunk=remat_chunk,
                               deterministic=True,
                               num_groups=num_groups, act_fn=act_fn,
                               inner_act_fn=inner_act_fn,
                               moe_shard_fns=moe_shard_fns)
    return chunked_ce_loss(cfg, params, h, labels, mask), counts


# ==========================================================================
# decode path
# ==========================================================================

def cache_len_for(cfg, seq_len: int) -> int:
    if cfg.attention_window > 0:
        return min(cfg.attention_window, seq_len)
    return seq_len


def init_cache(cfg, batch: int, seq_len: int) -> PyTree:
    """Zeroed decode cache for the whole stack (leading axis n_periods)."""
    P = cfg.pattern_period
    n_periods = cfg.num_layers // P
    dtype = jnp.dtype(cfg.dtype)
    clen = cache_len_for(cfg, seq_len)
    cache = {}
    for pos in range(P):
        kind = cfg.layer_kind(pos)
        if kind == "attn":
            hd = cfg.head_dim_
            c = {"attn": {
                "k": jnp.zeros((n_periods, batch, clen, cfg.n_kv_heads, hd),
                               dtype),
                "v": jnp.zeros((n_periods, batch, clen, cfg.n_kv_heads, hd),
                               dtype),
            }}
        else:
            base = ssm_mod.init_mamba_cache(cfg, batch)
            c = {"ssm": jax.tree.map(
                lambda t: jnp.broadcast_to(t, (n_periods,) + t.shape), base)}
        cache[f"pos{pos}"] = c
    return cache


def init_paged_cache(cfg, num_slots: int, num_blocks: int,
                     block_size: int) -> PyTree:
    """Zeroed block-paged decode cache (leading axis n_periods).

    Attention K/V live in a global pool of ``num_blocks + 1`` fixed-size
    blocks — block 0 is the null/trash block that unallocated block-table
    entries point at — instead of per-slot contiguous rows.  Mamba SSM
    state is O(1) per request, so it stays per-slot (``num_slots`` rows on
    axis 1), exactly as in :func:`init_cache`."""
    P = cfg.pattern_period
    n_periods = cfg.num_layers // P
    dtype = jnp.dtype(cfg.dtype)
    cache = {}
    for pos in range(P):
        kind = cfg.layer_kind(pos)
        if kind == "attn":
            hd = cfg.head_dim_
            shape = (n_periods, num_blocks + 1, block_size,
                     cfg.n_kv_heads, hd)
            c = {"attn": {"k": jnp.zeros(shape, dtype),
                          "v": jnp.zeros(shape, dtype)}}
        else:
            base = ssm_mod.init_mamba_cache(cfg, num_slots)
            c = {"ssm": jax.tree.map(
                lambda t: jnp.broadcast_to(t, (n_periods,) + t.shape), base)}
        cache[f"pos{pos}"] = c
    return cache


def decode_step(cfg, params, cache, tokens, pos, *, trainable=None, k=None,
                num_groups=1, slot_mask=None, block_table=None,
                page_span=None, no_drop=False, dispatch=None,
                return_counts=False):
    """One decode step.  tokens: (B,S) or (B,S,K); pos: scalar int, or a
    (B,) vector of per-row positions — the serving engine's slotted decode,
    where every cache slot sits at a different depth (serving/engine.py).
    ``S`` is normally 1; ``S > 1`` is the speculative verify step: the S
    tokens are teacher-forced at positions ``pos .. pos+S-1`` against the
    cache (attention-only models; attention.verify_attention) and logits
    for every window position come back in one call.
    ``k`` follows :func:`repro.models.moe_layer.apply_moe`: an int, or a
    length-B tuple of per-slot expert budgets (FLAME's adaptive-k serving);
    ``slot_mask``: optional dynamic (B,) 0/1 vector masking rows (free
    serving slots) out of MoE routing entirely.

    ``block_table``: optional (B, max_blocks) int32 table selecting this
    step's KV pages per row — the cache's attention leaves are then the
    block-paged pool from :func:`init_paged_cache`.  ``page_span`` (static
    int) is each row's logical capacity in tokens: the ring modulus for
    sliding-window models and the mask cap for the gathered pages
    (serving/kv_cache.BlockPool).

    ``dispatch``/``no_drop`` select the MoE token-dispatch mode
    (:func:`repro.models.moe_layer.apply_moe`): ``dispatch`` is one of
    ``"capacity"``/``"dense"``/``"ragged"``; ``no_drop=True`` is the
    legacy spelling of ``dispatch="dense"``.
    Returns (logits (B,S,V[,K]), new_cache), or with
    ``return_counts=True`` (logits, new_cache, counts) where ``counts``
    is ``{posN: (n_periods, E)}`` per-expert activation counts for this
    step — the router already computes them (``MoEAux``), so surfacing
    them costs one small extra output, no kernel changes
    (repro.obs.expert_load consumes these host-side)."""
    dispatch = moe_mod.resolve_dispatch(dispatch, no_drop)
    x = embed_tokens(params, cfg, tokens)
    B, S = x.shape[0], x.shape[1]
    pos = jnp.asarray(pos)
    base = pos[:, None] if pos.ndim == 1 else jnp.full((B, 1), pos)
    positions = base + jnp.arange(S)[None, :]
    h, ys = _stack_scan(cfg, params, x, positions, trainable=trainable, k=k,
                        cache=cache, cache_pos=pos, return_cache=True,
                        num_groups=num_groups, slot_mask=slot_mask,
                        block_table=block_table, page_span=page_span,
                        dispatch=dispatch)
    h = rms_norm(params["final_norm"], h, cfg.rms_eps)
    logits = lm_head(params, cfg, h)
    if return_counts:
        return logits, ys["cache"], ys.get("counts", {})
    return logits, ys["cache"]


def draft_window(cfg, params, cache, tok0, pos, keys, *, sample_fn,
                 window, trainable=None, k=None, block_table=None,
                 page_span=None, dispatch=None):
    """W sequential reduced-k decode steps fused into one graph, for the
    speculative draft phase (serving/speculative.py) — WITHOUT touching
    the KV cache.

    The verify step overwrites the window's cache positions with full-k
    K/V anyway, so the draft pass has no reason to write them: each
    step's K/V go into a small per-layer window buffer ((B, W, KV, hd)
    per period) carried through the scan, and attention reads the
    existing cache READ-ONLY (attention.apply_draft_attention).  That
    removes the whole-cache read-modify-write from every draft step —
    the cache-carry machinery is most of a decode step's cost at small
    batch — and for the paged layout the prefix pages are gathered into
    a contiguous buffer ONCE, so the W steps also skip the per-step
    block-table indirection.

    tok0: (B,1) first window token per row; pos: (B,) window-start
    positions (== each row's cache_pos); keys: (W,B,2) per-step sampling
    keys; ``sample_fn(logits (B,V) fp32, keys_j (B,2)) -> (B,) int32``
    picks each step's token in-graph.  ``k`` is the scalar draft budget
    (every row drafts at the same cheap k — free rows ride along; with a
    loss-free dispatch they cannot perturb real rows, and the rejection
    rule is exact for ANY draft distribution regardless).

    Attention-only models (SSM state cannot roll back) with a
    non-wrapping cache (the serving engine guards both).
    Returns (draft_logits (W,B,V) fp32, draft_tokens (W,B) int32).
    """
    P = cfg.pattern_period
    if any(cfg.layer_kind(p) != "attn" for p in range(P)):
        raise ValueError("draft_window requires attention-only models")
    dispatch = moe_mod.resolve_dispatch(dispatch, False)
    n_periods = cfg.num_layers // P
    trainable = trainable or {}
    lora_blocks = (trainable.get("lora") or {}).get("blocks") or {}
    rescalers = trainable.get("rescaler") or {}
    lora_scale = cfg.lora.scale if cfg.lora.enabled else 0.0
    kk = k if k is not None else cfg.moe.top_k
    W = window
    pos = jnp.asarray(pos)
    B = tok0.shape[0]
    hd = cfg.head_dim_
    dtype = jnp.dtype(cfg.dtype)

    static = {}                 # read-only contiguous prefix per pos-group
    win0 = {}                   # the window K/V buffers (scan carry)
    for name, c in cache.items():
        kv = c["attn"]
        if block_table is not None:
            static[name] = {
                leaf: jax.vmap(lambda pool: attn_mod.paged_gather(
                    pool, block_table, page_span))(kv[leaf])
                for leaf in ("k", "v")}
        else:
            static[name] = {"k": kv["k"], "v": kv["v"]}
        KV = kv["k"].shape[-2]
        win0[name] = {
            "k": jnp.zeros((n_periods, B, W, KV, hd), dtype),
            "v": jnp.zeros((n_periods, B, W, KV, hd), dtype)}

    xs_stack = {"params": params["blocks"], "static": static,
                "idx": jnp.arange(n_periods)}
    if lora_blocks:
        xs_stack["lora"] = lora_blocks
    if rescalers:
        xs_stack["rescaler"] = rescalers

    def one_step(tok, win, key_j, j):
        x = embed_tokens(params, cfg, tok)               # (B,1,D)
        positions = pos[:, None] + j                     # (B,1)

        def body(carry, sl):
            h, win_c = carry
            i = sl["idx"]
            win_slice = jax.tree.map(
                lambda c_: jax.lax.dynamic_index_in_dim(c_, i, 0,
                                                        keepdims=False),
                win_c)
            new_slices = {}
            for lpos in range(P):
                name = f"pos{lpos}"
                pblk = sl["params"][name]
                lg = sl.get("lora", {}).get(name) or {}
                h1 = rms_norm(pblk["mixer_norm"], h, cfg.rms_eps)
                h1, nw = attn_mod.apply_draft_attention(
                    pblk["attn"], cfg, h1, positions, j,
                    win_slice[name], sl["static"][name], pos,
                    lora=lg.get("attn"), lora_scale=lora_scale)
                new_slices[name] = nw
                h = h + h1
                if cfg.layer_is_moe(lpos):
                    h2 = rms_norm(pblk["ffn_norm"], h, cfg.rms_eps)
                    h2, _ = moe_mod.apply_moe(
                        pblk["moe"], cfg, h2, k=kk,
                        rescaler=sl.get("rescaler", {}).get(name),
                        lora=lg.get("moe"), lora_scale=lora_scale,
                        deterministic=True, dispatch=dispatch)
                    h = h + h2
                elif cfg.d_ff > 0:
                    h2 = rms_norm(pblk["ffn_norm"], h, cfg.rms_eps)
                    h2 = apply_ffn(pblk["ffn"], h2, lg.get("ffn"),
                                   lora_scale, kernels=cfg.kernels)
                    h = h + h2
            win_c = jax.tree.map(
                lambda c_, n: jax.lax.dynamic_update_index_in_dim(
                    c_, n.astype(c_.dtype), i, 0), win_c, new_slices)
            return (h, win_c), None

        (h, win), _ = jax.lax.scan(body, (x, win), xs_stack)
        h = rms_norm(params["final_norm"], h, cfg.rms_eps)
        logits = lm_head(params, cfg, h)[:, 0].astype(jnp.float32)
        nxt = sample_fn(logits, key_j).astype(tok0.dtype)
        return logits, nxt, win

    def outer(carry, xs_j):
        tok, win = carry
        key_j, j = xs_j
        logits, nxt, win = one_step(tok, win, key_j, j)
        return (nxt[:, None], win), (logits, nxt)

    (_, _), (qs, toks) = jax.lax.scan(
        outer, (tok0, win0), (keys, jnp.arange(W)))
    return qs, toks


def prefill(cfg, params, tokens, *, trainable=None, k=None, num_groups=1,
            act_fn=None, cache_len=None, slot_mask=None, no_drop=False,
            dispatch=None):
    """Forward pass that also builds the decode cache.
    Returns (logits_last (B,1,V[,K]), cache).

    ``cache_len``: total decode capacity; attention K/V caches are
    zero-padded from the prompt length up to ``cache_len_for(cfg,
    cache_len)`` so decode_step can write new tokens in place (the padded
    slots are masked out by ``idx <= pos`` until written).

    ``slot_mask``: optional dynamic (B,) 0/1 row mask — rows at 0 are
    excluded from MoE routing (the serving engine's prefill batch-bucket
    padding rows, which must not consume expert capacity).

    ``dispatch``/``no_drop``: MoE token-dispatch mode, as in
    :func:`decode_step`."""
    dispatch = moe_mod.resolve_dispatch(dispatch, no_drop)
    B, S = tokens.shape[:2]
    positions = jnp.arange(S)
    x = embed_tokens(params, cfg, tokens)
    h, ys = _stack_scan(cfg, params, x, positions, trainable=trainable,
                        k=k, return_cache=True, num_groups=num_groups,
                        act_fn=act_fn, slot_mask=slot_mask,
                        dispatch=dispatch)
    cache = ys["cache"]
    target = cache_len_for(cfg, cache_len or S)

    def pad_attn(c):
        if "attn" not in c:
            return c
        kv = c["attn"]
        cur = kv["k"].shape[2]              # (n_periods, B, Sc, KV, hd)
        if cur >= target:
            return c
        pad = [(0, 0)] * kv["k"].ndim
        pad[2] = (0, target - cur)
        return {**c, "attn": {"k": jnp.pad(kv["k"], pad),
                              "v": jnp.pad(kv["v"], pad)}}

    cache = {pos: pad_attn(c) for pos, c in cache.items()}
    h = rms_norm(params["final_norm"], h[:, -1:], cfg.rms_eps)
    return lm_head(params, cfg, h), cache


def prefill_suffix(cfg, params, tokens, prefix_len, suffix_len, cache,
                   block_table, *, page_span, trainable=None, k=None,
                   num_groups=1, slot_mask=None, dispatch=None):
    """Suffix-only cached prefill against a block-paged pool.

    A request whose prompt head is already cached (prefix sharing,
    serving/kv_cache.BlockPool) pays compute for the *unmatched suffix*
    only: ``tokens`` (B, S) holds each row's suffix (padded to the
    bucket), RoPE'd and attended at absolute positions ``prefix_len[b] +
    s``, reading the attached prefix pages through ``block_table``
    read-only (attention.apply_attention suffix mode).  MoE routing runs
    over the S suffix columns only, so ragged dispatch cost drops to
    ``sum(suffix_len · k)`` instead of ``sum(prompt_len · k)``.

    ``prefix_len``/``suffix_len``: (B,) int32 — the per-row cached-prefix
    offset and real (un-padded) suffix length; logits come from column
    ``suffix_len - 1``.  ``slot_mask``: optional (B, S) 0/1 per-token
    validity (padding rows AND ragged suffix-padding columns), required
    by the capacity dispatch mode; the loss-free modes only need it for
    rows (padding cannot perturb real tokens there).

    Attention-only models (an SSM's state at the suffix start is not
    reconstructible from cached K/V — the engine gates on this).
    Returns (logits (B, 1, V) at the last real suffix token, piece) where
    ``piece[pos]["attn"]["k"|"v"]`` is (n_periods, B, S, KV, hd) with
    column ``c`` holding prompt position ``prefix_len[b] + c`` — exactly
    what ``BlockPool.write(..., starts=, piece_col0=)`` scatters.
    """
    P = cfg.pattern_period
    if any(cfg.layer_kind(p) != "attn" for p in range(P)):
        raise ValueError("prefill_suffix requires attention-only models")
    dispatch = moe_mod.resolve_dispatch(dispatch, False)
    B, S = tokens.shape[:2]
    prefix_len = jnp.asarray(prefix_len)
    suffix_len = jnp.asarray(suffix_len)
    positions = prefix_len[:, None] + jnp.arange(S)[None, :]
    x = embed_tokens(params, cfg, tokens)
    h, ys = _stack_scan(cfg, params, x, positions, trainable=trainable,
                        k=k, cache=cache, cache_pos=prefix_len,
                        return_cache=True, cache_readonly=True,
                        num_groups=num_groups, slot_mask=slot_mask,
                        block_table=block_table, page_span=page_span,
                        dispatch=dispatch, suffix_readonly=True)
    h = rms_norm(params["final_norm"], h, cfg.rms_eps)
    last = h[jnp.arange(B), jnp.clip(suffix_len - 1, 0, S - 1)]
    return lm_head(params, cfg, last[:, None]), ys["cache"]
