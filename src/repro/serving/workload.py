"""Synthetic open-loop serving workloads.

Generates deterministic (seeded) request traces for the engine
benchmarks: Poisson / diurnal / bursty arrival processes, categorical or
heavy-tailed (Zipf) output-length distributions, a tier mix mapping
expert budgets k to traffic fractions (FLAME's premium/constrained
client tiers at serving time), and optional shared system-prompt
prefixes for exercising the paged pool's prefix cache.  ``rate=inf``
collapses the trace to a closed batch (everything arrives at t=0) — the
deterministic configuration the parity tests use.

Arrival processes (``arrival=``):

* ``"poisson"`` — homogeneous: exponential inter-arrivals at ``rate``.
* ``"diurnal"`` — the rate is modulated by a sinusoid of period
  ``diurnal_period_s`` swinging ``±diurnal_depth`` around ``rate`` (a
  compressed day/night load curve); inter-arrivals are exponential at
  the instantaneous rate.
* ``"burst"`` — every ``burst_every_s`` seconds the rate multiplies by
  ``burst_factor`` for ``burst_len_s`` seconds (flash-crowd spikes on a
  quiet baseline) — the overload-bench shape.

Output lengths (``length_dist=``): ``"categorical"`` draws from
``new_tokens``/``new_tokens_probs``; ``"zipf"`` draws
``min(new_tokens) - 1 + Zipf(zipf_alpha)`` clipped to ``max_new_cap`` —
a heavy right tail of long generations over a short-request bulk.

Shared prefixes: with ``shared_prefix_len > 0`` every prompt starts with
one of ``n_shared_prefixes`` fixed token templates (chosen per request),
followed by private random tokens — the many-requests-one-system-prompt
shape prefix caching exists for.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .scheduler import Request


@dataclass(frozen=True)
class WorkloadConfig:
    """Declarative trace spec; :func:`make_trace` materialises it."""
    n_requests: int = 32
    rate: float = float("inf")            # mean arrival rate, requests/s
    prompt_lens: Tuple[int, ...] = (16, 32)
    prompt_len_probs: Optional[Tuple[float, ...]] = None   # None = uniform
    new_tokens: Tuple[int, ...] = (8, 16)
    new_tokens_probs: Optional[Tuple[float, ...]] = None
    # (k, fraction) tier mix; empty = every request takes any slot
    tier_mix: Tuple[Tuple[int, float], ...] = ()
    vocab_size: int = 512
    seed: int = 0
    # arrival process: "poisson" | "diurnal" | "burst"
    arrival: str = "poisson"
    diurnal_period_s: float = 2.0
    diurnal_depth: float = 0.8            # rate swing fraction in [0, 1)
    burst_every_s: float = 1.0
    burst_len_s: float = 0.2
    burst_factor: float = 8.0
    # output-length distribution: "categorical" | "zipf"
    length_dist: str = "categorical"
    zipf_alpha: float = 1.8
    max_new_cap: int = 64                 # clip for the zipf tail
    # shared system-prompt prefixes (0 = fully private prompts)
    shared_prefix_len: int = 0
    n_shared_prefixes: int = 1


def _rate_at(wl: WorkloadConfig, t: float) -> float:
    """Instantaneous arrival rate of the configured process at time t."""
    if wl.arrival == "diurnal":
        return wl.rate * (1.0 + wl.diurnal_depth
                          * math.sin(2.0 * math.pi * t
                                     / wl.diurnal_period_s))
    if wl.arrival == "burst":
        in_burst = (t % wl.burst_every_s) < wl.burst_len_s
        return wl.rate * (wl.burst_factor if in_burst else 1.0)
    return wl.rate


def make_trace(wl: WorkloadConfig) -> List[Request]:
    """Materialise a deterministic request trace from ``wl``.

    Everything is drawn from one ``np.random.default_rng(wl.seed)``
    stream, so equal configs produce identical traces (arrival times,
    prompts, tiers and lengths alike)."""
    assert wl.arrival in ("poisson", "diurnal", "burst"), wl.arrival
    assert wl.length_dist in ("categorical", "zipf"), wl.length_dist
    assert 0.0 <= wl.diurnal_depth < 1.0, wl.diurnal_depth
    rng = np.random.default_rng(wl.seed)
    ks: Sequence[Optional[int]]
    if wl.tier_mix:
        tiers = [k for k, _ in wl.tier_mix]
        fracs = np.asarray([f for _, f in wl.tier_mix], np.float64)
        fracs = fracs / fracs.sum()
        ks = rng.choice(tiers, size=wl.n_requests, p=fracs).tolist()
    else:
        ks = [None] * wl.n_requests

    prefixes: Optional[np.ndarray] = None
    if wl.shared_prefix_len > 0:
        assert wl.shared_prefix_len < min(wl.prompt_lens), \
            "shared prefix must leave room for private prompt tokens"
        prefixes = rng.integers(
            0, wl.vocab_size,
            (wl.n_shared_prefixes, wl.shared_prefix_len)).astype(np.int32)

    t = 0.0
    out: List[Request] = []
    for i in range(wl.n_requests):
        if np.isfinite(wl.rate) and wl.rate > 0 and i > 0:
            # exponential inter-arrival at the instantaneous rate — a
            # cheap deterministic approximation of the inhomogeneous
            # process, good enough for load-shape benchmarking
            t += float(rng.exponential(1.0 / _rate_at(wl, t)))
        L = int(rng.choice(wl.prompt_lens, p=wl.prompt_len_probs))
        if wl.length_dist == "zipf":
            n_new = min(wl.new_tokens) - 1 + int(rng.zipf(wl.zipf_alpha))
            n_new = min(n_new, wl.max_new_cap)
        else:
            n_new = int(rng.choice(wl.new_tokens, p=wl.new_tokens_probs))
        prompt = rng.integers(0, wl.vocab_size, (L,)).astype(np.int32)
        if prefixes is not None:
            which = int(rng.integers(0, wl.n_shared_prefixes))
            prompt[:wl.shared_prefix_len] = prefixes[which]
        out.append(Request(rid=i, prompt=prompt, max_new_tokens=n_new,
                           k=ks[i], arrival=t))
    return out


def percentile(xs: Sequence[float], q: float) -> float:
    """float(np.percentile) with an empty-input guard."""
    if not len(xs):
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), q))
