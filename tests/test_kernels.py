"""Pallas kernel validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles in repro/kernels/ref.py (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lora_matmul import lora_matmul, lora_matmul_experts
from repro.kernels.topk_router import topk_router


def _tol(dtype):
    # fp32 accumulation over K≈512 leaves ~1e-4 absolute noise on O(10) values
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------- flash attn

@pytest.mark.parametrize("S,H,KV,D,window", [
    (128, 4, 4, 64, 0),
    (256, 4, 2, 64, 0),        # GQA
    (128, 8, 1, 64, 0),        # MQA
    (256, 4, 2, 64, 64),       # sliding window
    (128, 2, 2, 128, 0),       # wide head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, KV, D, window, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, H, S, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, KV, S, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, KV, S, D), dtype)
    out = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_block_shape_independence():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 2, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 64))
    o1 = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    o2 = flash_attention(q, k, v, block_q=128, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- lora matmul

@pytest.mark.parametrize("M,K,N,r", [
    (256, 256, 256, 8), (512, 256, 128, 16), (128, 512, 256, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_sweep(M, K, N, r, dtype):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (M, K), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), dtype)
    a = jax.random.normal(jax.random.fold_in(key, 2), (K, r), dtype) * 0.1
    b = jax.random.normal(jax.random.fold_in(key, 3), (r, N), dtype) * 0.1
    out = lora_matmul(x, w, a, b, scale=0.8, block_m=128, block_n=128,
                      block_k=128, interpret=True)
    want = ref.lora_matmul_ref(x, w, a, b, 0.8)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_lora_matmul_zero_adapter_is_base_matmul():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (128, 128))
    w = jax.random.normal(jax.random.fold_in(key, 1), (128, 128))
    a = jax.random.normal(jax.random.fold_in(key, 2), (128, 8))
    b = jnp.zeros((8, 128))
    out = lora_matmul(x, w, a, b, scale=1.0, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("E,C,K,N,r", [(4, 128, 128, 128, 8),
                                       (2, 256, 128, 256, 16)])
def test_lora_matmul_experts_sweep(E, C, K, N, r):
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (E, C, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (E, K, N))
    a = jax.random.normal(jax.random.fold_in(key, 2), (E, K, r)) * 0.1
    b = jax.random.normal(jax.random.fold_in(key, 3), (E, r, N)) * 0.1
    out = lora_matmul_experts(x, w, a, b, scale=0.5, block_m=64,
                              block_n=64, block_k=64, interpret=True)
    want = ops.lora_matmul_experts(x, w, a, b, scale=0.5, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- router

@pytest.mark.parametrize("T,E,k", [(512, 8, 2), (1024, 64, 8), (256, 16, 1),
                                   (2048, 64, 4)])
def test_topk_router_sweep(T, E, k):
    logits = jax.random.normal(jax.random.PRNGKey(5), (T, E))
    w1, m1, c1 = topk_router(logits, k, block_t=256, interpret=True)
    w2, m2, c2 = ref.topk_router_ref(logits, k)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2))


def test_topk_router_counts_accumulate_across_blocks():
    """Counts output block is revisited by every grid step — verify the
    accumulation by comparing against a single-block call."""
    logits = jax.random.normal(jax.random.PRNGKey(6), (1024, 8))
    _, _, c_multi = topk_router(logits, 2, block_t=128, interpret=True)
    _, _, c_single = topk_router(logits, 2, block_t=1024, interpret=True)
    np.testing.assert_allclose(np.asarray(c_multi), np.asarray(c_single))
    assert float(c_multi.sum()) == 2 * 1024


def test_router_matches_model_routing():
    """Kernel semantics == models.moe_layer.topk_routing (the path the
    GSPMD-lowered model actually uses)."""
    from repro.models.moe_layer import topk_routing
    logits = jax.random.normal(jax.random.PRNGKey(7), (256, 16))
    w_k, m_k, _ = topk_router(logits, 4, interpret=True)
    w_m, m_m = topk_routing(logits, 4)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_m),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_m))
