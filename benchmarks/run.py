"""Benchmark orchestrator — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table1 fig3
  PYTHONPATH=src python -m benchmarks.run --smoke --out bench-smoke.json

``--smoke`` runs the fast hardware-facing subset (kernel micro-bench +
end-to-end backend bench) — the CI job.  ``--out PATH`` writes every
emitted row as JSON (the artifact CI uploads).

Output: CSV blocks (``name,...`` headers) + `#` summary lines asserting the
paper's directional claims.  Roofline numbers live in EXPERIMENTS.md
(§Roofline) — they come from the dry-run, not from CPU wall clock.
"""
from __future__ import annotations

import argparse
import inspect
import json
import time

from . import (backend_bench, common, federated_scale_bench, fig2_activation,
               fig3_temperature, kernel_bench, round_engine_bench,
               serving_bench, table1_flops, table2_budgets, table3_scale,
               table4_sampling, table5_rescaler, telemetry_bench)

ALL = {
    "table1": table1_flops.run,
    "table2": table2_budgets.run,
    "table3": table3_scale.run,
    "table4": table4_sampling.run,
    "table5": table5_rescaler.run,
    "fig2": fig2_activation.run,
    "fig3": fig3_temperature.run,
    "kernels": kernel_bench.run,
    "backend": backend_bench.run,
    "round_engine": round_engine_bench.run,
    "federated_scale": federated_scale_bench.run,
    "serving": serving_bench.run,
    "telemetry": telemetry_bench.run,
}

# CPU-fast subset for CI (`--smoke`): no pretraining; federated_scale
# self-limits to its 64-client row under smoke
SMOKE = ["kernels", "backend", "serving", "telemetry", "federated_scale"]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("picks", nargs="*", help=f"subset of {list(ALL)}")
    ap.add_argument("--smoke", action="store_true",
                    help="run the fast CI subset")
    ap.add_argument("--out", metavar="PATH",
                    help="write emitted rows as JSON to PATH")
    ns = ap.parse_args(argv)

    picks = ns.picks or (SMOKE if ns.smoke else list(ALL))
    t0 = time.time()
    for name in picks:
        if name not in ALL:
            raise SystemExit(f"unknown benchmark {name!r}; "
                             f"choose from {list(ALL)}")
        t = time.time()
        fn = ALL[name]
        # benchmarks that can scale themselves down take smoke=True
        kw = ({"smoke": True} if ns.smoke
              and "smoke" in inspect.signature(fn).parameters else {})
        fn(**kw)
        print(f"# [{name}] done in {time.time() - t:.1f}s", flush=True)
    wall = time.time() - t0
    print(f"\n# all benchmarks done in {wall:.1f}s")
    if ns.out:
        payload = {"benchmarks": picks, "wall_s": round(wall, 2),
                   "results": common.RESULTS}
        if common.TELEMETRY:
            payload["telemetry"] = common.TELEMETRY
        with open(ns.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(common.RESULTS)} rows to {ns.out}")


if __name__ == "__main__":
    main()
