"""Synthetic open-loop serving workloads.

Generates deterministic request traces for the engine benchmarks: Poisson
arrivals at a configurable rate, categorical prompt-length and
output-length distributions, and a tier mix mapping expert budgets k to
traffic fractions (FLAME's premium/constrained client tiers at serving
time).  ``rate=inf`` collapses the trace to a closed batch (everything
arrives at t=0) — the deterministic configuration the parity tests use.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .scheduler import Request


@dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int = 32
    rate: float = float("inf")            # Poisson arrival rate, requests/s
    prompt_lens: Tuple[int, ...] = (16, 32)
    prompt_len_probs: Optional[Tuple[float, ...]] = None   # None = uniform
    new_tokens: Tuple[int, ...] = (8, 16)
    new_tokens_probs: Optional[Tuple[float, ...]] = None
    # (k, fraction) tier mix; empty = every request takes any slot
    tier_mix: Tuple[Tuple[int, float], ...] = ()
    vocab_size: int = 512
    seed: int = 0


def make_trace(wl: WorkloadConfig) -> List[Request]:
    """Materialise a deterministic request trace from ``wl``."""
    rng = np.random.default_rng(wl.seed)
    ks: Sequence[Optional[int]]
    if wl.tier_mix:
        tiers = [k for k, _ in wl.tier_mix]
        fracs = np.asarray([f for _, f in wl.tier_mix], np.float64)
        fracs = fracs / fracs.sum()
        ks = rng.choice(tiers, size=wl.n_requests, p=fracs).tolist()
    else:
        ks = [None] * wl.n_requests

    t = 0.0
    out: List[Request] = []
    for i in range(wl.n_requests):
        if np.isfinite(wl.rate) and wl.rate > 0 and i > 0:
            t += float(rng.exponential(1.0 / wl.rate))
        L = int(rng.choice(wl.prompt_lens, p=wl.prompt_len_probs))
        n_new = int(rng.choice(wl.new_tokens, p=wl.new_tokens_probs))
        prompt = rng.integers(0, wl.vocab_size, (L,)).astype(np.int32)
        out.append(Request(rid=i, prompt=prompt, max_new_tokens=n_new,
                           k=ks[i], arrival=t))
    return out


def percentile(xs: Sequence[float], q: float) -> float:
    """float(np.percentile) with an empty-input guard."""
    if not len(xs):
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), q))
