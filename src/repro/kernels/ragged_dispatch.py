"""Sort-based ragged MoE dispatch: loss-free AND sum(k)-proportional.

The GShard one-hot dispatch (models/moe_layer.py) buys static shapes with a
per-expert capacity ``C``: every expert's token queue is padded to ``C``
and tokens past it are DROPPED.  The serving engine's loss-free mode pins
``C`` to the whole token count, so nothing ever drops — but then every
expert pays worst-case padding and the FLOPs-adaptivity of a small expert
budget k (FLAME's whole point) is gone.

This module is the third way: a **counting-sort** dispatch (the
static-shape form of argsort-by-expert + segment offsets).  Assignments
are laid out expert-major in one ragged buffer of ``N`` rows:

  =========  ============================================================
  segment    expert ``e`` owns rows ``[off[e], off[e] + count[e])``,
             its segment padded up to a multiple of ``block_m`` so
             matmul tiles never straddle two experts;
  src        ``src[i]``: which token sits in buffer row ``i``
             (tokens ascending within each expert — a stable sort by
             expert key, computed with cumsums instead of a sort);
  blocks     ``block_expert[i]``: which expert's weights row-block ``i``
             multiplies (the segment-offset lookup, precomputed);
  inverse    ``rows[t, j]``: the buffer row holding token ``t``'s rank-j
             assignment, with combine weight ``wrank[t, j]`` — the
             combine is a per-token gather, no scatter races.
  =========  ============================================================

``N`` is **static**: the worst-case assignment count (``T * k``, or
``S * sum(slot_k)`` for per-slot budgets) plus one block of padding per
expert — so expert compute is proportional to the *activated budget*, not
``num_tokens × num_experts``.  Every token the router selects is routed —
no capacity limit, no dropping — and each token's output depends only on
its own row: co-batched rows provably cannot change results, which is why
the serving engine runs this mode by default (docs/kernels.md).

Three Pallas kernels implement the hot path (one grid program per
``block_m`` row block; scalar-prefetched plan arrays drive the dynamic
addressing), each with a pure-jnp oracle in :mod:`repro.kernels.ref` and a
``custom_vjp`` (kernel forward, reference backward) in
:mod:`repro.kernels.backend`:

* :func:`ragged_gather`   — ``xs[i] = x[src[i]] * valid[i]``;
* :func:`ragged_expert_matmul` — grouped (segment) LoRA matmul: row block
  ``i`` multiplies ``w[block_expert[i]]`` (+ the LoRA bypass);
* :func:`ragged_combine`  — ``out[t] = sum_j wrank[t,j] * eo[rows[t,j]]``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Row-block size of the ragged buffer: every expert segment is padded to a
# multiple of this, so grouped-matmul tiles never straddle experts.  8 is
# the fp32 sublane minimum — the smallest padding that still tiles.
BLOCK_M = 8


def ragged_rows(budget: int, num_experts: int,
                block_m: int = BLOCK_M) -> int:
    """Static ragged-buffer size for a worst-case assignment ``budget``:
    the budget rounded up to blocks, plus one block of segment padding per
    expert (each expert's count rounds up independently)."""
    return -(-budget // block_m) * block_m + num_experts * block_m


class RaggedPlan(NamedTuple):
    """Integer dispatch plan (plus the differentiable combine weights).

    Built once per MoE layer call from the router outputs; consumed by the
    three backend ops.  All layout arrays are int32 and carry no gradient;
    ``wrank`` is the per-rank combine weight and is differentiable back to
    the router weights."""

    src: jnp.ndarray           # (N,)  token id per buffer row
    valid: jnp.ndarray         # (N,)  0/1 — padding rows are 0
    block_expert: jnp.ndarray  # (N // block_m,) expert id per row block
    rows: jnp.ndarray          # (T, max_k) buffer row per (token, rank)
    wrank: jnp.ndarray         # (T, max_k) combine weight per rank (f32)


def ragged_plan(mask: jnp.ndarray, weights: jnp.ndarray, *, budget: int,
                max_k: int, block_m: int = BLOCK_M) -> RaggedPlan:
    """Counting-sort dispatch plan from router outputs.

    ``mask``/``weights``: (T, E) selection one-hots and renormalised
    combine weights (``ref.topk_router_ref`` layout); ``budget``: static
    worst-case total assignments (>= ``mask.sum()`` always); ``max_k``:
    static per-token selection cap (``rows``' second dim).

    The forward plan scatters each selected (token, expert) pair to its
    segment slot ``off[e] + rank_of_t_within_e``; the inverse plan reads
    the same expression at each token's top-``max_k`` experts.  Ranks past
    a token's own budget have ``wrank == 0`` and point at row 0 — they
    gather a live row times zero, never influencing anything.
    """
    T, E = mask.shape
    N = ragged_rows(budget, E, block_m)
    nb = N // block_m
    m = mask.astype(jnp.float32)
    counts = m.sum(axis=0).astype(jnp.int32)                       # (E,)
    padded = -(-counts // block_m) * block_m
    ends = jnp.cumsum(padded)
    off = ends - padded                                            # exclusive
    # rank of token t within expert e's segment (valid where selected)
    pos = (jnp.cumsum(m, axis=0) - 1.0).astype(jnp.int32)          # (T, E)
    slot = off[None, :] + pos
    dst = jnp.where(m > 0, slot, N)                # unselected -> dropped
    tok = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None], (T, E))
    src = jnp.zeros((N,), jnp.int32).at[dst].set(tok, mode="drop")
    valid = jnp.zeros((N,), jnp.int32).at[dst].set(1, mode="drop")
    starts = jnp.arange(nb, dtype=jnp.int32) * block_m
    block_expert = jnp.minimum(
        (ends[None, :] <= starts[:, None]).sum(axis=1), E - 1
    ).astype(jnp.int32)
    # inverse plan: a token's selected experts are exactly its nonzero
    # combine weights, in descending-weight order (the router's own rank
    # order — selection is nested, so rank order never matters for the sum)
    top_w, top_idx = jax.lax.top_k(weights, max_k)
    rank_valid = (top_w > 0).astype(weights.dtype)
    rows = jnp.take_along_axis(slot, top_idx, axis=1)
    rows = jnp.where(rank_valid > 0, rows, 0).astype(jnp.int32)
    wrank = top_w * rank_valid
    return RaggedPlan(src=src, valid=valid, block_expert=block_expert,
                      rows=rows, wrank=wrank)


# ==========================================================================
# Pallas kernels
# ==========================================================================

def _gather_kernel(src_ref, val_ref, x_ref, o_ref, *, block_m: int):
    i = pl.program_id(0)
    for r in range(block_m):                       # static unroll
        row = src_ref[i * block_m + r]
        v = val_ref[i * block_m + r]
        xr = pl.load(x_ref, (pl.ds(row, 1), slice(None)))
        o_ref[r, :] = (xr * v.astype(xr.dtype))[0]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def ragged_gather(x: jnp.ndarray, src: jnp.ndarray, valid: jnp.ndarray, *,
                  block_m: int = BLOCK_M, interpret: bool = True):
    """x: (T, D); src, valid: (N,) int32 -> xs (N, D) with
    ``xs[i] = x[src[i]] * valid[i]`` (padding rows zero)."""
    T, D = x.shape
    N = src.shape[0]
    assert N % block_m == 0, (N, block_m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N // block_m,),
        in_specs=[pl.BlockSpec((T, D), lambda i, s, v: (0, 0))],
        out_specs=pl.BlockSpec((block_m, D), lambda i, s, v: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, block_m=block_m),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(src, valid, x)


def _matmul_kernel(be_ref, x_ref, w_ref, o_ref, *, scale: float):
    del be_ref, scale
    xf = x_ref[...].astype(jnp.float32)
    y = jnp.dot(xf, w_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _matmul_lora_kernel(be_ref, x_ref, w_ref, a_ref, b_ref, o_ref, *,
                        scale: float):
    del be_ref
    xf = x_ref[...].astype(jnp.float32)
    y = jnp.dot(xf, w_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    xa = jnp.dot(xf, a_ref[0].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    y = y + jnp.dot(xa, b_ref[0].astype(jnp.float32),
                    preferred_element_type=jnp.float32) * scale
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def ragged_expert_matmul(xs: jnp.ndarray, block_expert: jnp.ndarray,
                         w: jnp.ndarray, a: Optional[jnp.ndarray] = None,
                         b: Optional[jnp.ndarray] = None, *,
                         scale: float = 0.0, interpret: bool = True):
    """Grouped (segment) matmul over the ragged buffer.

    xs: (N, K); block_expert: (N // bm,) int32; w: (E, K, H);
    a/b: optional per-expert LoRA factors (E, K, r) / (E, r, H).
    Row block ``i`` computes ``xs_i @ w[be[i]]`` (+ LoRA bypass) — the
    expert index comes in through the scalar-prefetched block spec, the
    MegaBlocks-style grouped GEMM.  fp32 accumulate, one cast at the end
    (the suite-wide numerics contract)."""
    N, K = xs.shape
    nb = block_expert.shape[0]
    assert N % nb == 0, (N, nb)
    bm = N // nb
    H = w.shape[-1]
    in_specs = [
        pl.BlockSpec((bm, K), lambda i, be: (i, 0)),
        pl.BlockSpec((1, K, H), lambda i, be: (be[i], 0, 0)),
    ]
    if a is None:
        kernel = functools.partial(_matmul_kernel, scale=scale)
        args = (block_expert, xs, w)
    else:
        r = a.shape[-1]
        in_specs += [
            pl.BlockSpec((1, K, r), lambda i, be: (be[i], 0, 0)),
            pl.BlockSpec((1, r, H), lambda i, be: (be[i], 0, 0)),
        ]
        kernel = functools.partial(_matmul_lora_kernel, scale=scale)
        args = (block_expert, xs, w, a, b)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, H), lambda i, be: (i, 0)),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, H), xs.dtype),
        interpret=interpret,
    )(*args)


def _combine_kernel(rows_ref, w_ref, eo_ref, o_ref, *, block_t: int,
                    max_k: int):
    i = pl.program_id(0)
    wblk = w_ref[...]                              # (bt, max_k)
    for r in range(block_t):
        acc = jnp.zeros((1, o_ref.shape[-1]), jnp.float32)
        for j in range(max_k):
            row = rows_ref[(i * block_t + r) * max_k + j]
            er = pl.load(eo_ref, (pl.ds(row, 1), slice(None)))
            acc = acc + er.astype(jnp.float32) * wblk[r, j]
        o_ref[r, :] = acc[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ragged_combine(eo: jnp.ndarray, rows: jnp.ndarray, wrank: jnp.ndarray,
                   *, block_t: int = BLOCK_M, interpret: bool = True):
    """eo: (N, D); rows: (T, max_k) int32; wrank: (T, max_k) ->
    out (T, D) with ``out[t] = sum_j wrank[t, j] * eo[rows[t, j]]``.
    A pure gather per token — no scatter, no cross-token accumulation."""
    T, max_k = rows.shape
    D = eo.shape[-1]
    bt = min(block_t, T)
    while T % bt:
        bt -= 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T // bt,),
        in_specs=[
            pl.BlockSpec((bt, max_k), lambda i, r: (i, 0)),
            pl.BlockSpec(eo.shape, lambda i, r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda i, r: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_combine_kernel, block_t=bt, max_k=max_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, D), eo.dtype),
        interpret=interpret,
    )(rows.reshape(-1), wrank, eo)
