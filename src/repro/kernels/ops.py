"""Jit'd dispatch wrappers over the Pallas kernels.

``interpret`` defaults to True unless running on real TPU hardware — the
kernels are the TPU *target*; this container validates them on CPU via the
Pallas interpreter.  Every op has a pure-jnp oracle in ``ref.py``; the
``use_kernel=False`` path routes to the oracle so higher layers can switch
implementations with one flag (and the dry-run lowers the jnp path, which
GSPMD shards).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash_pallas
from .lora_matmul import lora_matmul as _lora_pallas
from .lora_matmul import lora_matmul_experts as _lora_experts_pallas
from .topk_router import topk_router as _router_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: int = 0,
              use_kernel: bool = True, interpret=None):
    """q: (B,H,S,D); k,v: (B,KV,S,D)."""
    if not use_kernel:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    interpret = default_interpret() if interpret is None else interpret
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         interpret=interpret)


# --------------------------------------------------------------------------
# fused LoRA matmul
# --------------------------------------------------------------------------

def lora_matmul(x, w, a, b, *, scale: float = 1.0, use_kernel: bool = True,
                interpret=None):
    if not use_kernel:
        return ref.lora_matmul_ref(x, w, a, b, scale)
    interpret = default_interpret() if interpret is None else interpret
    return _lora_pallas(x, w, a, b, scale=scale, interpret=interpret)


def lora_matmul_experts(x, w, a, b, *, scale: float = 1.0,
                        use_kernel: bool = True, interpret=None):
    if not use_kernel:
        return ref.lora_matmul_experts_ref(x, w, a, b, scale)
    interpret = default_interpret() if interpret is None else interpret
    return _lora_experts_pallas(x, w, a, b, scale=scale, interpret=interpret)


# --------------------------------------------------------------------------
# top-k router
# --------------------------------------------------------------------------

def router(logits, k: int, *, use_kernel: bool = True, interpret=None):
    """Returns (weights, mask, counts) — see ref.topk_router_ref."""
    if not use_kernel:
        return ref.topk_router_ref(logits, k)
    interpret = default_interpret() if interpret is None else interpret
    return _router_pallas(logits, k, interpret=interpret)
