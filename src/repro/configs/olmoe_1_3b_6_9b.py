"""OLMoE-1.3B/6.9B [moe] — the paper's SMoE evaluation model.
16L d_model=2048 16H, 64 experts top-8, d_expert=1024, vocab=50304, qk-norm.
[arXiv:2409.02060]

FLAME's budgets on this model: constant LoRA rank r=20 with
k ∈ {8, 4, 2, 1} for β1–β4 (Appendix A1.2)."""
from .base import LoRAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="olmoe-1.3b-6.9b",
    family="moe",
    num_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=50_304,
    qk_norm=True,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024),
    lora=LoRAConfig(rank=20),
    source="arXiv:2409.02060",
)

# reduced same-family variant used by the quality experiments (Tables 2-5,
# Figures 2-4 reproduced directionally on CPU) and the smoke tests
SMOKE = FULL.replace(
    name="olmoe-smoke",
    num_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=64),
    lora=LoRAConfig(rank=4),
)

# a slightly larger reduced config for the federated quality benchmarks:
# 8 experts gives routing room for the activation-imbalance phenomenon
BENCH = FULL.replace(
    name="olmoe-bench",
    num_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    vocab_size=512,
    moe=MoEConfig(num_experts=8, top_k=4, d_expert=64),
    lora=LoRAConfig(rank=8),
)

SWA_WINDOW = 8192
