"""Roofline analysis from the compiled dry-run artifact.

Three terms, each in seconds on the target TPU v5e pod:

  compute    = HLO_FLOPs        / (chips · 197e12 FLOP/s bf16)
  memory     = HLO_bytes        / (chips · 819e9  B/s HBM)
  collective = collective_bytes / (chips · 50e9   B/s ICI per link)

CALIBRATION (measured, see EXPERIMENTS.md §Dry-run): after GSPMD
partitioning, ``cost_analysis()`` reports **per-device** FLOPs/bytes and the
optimized-HLO shapes are per-device shards.  The ``/chips`` in the formulas
above is therefore already applied — the code divides per-device quantities
by single-chip rates.  MODEL_FLOPS stays global, so the useful-compute ratio
is ``model_flops / (hlo_flops · chips)``.

``cost_analysis()`` provides HLO_FLOPs and bytes-accessed.  Collective bytes
are NOT in cost_analysis — we parse the optimized HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (ICI hop-count folded into the single-link bandwidth
model; cross-pod ops are charged at DCN bandwidth).

MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (per forward) — the
"useful" compute; HLO_FLOPs / MODEL_FLOPS exposes remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

# ---- TPU v5e hardware constants (per chip) ----
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
DCN_BW = 6.25e9              # bytes/s cross-pod (50 Gbit)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one HLO shape string like 'bf16[128,4096]{1,0}' or a tuple."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in the optimized HLO.

    Output shape ≈ the data volume crossing the interconnect per op (for
    all-reduce it is one round in/out — ring all-reduce moves 2·(n-1)/n ≈ 2×
    the buffer; we fold that factor into the per-kind multiplier)."""
    by_bytes: Dict[str, int] = {}
    by_count: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        mult = 2.0 if kind == "all-reduce" else 1.0
        by_bytes[kind] = by_bytes.get(kind, 0) + int(b * mult)
        by_count[kind] = by_count.get(kind, 0) + 1
    return CollectiveStats(by_bytes, by_count)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    bytes_per_device: float      # peak HBM from memory_analysis
    collectives: Dict[str, int]
    meta: Dict[str, Any]

    # ---- the three terms (seconds); hlo_* are PER-DEVICE quantities ----
    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs · chips) — useful share of compiled
        compute (catches remat / redundancy waste)."""
        return self.model_flops / max(self.hlo_flops * self.chips, 1.0)

    @property
    def step_time(self) -> float:
        """Roofline-model step time: max of the three terms (assumes perfect
        overlap of the other two)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model FLOPs utilisation at the roofline-model step time."""
        return (self.model_flops /
                (self.step_time * self.chips * PEAK_FLOPS)
                if self.step_time else 0.0)

    def row(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_gflops": self.hlo_flops / 1e9,
            "hlo_gbytes": self.hlo_bytes / 1e9,
            "coll_gbytes": self.collective_bytes / 1e9,
            "model_gflops": self.model_flops / 1e9,
            "t_compute_ms": self.t_compute * 1e3,
            "t_memory_ms": self.t_memory * 1e3,
            "t_collective_ms": self.t_collective * 1e3,
            "bottleneck": self.bottleneck,
            "useful_frac": self.useful_fraction,
            "mfu": self.mfu,
            "hbm_gb_per_device": self.bytes_per_device / 2 ** 30,
            **{f"n_{k}": v for k, v in self.collectives.items()},
        }


def extract(compiled, hlo_text: str, *, arch: str, shape: str,
            mesh_name: str, chips: int, model_flops: float,
            device_flops: float, device_bytes: float,
            meta: Optional[Dict[str, Any]] = None) -> Roofline:
    """Build a Roofline record.

    compute/memory terms use the ANALYTIC per-device models
    (launch/analytic.py — cost_analysis() counts scan bodies once and is
    useless at depth; its raw numbers are kept in meta for reference);
    the collective term uses trip-count-weighted HLO parsing
    (launch/hlo_parse.py)."""
    from . import hlo_parse
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    mem = compiled.memory_analysis()
    per_dev = float(
        getattr(mem, "temp_size_in_bytes", 0) +
        getattr(mem, "argument_size_in_bytes", 0) +
        getattr(mem, "output_size_in_bytes", 0) -
        getattr(mem, "alias_size_in_bytes", 0))
    coll_bytes, coll_execs = hlo_parse.collective_bytes_weighted(hlo_text)
    meta = dict(meta or {})
    meta["hlo_flops_body_once"] = float(cost.get("flops", 0.0))
    meta["hlo_bytes_body_once"] = float(cost.get("bytes accessed", 0.0))
    meta["collective_bytes_by_kind"] = coll_bytes
    meta["collective_execs_by_kind"] = coll_execs
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=device_flops, hlo_bytes=device_bytes,
        collective_bytes=float(sum(coll_bytes.values())),
        model_flops=model_flops, bytes_per_device=per_dev,
        collectives={k: int(v) for k, v in coll_execs.items()},
        meta=meta)
