"""Kernel-backend parity: ``backend="pallas", interpret=True`` must match
``backend="reference"`` through the full model — forward losses, gradients,
activation counts, and a whole federated ``cohort_update`` training step
(ISSUE 2 acceptance: rtol 1e-3 bf16 / 1e-5 fp32).

The pallas ops are ``jax.custom_vjp``-wrapped (Pallas has no autodiff rule),
so gradient parity here is what certifies the hand-written backward formulas
in ``repro.kernels.backend``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_moe
from repro.configs.base import KernelConfig, TrainConfig
from repro.core import lora as lora_lib
from repro.federated import client as client_lib
from repro.kernels import backend as kb
from repro.kernels import ref
from repro.models import model as model_lib

REFERENCE = KernelConfig(backend="reference")
PALLAS = KernelConfig(backend="pallas", interpret=True)


def _tol(dtype):
    # bf16 atol = one bf16 ulp at unit scale: primal activations round to
    # bf16 at the same program points on both backends, but fp32 summation
    # -order differences occasionally flip a rounding boundary, leaving
    # few-ulp noise on downstream gradients.  rtol follows the ISSUE 2
    # acceptance spec (1e-3 bf16 / 1e-5 fp32).
    return dict(rtol=1e-3, atol=4e-3) if dtype == "bfloat16" else \
        dict(rtol=1e-5, atol=1e-5)


def _assert_trees_close(a, b, **tol):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for (path, x), y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   err_msg=str(path), **tol)


def _loss_and_grad(cfg, params, trainable, tokens, labels, mask, k):
    def f(tr):
        return model_lib.lm_loss(cfg, params, tokens, labels, mask,
                                 trainable=tr, k=k)

    return jax.value_and_grad(f, has_aux=True)(trainable)


def _setup(cfg, seed=0, batch=2, seq=16):
    key = jax.random.PRNGKey(seed)
    params = model_lib.init_params(key, cfg)
    lora = lora_lib.init_lora(jax.random.fold_in(key, 1), cfg, params)
    resc = lora_lib.init_rescalers(cfg, 1) if cfg.moe.enabled else None
    trainable = lora_lib.make_trainable(lora, resc)
    tokens = jax.random.randint(jax.random.fold_in(key, 2), (batch, seq),
                                0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((batch, seq), jnp.float32)
    return params, trainable, tokens, labels, mask


# ---------------------------------------------------------------- resolution

def test_auto_backend_resolves_to_reference_off_tpu():
    assert kb.resolve(KernelConfig()) == "reference"
    assert kb.resolve(None) == "reference"
    assert kb.resolve(PALLAS) == "pallas"
    assert kb.resolve_interpret(KernelConfig(backend="pallas")) is True


# ---------------------------------------------------------- op-level parity

@pytest.mark.parametrize("rank", [2, 8, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_backend_parity_ranks_dtypes(rank, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 96), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (96, 80), dtype)
    a = jax.random.normal(jax.random.fold_in(key, 2), (96, rank), dtype) * .1
    b = jax.random.normal(jax.random.fold_in(key, 3), (rank, 80), dtype) * .1

    def run(kcfg):
        def f(x, w, a, b):
            return kb.lora_matmul(kcfg, x, w, a, b, scale=0.5).astype(
                jnp.float32).sum()
        val, grads = jax.value_and_grad(f, argnums=(0, 1, 2, 3))(x, w, a, b)
        return val, grads

    v_ref, g_ref = run(REFERENCE)
    v_pal, g_pal = run(PALLAS)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(v_ref), float(v_pal), rtol=1e-3)
    for gr, gp in zip(g_ref, g_pal):
        np.testing.assert_allclose(np.asarray(gr, np.float32),
                                   np.asarray(gp, np.float32), **tol)


def test_flash_attention_backend_grad_parity():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 2, 16))
    from repro.models.attention import flash_attention_jnp

    def f_pal(q, k, v):
        return kb.flash_attention(PALLAS, q, k, v).sum()

    def f_ref(q, k, v):
        return flash_attention_jnp(q, k, v, causal=True).sum()

    g_pal = jax.grad(f_pal, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_pal, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_router_backend_parity_and_grads():
    logits = jax.random.normal(jax.random.PRNGKey(2), (128, 8))

    def wsum(kcfg):
        return lambda l: kb.router(kcfg, l, 2)[0].sum()

    for i in range(3):
        np.testing.assert_allclose(
            np.asarray(kb.router(PALLAS, logits, 2)[i]),
            np.asarray(kb.router(REFERENCE, logits, 2)[i]),
            rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(jax.grad(wsum(PALLAS))(logits)),
                               np.asarray(jax.grad(wsum(REFERENCE))(logits)),
                               rtol=1e-5, atol=1e-6)


def test_degenerate_block_shapes_fall_back_to_reference():
    """Prime dims above the block target would give near-1-wide Pallas
    grids — the dispatch layer must fall back to the reference instead."""
    assert not kb.flash_blocks_ok(509)       # prime > 128
    assert kb.flash_blocks_ok(512)
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (509, 64))    # M prime > 256 -> fallback
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 64))
    a = jax.random.normal(jax.random.fold_in(key, 2), (64, 4))
    b = jax.random.normal(jax.random.fold_in(key, 3), (4, 64))
    np.testing.assert_array_equal(
        np.asarray(kb.lora_matmul(PALLAS, x, w, a, b, scale=0.5)),
        np.asarray(kb.lora_matmul(REFERENCE, x, w, a, b, scale=0.5)))


# ------------------------------------------------------- model-level parity

@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("rank", [2, 8])
def test_moe_model_loss_and_grad_parity(dtype, rank):
    """Budget-restricted k_i=1 < top_k=2 on the tiny MoE, across dtypes and
    LoRA ranks: losses, grads and activation counts must agree."""
    import repro.configs.base as cb
    cfg = tiny_moe(dtype=dtype, lora=cb.LoRAConfig(rank=rank))
    params, trainable, tokens, labels, mask = _setup(cfg)
    (l_ref, c_ref), g_ref = _loss_and_grad(
        cfg.replace(kernels=REFERENCE), params, trainable, tokens, labels,
        mask, k=1)
    (l_pal, c_pal), g_pal = _loss_and_grad(
        cfg.replace(kernels=PALLAS), params, trainable, tokens, labels,
        mask, k=1)
    tol = _tol(dtype)
    np.testing.assert_allclose(float(l_ref), float(l_pal),
                               rtol=tol["rtol"])
    _assert_trees_close(g_ref, g_pal, **tol)
    for pos in c_ref:
        np.testing.assert_allclose(np.asarray(c_ref[pos]),
                                   np.asarray(c_pal[pos]))


def test_dense_model_parity_uses_attention_kernel():
    """The dense family exercises the flash-attention dispatch (no MoE)."""
    cfg = tiny_dense()
    params, trainable, tokens, labels, mask = _setup(cfg)
    (l_ref, _), g_ref = _loss_and_grad(
        cfg.replace(kernels=REFERENCE), params, trainable, tokens, labels,
        mask, k=None)
    (l_pal, _), g_pal = _loss_and_grad(
        cfg.replace(kernels=PALLAS), params, trainable, tokens, labels,
        mask, k=None)
    np.testing.assert_allclose(float(l_ref), float(l_pal), rtol=1e-5)
    _assert_trees_close(g_ref, g_pal, rtol=1e-5, atol=1e-5)


def test_softcap_models_fall_back_to_jnp_path():
    """attn_logit_softcap > 0 must route to the blockwise jnp path even on
    the pallas backend (the kernel has no softcap) — outputs identical."""
    cfg = tiny_dense(attn_logit_softcap=30.0)
    params, trainable, tokens, labels, mask = _setup(cfg)
    (l_ref, _), _ = _loss_and_grad(cfg.replace(kernels=REFERENCE), params,
                                   trainable, tokens, labels, mask, k=None)
    (l_pal, _), _ = _loss_and_grad(cfg.replace(kernels=PALLAS), params,
                                   trainable, tokens, labels, mask, k=None)
    assert float(l_ref) == float(l_pal)


# ------------------------------------------- cohort training step (the CI
# acceptance contract: a full federated training step, both backends)

@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_cohort_update_full_step_parity(dtype):
    cfg = tiny_moe(dtype=dtype)
    tc = TrainConfig(batch_size=2, local_epochs=1, seq_len=16)
    key = jax.random.PRNGKey(3)
    params = model_lib.init_params(key, cfg)
    lora = lora_lib.init_lora(jax.random.fold_in(key, 1), cfg, params)

    # two clients, shared shapes (one cohort), budget k_i=1 < top_k=2
    n_ex, seq = 6, 16
    trainables, plans = [], []
    from repro.data.synthetic import Corpus
    for cid in range(2):
        ck = jax.random.fold_in(key, 10 + cid)
        toks = np.asarray(jax.random.randint(ck, (n_ex, seq), 0,
                                             cfg.vocab_size), np.int32)
        shard = Corpus(tokens=toks, labels=np.roll(toks, -1, 1),
                       mask=np.ones((n_ex, seq), np.float32),
                       clusters=np.zeros((n_ex,), np.int32))
        client = client_lib.ClientState(client_id=cid, shard=shard, k=1,
                                        rank=cfg.lora.rank,
                                        rescaler=lora_lib.init_rescalers(
                                            cfg, 1))
        trainables.append(lora_lib.make_trainable(lora, client.rescaler))
        plans.append(client_lib.make_batch_plan(client, tc, round_seed=5))

    stacked_tr = lora_lib.stack_adapters(trainables)
    plan = client_lib.stack_plans(plans)
    args = (jnp.asarray(plan.tokens), jnp.asarray(plan.labels),
            jnp.asarray(plan.mask), jnp.asarray(plan.valid))

    def run(kcfg):
        return client_lib.cohort_update(
            cfg.replace(kernels=kcfg), params, stacked_tr, *args, k=1,
            tc=tc, rescaler_trainable=True)

    tr_ref, counts_ref, tok_ref, loss_ref, n_ref = run(REFERENCE)
    tr_pal, counts_pal, tok_pal, loss_pal, n_pal = run(PALLAS)

    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(loss_ref), np.asarray(loss_pal),
                               rtol=tol["rtol"], atol=tol["atol"])
    _assert_trees_close(tr_ref, tr_pal, **tol)
    _assert_trees_close(counts_ref, counts_pal, **tol)
    np.testing.assert_allclose(np.asarray(tok_ref), np.asarray(tok_pal))
    np.testing.assert_allclose(np.asarray(n_ref), np.asarray(n_pal))

    # and per-step gradients of the same cohort loss agree (the "gradients"
    # half of the acceptance criterion, at the cohort level)
    def cohort_loss(kcfg):
        def f(tr):
            c2 = cfg.replace(kernels=kcfg)

            def one(tr1, tok, lab, msk):
                loss, _ = model_lib.lm_loss(c2, params, tok, lab, msk,
                                            trainable=tr1, k=1)
                return loss

            return jax.vmap(one)(tr, args[0][:, 0], args[1][:, 0],
                                 args[2][:, 0]).sum()

        return f

    g_ref = jax.grad(cohort_loss(REFERENCE))(stacked_tr)
    g_pal = jax.grad(cohort_loss(PALLAS))(stacked_tr)
    _assert_trees_close(g_ref, g_pal, **tol)
