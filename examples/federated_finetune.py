"""End-to-end federated fine-tuning driver (the deliverable-b e2e example).

Runs the full FLAME pipeline at ~100M scale for a configurable number of
rounds with per-round checkpointing, resumability, and a final method
comparison.  On CPU this is sized to finish in minutes; pass ``--large``
for the ~100M-parameter model (recommended on real hardware).

  PYTHONPATH=src python examples/federated_finetune.py \
      --rounds 3 --clients 4 --alpha 0.5 --method flame --out runs/flame

Resume after an interruption:

  PYTHONPATH=src python examples/federated_finetune.py --resume runs/flame
"""
import argparse
import os
import time

import numpy as np

from repro.configs.base import (FederatedConfig, LoRAConfig, MoEConfig,
                                TrainConfig)
from repro.configs.registry import get_config
from repro.data.synthetic import DataConfig
from repro.federated.client import evaluate
from repro.federated.simulation import build_experiment


def model_for(large: bool):
    cfg = get_config("olmoe-1.3b-6.9b", "full")
    if large:
        # ~100M-class OLMoE-family config (8 layers, d=512, 16 experts)
        return cfg.replace(
            name="olmoe-100m", num_layers=8, d_model=512, n_heads=8,
            n_kv_heads=8, head_dim=64, vocab_size=8192,
            moe=MoEConfig(num_experts=16, top_k=4, d_expert=512),
            lora=LoRAConfig(rank=8))
    return cfg.replace(
        name="olmoe-mini", num_layers=4, d_model=256, n_heads=4,
        n_kv_heads=4, head_dim=64, vocab_size=2048,
        moe=MoEConfig(num_experts=8, top_k=4, d_expert=256),
        lora=LoRAConfig(rank=8))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--method", default="flame",
                    choices=["flame", "trivial", "hlora", "flexlora"])
    ap.add_argument("--temperature", type=int, default=2)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--large", action="store_true")
    ap.add_argument("--out", default="runs/flame")
    ap.add_argument("--resume", default=None)
    args = ap.parse_args()

    out = args.resume or args.out
    os.makedirs(out, exist_ok=True)

    cfg = model_for(args.large)
    fed = FederatedConfig(
        num_clients=args.clients, rounds=args.rounds,
        participation=args.participation, dirichlet_alpha=args.alpha,
        temperature=args.temperature, method=args.method, seed=0)
    tc = TrainConfig(batch_size=8 if not args.large else 16, local_epochs=1)
    data = DataConfig(vocab_size=cfg.vocab_size,
                      n_examples=512 if args.large else 256,
                      seq_len=128 if args.large else 64, n_clusters=8)

    exp = build_experiment(cfg, fed=fed, tc=tc, data=data)

    start_round = 0
    state_path = os.path.join(out, "state.npz")
    if args.resume and os.path.exists(state_path):
        # server-side resume: restores the global LoRA, every client's
        # local rescaler s_i, and replays the participant-sampling RNG so
        # the continued run matches an uninterrupted one exactly
        start_round = exp.server.restore_checkpoint(state_path)
        print(f"resumed at round {start_round} from {state_path}")

    init = evaluate(cfg, exp.server.params, None, exp.val,
                    k=cfg.moe.top_k or 1)
    print(f"[{cfg.name}] {args.method} | clients={args.clients} "
          f"alpha={args.alpha} | init val loss {init:.4f}")

    for r in range(start_round, args.rounds):
        t0 = time.time()
        res = exp.server.run_round(r)
        val = evaluate(cfg, exp.server.params,
                       {"lora": exp.server.global_lora}, exp.val,
                       k=cfg.moe.top_k or 1)
        print(f"round {r}: mean client loss "
              f"{np.mean(res.client_losses):.4f} | global val {val:.4f} | "
              f"clients {res.participating} | {time.time() - t0:.1f}s")
        exp.server.save_checkpoint(state_path)

    test = evaluate(cfg, exp.server.params,
                    {"lora": exp.server.global_lora}, exp.test,
                    k=cfg.moe.top_k or 1)
    print(f"final test loss {test:.4f} | score {100 * np.exp(-test):.2f} | "
          f"state: {state_path}")


if __name__ == "__main__":
    main()
