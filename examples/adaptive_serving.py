"""Adaptive-activation serving (FLAME's deployment-efficiency claim).

A model fine-tuned under reduced expert activation can be SERVED with
reduced activation — and the serving engine makes the trade-off per
REQUEST TIER, not per deployment: after federated fine-tuning the merged
model is loaded into one `repro.serving.ServingEngine` whose KV-cache
slots are split between a premium tier (full top_k) and constrained tiers
(k=1–2), all decoding in the same compiled mixed-k step.

Quality is measured through the engine itself: each held-out prompt is
submitted as a teacher-forced request (`Request.forced`), so the reported
per-tier NLL is the NLL of the exact tokens the serving path scores.

  PYTHONPATH=src python examples/adaptive_serving.py --new-tokens 16
"""
import argparse

import numpy as np

from repro.configs.base import FederatedConfig, TrainConfig
from repro.core import flops as F
from repro.core import lora as lora_lib
from repro.data.synthetic import DataConfig
from repro.federated.simulation import build_experiment
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=1)
    args = ap.parse_args()

    from repro.configs.olmoe_1_3b_6_9b import BENCH as cfg
    fed = FederatedConfig(num_clients=2, rounds=args.rounds, method="flame")
    tc = TrainConfig(batch_size=8)
    data = DataConfig(vocab_size=cfg.vocab_size, n_examples=128, seq_len=64)
    exp = build_experiment(cfg, fed=fed, tc=tc, data=data)
    exp.server.run()

    # deployment: merge LoRA into the base weights (zero serving overhead)
    params = lora_lib.merge_into_params(exp.server.params,
                                        exp.server.global_lora,
                                        cfg.lora.scale)

    prompts = np.asarray(exp.test.tokens[:args.batch, :32], np.int32)
    golds = np.asarray(exp.test.tokens[:args.batch,
                                       32:32 + args.new_tokens], np.int32)

    tiers = sorted({cfg.moe.top_k, max(cfg.moe.top_k // 2, 1), 1},
                   reverse=True)
    print(f"serving {cfg.name}: {cfg.moe.num_experts} experts, "
          f"trained top-{cfg.moe.top_k}; engine = "
          f"{args.batch * len(tiers)} slots over tiers k={tiers}, "
          f"prefill 32 + decode {args.new_tokens}\n")

    # one engine, one compiled mixed-k decode step: `args.batch` slots per
    # tier, every tier decoding the SAME prompts teacher-forced on the gold
    # continuation so the per-tier NLLs are directly comparable
    slot_k = tuple(k for k in tiers for _ in range(args.batch))
    engine = ServingEngine(cfg, params, num_slots=len(slot_k),
                           slot_len=32 + args.new_tokens, slot_k=slot_k)
    requests = [
        Request(rid=t * args.batch + b, prompt=prompts[b],
                max_new_tokens=args.new_tokens, k=k, forced=golds[b])
        for t, k in enumerate(tiers) for b in range(args.batch)
    ]
    report = engine.run(requests)

    print("k,active_params_M,decode_GFLOPs_per_tok,nll,latency_p50_ms")
    by_rid = {c.rid: c for c in report.completions}
    for t, k in enumerate(tiers):
        comps = [by_rid[t * args.batch + b] for b in range(args.batch)]
        nll = float(np.mean([c.nll_sum / c.n_generated for c in comps]))
        lat = float(np.median([c.latency for c in comps])) * 1e3
        p_act = F.count_params(cfg, k=k)["active"] / 1e6
        gflops = F.flops_paper_convention(cfg, tokens=1, k=k) / 1e9
        print(f"{k},{p_act:.1f},{gflops:.3f},{nll:.4f},{lat:.1f}")

    s = report.summary()
    print(f"\nengine: {s['decode_steps']} mixed-k decode steps, "
          f"{s['gen_tokens_per_s']:.1f} tok/s, "
          f"TTFT p95 {s['ttft_p95_ms']:.1f} ms")
    print("lower k => proportionally fewer active params/FLOPs per token "
          "with modest quality cost — the paper's Table 1 economics, "
          "per request tier in one serving batch.")


if __name__ == "__main__":
    main()
