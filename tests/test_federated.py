"""End-to-end federated integration tests: all four methods, sampling,
rescaler modes, checkpoint round-trip of federated state."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import io as ckpt
from repro.configs.base import FederatedConfig, TrainConfig
from repro.configs.registry import get_config
from repro.data.synthetic import DataConfig
from repro.federated.simulation import build_experiment, run_experiment

CFG = get_config("olmoe-1.3b-6.9b", "smoke")
DENSE = get_config("olmo-1.3b", "smoke")
TC = TrainConfig(batch_size=8, local_epochs=1)
DATA = DataConfig(vocab_size=CFG.vocab_size, n_examples=96, seq_len=64,
                  n_clusters=4)


def _run(method, cfg=CFG, rescaler="learnable", participation=1.0,
         rounds=1, clients=2):
    fed = FederatedConfig(num_clients=clients, rounds=rounds, method=method,
                          rescaler=rescaler if cfg.moe.enabled else "none",
                          participation=participation, temperature=2)
    exp = build_experiment(cfg, fed=fed, tc=TC, data=DATA)
    res = run_experiment(exp)
    return exp, res


@pytest.mark.parametrize("method", ["flame", "trivial", "hlora", "flexlora"])
def test_method_end_to_end(method):
    exp, res = _run(method)
    assert np.isfinite(res["val_loss"]) and np.isfinite(res["test_loss"])
    assert res["rounds"] == 1
    for leaf in jax.tree.leaves(exp.server.global_lora):
        assert not bool(np.isnan(np.asarray(leaf)).any())


def test_flame_on_dense_model_degenerates_to_fedavg_lora():
    _, res = _run("flame", cfg=DENSE)
    assert np.isfinite(res["test_loss"])


def test_client_sampling_participation():
    exp, _ = _run("flame", participation=0.5, clients=4)
    assert all(len(r.participating) == 2 for r in exp.server.history)


def test_activation_frequencies_recorded_per_round():
    exp, _ = _run("flame")
    freqs = exp.server.history[0].client_freqs
    assert len(freqs) == 2
    for f in freqs:
        for pos, arr in f.items():
            arr = np.asarray(arr)
            assert arr.shape[-1] == CFG.moe.num_experts
            assert (arr >= 0).all() and (arr <= 1.0 + 1e-6).all()


def test_flame_client_budgets_differ():
    """Uniform β assignment gives clients different k_i (FLAME) and the
    rank grid to the baselines."""
    fed = FederatedConfig(num_clients=4, rounds=1, method="flame")
    exp = build_experiment(CFG, fed=fed, tc=TC, data=DATA)
    ks = [c.k for c in exp.server.clients]
    assert len(set(ks)) > 1 and max(ks) <= CFG.moe.top_k

    fed2 = FederatedConfig(num_clients=4, rounds=1, method="hlora")
    exp2 = build_experiment(CFG, fed=fed2, tc=TC, data=DATA)
    ranks = [c.rank for c in exp2.server.clients]
    assert len(set(ranks)) > 1 and max(ranks) <= CFG.lora.rank


def test_training_reduces_loss_over_rounds():
    """Two FLAME rounds on the learnable synthetic corpus move val loss
    down versus the fresh-init model.  Uses the LoRA-scale lr appropriate
    for the 2-layer smoke model (at the paper's 1.5e-4 the margin is
    < 0.002 nats — below init-seed noise; see benchmarks/common.py)."""
    fed = FederatedConfig(num_clients=2, rounds=2, method="flame",
                          temperature=2)
    tc = dataclasses.replace(TC, learning_rate=1e-2)
    exp = build_experiment(CFG, fed=fed, tc=tc, data=DATA)
    from repro.federated.client import evaluate
    init_loss = evaluate(CFG, exp.server.params, None, exp.val,
                         k=CFG.moe.top_k)
    res = run_experiment(exp)
    assert res["val_loss"] < init_loss, (res, init_loss)


def test_round_resume_matches_straight_run(tmp_path):
    """run(checkpoint_to=...) + a fresh server's run(resume_from=...) must
    reproduce a straight multi-round run exactly: global LoRA, client
    rescalers, and (via the replayed sampling RNG) cohort selection."""
    path = str(tmp_path / "fed.npz")

    def fresh():
        fed = FederatedConfig(num_clients=4, rounds=2, method="flame",
                              participation=0.5, temperature=2)
        return build_experiment(CFG, fed=fed, tc=TC, data=DATA)

    straight = fresh()
    straight.server.run()

    first = fresh()
    first.server.fed = dataclasses.replace(first.server.fed, rounds=1)
    first.server.run(checkpoint_to=path)

    resumed = fresh()
    resumed.server.run(resume_from=path)
    assert len(resumed.server.history) == 1          # only round 1 re-ran
    assert resumed.server.history[0].round_idx == 1
    assert (resumed.server.history[0].participating
            == straight.server.history[1].participating)
    for a, b in zip(jax.tree.leaves(straight.server.global_lora),
                    jax.tree.leaves(resumed.server.global_lora)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    for ca, cb in zip(straight.server.clients, resumed.server.clients):
        if ca.rescaler is None:
            assert cb.rescaler is None
            continue
        for a, b in zip(jax.tree.leaves(ca.rescaler),
                        jax.tree.leaves(cb.rescaler)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)


def test_resume_round_idx_survives_rechckpoint(tmp_path):
    """A checkpoint written AFTER a resume records the true round count."""
    from repro.checkpoint import io as ckpt_io
    path = str(tmp_path / "fed.npz")
    fed = FederatedConfig(num_clients=2, rounds=2, method="flame")
    exp = build_experiment(CFG, fed=fed, tc=TC, data=DATA)
    exp.server.fed = dataclasses.replace(fed, rounds=1)
    exp.server.run(checkpoint_to=path)

    exp2 = build_experiment(CFG, fed=fed, tc=TC, data=DATA)
    exp2.server.run(resume_from=path, checkpoint_to=path)
    _, meta = ckpt_io.load(path)
    assert meta["round_idx"] == 2


def test_federated_state_checkpoint_roundtrip(tmp_path):
    exp, _ = _run("flame")
    path = str(tmp_path / "state.npz")
    ckpt.save(path, {"lora": exp.server.global_lora,
                     "rescalers": [c.rescaler for c in exp.server.clients]},
              meta={"round": 1})
    tree, meta = ckpt.load(path)
    assert meta["round"] == 1
    for a, b in zip(jax.tree.leaves(tree["lora"]),
                    jax.tree.leaves(exp.server.global_lora)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
