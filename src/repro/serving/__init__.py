"""Adaptive-k serving: continuous batching over a paged KV cache.

The subsystem has four layers (docs/architecture.md §Serving):

* :mod:`repro.serving.kv_cache`  — ``BlockPool``: the block-paged KV pool
  (global fixed-size KV blocks, per-request block tables, on-demand
  allocation, reservation-backed admission math, refcounted prefix
  caching with copy-on-write, swap-out/swap-in for preemption) and
  ``SlotPool``, the legacy monolithic slotted pool kept as the
  differential-test oracle;
* :mod:`repro.serving.scheduler` — ``Request``/``Scheduler``: FIFO or
  earliest-deadline-first (per-tier TTFT SLO) queue with tier-aware
  admission into free slots, plus an optional can-admit resource
  predicate (projected block need) with per-tier head-of-line fairness;
* :mod:`repro.serving.engine`    — ``ServingEngine``: the continuous-
  batching loop; one jitted decode step over the whole slot batch with
  **per-slot expert budget k** (FLAME's adaptive-k at serving time), the
  rescaler applied per slot, and SLO-driven decode preemption;
* :mod:`repro.serving.workload`  — synthetic open-loop arrival traces
  (Poisson/diurnal/burst arrivals, heavy-tail lengths, shared prompt
  prefixes, tier mixes) and latency percentile helpers;
* :mod:`repro.serving.sampler`   — pure logits -> token sampling
  (greedy / temperature / top-p) with explicit PRNG threading;
* :mod:`repro.serving.speculative` — self-speculative decoding: draft at
  k=1, verify in one full-k multi-token step, accept via the standard
  rejection rule, roll rejected K/V back (``BlockPool.truncate_to``).
"""
from .engine import ServingEngine, ServingReport  # noqa: F401
from .kv_cache import BlockPool, SlotPool  # noqa: F401
from .sampler import SamplerConfig  # noqa: F401
from .scheduler import Completion, Request, Scheduler  # noqa: F401
from .speculative import SpeculativeConfig  # noqa: F401
from .workload import WorkloadConfig, make_trace, percentile  # noqa: F401
