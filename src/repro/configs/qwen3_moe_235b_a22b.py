"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B family card]

The FLAME-representative architecture: per-expert LoRA + adaptive k_i."""
from .base import LoRAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,                      # FFN is pure MoE
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536),
    lora=LoRAConfig(rank=16),
    source="hf:Qwen/Qwen3-30B-A3B",
)

SMOKE = FULL.replace(
    name="qwen3-moe-smoke",
    num_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=128),
    lora=LoRAConfig(rank=4),
)

SWA_WINDOW = 8192
