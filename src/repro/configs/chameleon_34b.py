"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536, early-fusion VQ image tokens.  [arXiv:2405.09818]

Early fusion means images enter as discrete VQ-VAE codes *inside the text
vocabulary*, so the backbone input is plain token ids — the VQ tokenizer is
the stubbed modality frontend (per the assignment's carve-out)."""
from .base import LoRAConfig, ModelConfig

FULL = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab_size=65_536,
    qk_norm=True,                # chameleon's training-stability fix
    rope_theta=10_000.0,
    lora=LoRAConfig(rank=16),
    source="arXiv:2405.09818",
)

SMOKE = FULL.replace(
    name="chameleon-smoke",
    num_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    lora=LoRAConfig(rank=4),
)

SWA_WINDOW = 8192
