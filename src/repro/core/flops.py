"""Analytic parameter / FLOPs accounting.

Reproduces the paper's Table 1 (the FLOPs-based limitation analysis of rank
compression vs FLAME's expert reduction) and supplies the
``MODEL_FLOPS = 6·N_active·D`` terms the roofline analysis needs.

Two conventions:
  * ``flops_paper_convention`` — 2 FLOPs per *active* parameter per token
    (the convention that reproduces the paper's 153.6/179.2/230.4/332.8 B
    grid exactly: 2 · P_a · T with T = 128);
  * ``flops_detailed``        — per-matmul accounting (incl. router, lm head,
    attention score/value matmuls, LoRA bypass) for honest roofline numbers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from ..configs.base import ModelConfig
from ..models.mamba2 import mamba_dims


# --------------------------------------------------------------------------
# parameter counting
# --------------------------------------------------------------------------

def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.head_dim_
    return (cfg.d_model * cfg.n_heads * hd          # wq
            + 2 * cfg.d_model * cfg.n_kv_heads * hd  # wk, wv
            + cfg.n_heads * hd * cfg.d_model)        # wo


def _ffn_params(cfg: ModelConfig) -> int:
    return 3 * cfg.d_model * cfg.d_ff if cfg.d_ff else 0


def _expert_params_each(cfg: ModelConfig) -> int:
    return 3 * cfg.d_model * cfg.moe.d_expert


def _shared_params(cfg: ModelConfig) -> int:
    m = cfg.moe
    if m.num_shared_experts <= 0:
        return 0
    dsh = m.d_shared_expert or m.d_expert * m.num_shared_experts
    return 3 * cfg.d_model * dsh


def _mamba_params(cfg: ModelConfig) -> int:
    d = mamba_dims(cfg)
    return (cfg.d_model * d["in_dim"] + d["conv_dim"] * d["conv_width"]
            + d["d_inner"] * cfg.d_model + 3 * d["n_heads"] + d["d_inner"])


def count_params(cfg: ModelConfig, k: Optional[int] = None) -> Dict[str, int]:
    """Total and active parameter counts; ``k`` = activated experts."""
    k = k if k is not None else cfg.moe.top_k
    embed = cfg.vocab_size * cfg.d_model * max(cfg.num_codebooks, 1)
    head = 0 if cfg.tie_embeddings else embed
    total = embed + head + cfg.d_model  # final norm
    active = total
    for layer in range(cfg.num_layers):
        kind = cfg.layer_kind(layer)
        mixer = _attn_params(cfg) if kind == "attn" else _mamba_params(cfg)
        total += mixer + cfg.d_model
        active += mixer + cfg.d_model
        if cfg.layer_is_moe(layer):
            router = cfg.d_model * cfg.moe.num_experts
            ep = _expert_params_each(cfg)
            sp = _shared_params(cfg)
            total += router + cfg.moe.num_experts * ep + sp + cfg.d_model
            active += router + k * ep + sp + cfg.d_model
        elif cfg.d_ff:
            total += _ffn_params(cfg) + cfg.d_model
            active += _ffn_params(cfg) + cfg.d_model
    return {"total": total, "active": active, "embed": embed + head}


# --------------------------------------------------------------------------
# LoRA parameter counting
# --------------------------------------------------------------------------

def lora_param_counts(cfg: ModelConfig, rank: Optional[int] = None,
                      k: Optional[int] = None) -> Dict[str, int]:
    """Trainable adapter params, total (P̂) and active (P̂_a)."""
    r = rank if rank is not None else cfg.lora.rank
    k = k if k is not None else cfg.moe.top_k
    hd = cfg.head_dim_
    total = active = 0
    for layer in range(cfg.num_layers):
        kind = cfg.layer_kind(layer)
        if kind == "attn" and cfg.lora.target_attn:
            per = (r * (cfg.d_model + cfg.n_heads * hd)            # wq
                   + 2 * r * (cfg.d_model + cfg.n_kv_heads * hd)   # wk, wv
                   + r * (cfg.n_heads * hd + cfg.d_model))         # wo
            total += per
            active += per
        if kind == "ssm" and cfg.lora.target_ssm:
            d = mamba_dims(cfg)
            per = (r * (cfg.d_model + d["in_dim"])
                   + r * (d["d_inner"] + cfg.d_model))
            total += per
            active += per
        if cfg.layer_is_moe(layer) and cfg.lora.target_expert:
            per_exp = (2 * r * (cfg.d_model + cfg.moe.d_expert)    # w1, w3
                       + r * (cfg.moe.d_expert + cfg.d_model))     # w2
            total += cfg.moe.num_experts * per_exp
            active += k * per_exp
        elif cfg.d_ff and cfg.lora.target_ffn and not cfg.layer_is_moe(layer):
            per = 3 * r * (cfg.d_model + cfg.d_ff)
            total += per
            active += per
    return {"total": total, "active": active}


# --------------------------------------------------------------------------
# FLOPs
# --------------------------------------------------------------------------

def flops_paper_convention(cfg: ModelConfig, tokens: int,
                           k: Optional[int] = None,
                           lora_rank: Optional[int] = None) -> float:
    """2 FLOPs per active param per token (paper's Table 1/2 convention)."""
    p = count_params(cfg, k=k)
    extra = 0
    if lora_rank:
        extra = lora_param_counts(cfg, rank=lora_rank, k=k)["active"]
    return 2.0 * (p["active"] + extra) * tokens


def flops_detailed(cfg: ModelConfig, tokens: int, seq_len: int,
                   k: Optional[int] = None,
                   lora_rank: Optional[int] = None,
                   backward: bool = False) -> float:
    """Per-matmul forward FLOPs; ``backward=True`` multiplies matmul terms by
    3 (standard fwd:bwd = 1:2 for LoRA-frozen base it is closer to 1+2·ρ with
    ρ the trainable fraction, but activations still backprop through the
    frozen base, so 3× is the honest count)."""
    k = k if k is not None else cfg.moe.top_k
    r = lora_rank if lora_rank is not None else cfg.lora.rank
    hd = cfg.head_dim_
    f = 0.0
    for layer in range(cfg.num_layers):
        kind = cfg.layer_kind(layer)
        if kind == "attn":
            f += 2.0 * tokens * _attn_params(cfg)
            # score + value matmuls (causal ~ S/2 average context)
            ctx = (cfg.attention_window if cfg.attention_window
                   else seq_len / 2.0)
            f += 2.0 * tokens * ctx * cfg.n_heads * hd * 2
            if r and cfg.lora.target_attn:
                f += 2.0 * tokens * (
                    r * (cfg.d_model + cfg.n_heads * hd)
                    + 2 * r * (cfg.d_model + cfg.n_kv_heads * hd)
                    + r * (cfg.n_heads * hd + cfg.d_model))
        else:
            d = mamba_dims(cfg)
            f += 2.0 * tokens * (cfg.d_model * d["in_dim"]
                                 + d["d_inner"] * cfg.d_model)
            f += 2.0 * tokens * d["conv_dim"] * d["conv_width"]
            # SSD: intra-chunk (L) + state update (N) per head-dim element
            L = min(cfg.ssm.chunk_size, seq_len)
            f += 2.0 * tokens * d["d_inner"] * (L + 2 * d["d_state"])
            if r and cfg.lora.target_ssm:
                f += 2.0 * tokens * (r * (cfg.d_model + d["in_dim"])
                                     + r * (d["d_inner"] + cfg.d_model))
        if cfg.layer_is_moe(layer):
            f += 2.0 * tokens * cfg.d_model * cfg.moe.num_experts   # router
            f += 2.0 * tokens * k * _expert_params_each(cfg)
            f += 2.0 * tokens * _shared_params(cfg)
            if r and cfg.lora.target_expert:
                f += (2.0 * tokens * k
                      * (2 * r * (cfg.d_model + cfg.moe.d_expert)
                         + r * (cfg.moe.d_expert + cfg.d_model)))
        elif cfg.d_ff:
            f += 2.0 * tokens * _ffn_params(cfg)
            if r and cfg.lora.target_ffn:
                f += 2.0 * tokens * 3 * r * (cfg.d_model + cfg.d_ff)
    f += 2.0 * tokens * cfg.d_model * cfg.vocab_size * max(cfg.num_codebooks, 1)
    return 3.0 * f if backward else f


def model_flops_roofline(cfg: ModelConfig, tokens: int,
                         kind: str = "train") -> float:
    """MODEL_FLOPS for the roofline table: 6·N_active·D for training,
    2·N_active·D for inference (per forward)."""
    n_active = count_params(cfg)["active"]
    per_tok = 6.0 if kind == "train" else 2.0
    return per_tok * n_active * tokens


# --------------------------------------------------------------------------
# Table 1 grid
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class BudgetRow:
    budget: str
    method: str
    rank: int
    k: int
    params_total: int
    params_active: int
    train_total: int
    train_active: int
    flops: float


def table1_grid(cfg_dense: ModelConfig, cfg_moe: ModelConfig,
                tokens: int = 128):
    """The paper's Table 1: β1–β4 for HLoRA/FlexLoRA (rank compression) on
    dense + MoE, and FLAME (expert reduction) on MoE."""
    rows = []
    dense_ranks = {"b1": 40, "b2": 24, "b3": 16, "b4": 12}
    moe_ranks = {"b1": 20, "b2": 12, "b3": 8, "b4": 6}
    flame_k = {"b1": 8, "b2": 4, "b3": 2, "b4": 1}

    for b, rk in dense_ranks.items():
        p = count_params(cfg_dense)
        l = lora_param_counts(cfg_dense, rank=rk)
        rows.append(BudgetRow(b, "rank-compress/dense", rk, 0,
                              p["total"], p["active"], l["total"], l["active"],
                              flops_paper_convention(cfg_dense, tokens,
                                                     lora_rank=rk)))
    for b, rk in moe_ranks.items():
        p = count_params(cfg_moe, k=cfg_moe.moe.top_k)
        l = lora_param_counts(cfg_moe, rank=rk)
        rows.append(BudgetRow(b, "rank-compress/moe", rk, cfg_moe.moe.top_k,
                              p["total"], p["active"], l["total"], l["active"],
                              flops_paper_convention(cfg_moe, tokens,
                                                     lora_rank=rk)))
    for b, kk in flame_k.items():
        p = count_params(cfg_moe, k=kk)
        l = lora_param_counts(cfg_moe, rank=20, k=kk)
        rows.append(BudgetRow(b, "flame", 20, kk,
                              p["total"], p["active"], l["total"], l["active"],
                              flops_paper_convention(cfg_moe, tokens, k=kk,
                                                     lora_rank=20)))
    return rows
