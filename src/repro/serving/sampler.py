"""Pure logits -> token sampling for the serving engine.

Every function here is a pure ``jnp`` map from (PRNG key, logits) to a
token — no host state, no implicit RNG — so samplers compose with
``jax.vmap`` across slots and with the speculative verify step, which
needs the *distribution* (:func:`sampler_probs`) and not just a draw.

``SamplerConfig`` is a frozen (hashable) dataclass so it can ride through
``jax.jit`` as a static argument.  Three kinds:

* ``greedy`` — argmax, expressed as a one-hot distribution so the
  speculative rejection rule degenerates to exact-match acceptance;
* ``temperature`` — softmax of ``logits / temperature``;
* ``top_p`` — nucleus sampling: temperature softmax, then the smallest
  prefix of probability-sorted tokens whose mass reaches ``top_p`` is
  kept and renormalised (ties broken by stable sort, so the nucleus is
  deterministic).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

KINDS = ("greedy", "temperature", "top_p")


@dataclass(frozen=True)
class SamplerConfig:
    """Token-selection policy: ``greedy`` argmax, ``temperature``
    softmax, or ``top_p`` nucleus (temperature applies before the
    nucleus cut).  One frozen config drives both plain decoding and the
    speculative rejection rule, which is what keeps the two paths
    distributionally identical."""
    kind: str = "greedy"
    temperature: float = 1.0
    top_p: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"sampler kind {self.kind!r} not in {KINDS}")
        if self.kind != "greedy" and not self.temperature > 0.0:
            raise ValueError(
                f"temperature must be > 0, got {self.temperature} "
                "(temperature -> 0 converges to greedy; use kind='greedy')")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


def sampler_probs(logits: jnp.ndarray, sc: SamplerConfig) -> jnp.ndarray:
    """logits (..., V) -> the sampler's token distribution (..., V), fp32.

    This is the single source of truth shared by plain sampling and the
    speculative rejection rule (speculative.verify_window), which needs
    draft/target probabilities under the SAME sampler transform for its
    exactness contract to hold.
    """
    logits = logits.astype(jnp.float32)
    if sc.kind == "greedy":
        # one-hot at argmax (first max wins, matching np.argmax): the
        # rejection rule then accepts iff draft argmax == target argmax
        return jax.nn.one_hot(jnp.argmax(logits, axis=-1),
                              logits.shape[-1], dtype=jnp.float32)
    probs = jax.nn.softmax(logits / sc.temperature, axis=-1)
    if sc.kind == "temperature" or sc.top_p >= 1.0:
        return probs
    # nucleus: keep a sorted token iff the mass strictly before it is
    # < top_p — the smallest prefix whose cumulative mass reaches top_p
    # (the crossing token included)
    order = jnp.argsort(-probs, axis=-1)
    ps = jnp.take_along_axis(probs, order, axis=-1)
    before = jnp.cumsum(ps, axis=-1) - ps
    ps = jnp.where(before < sc.top_p, ps, 0.0)
    ps = ps / ps.sum(axis=-1, keepdims=True)
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(ps, inv, axis=-1)


def sample_from_probs(key: jax.Array, probs: jnp.ndarray) -> jnp.ndarray:
    """One categorical draw per leading-batch row of ``probs`` (..., V).

    Zero-probability tokens map to ``-inf`` logits and can never be
    drawn, so a one-hot distribution samples its argmax deterministically
    regardless of the key (the greedy degenerate case).
    """
    return jax.random.categorical(key, jnp.log(probs), axis=-1)


@partial(jax.jit, static_argnames=("sc",))
def sample_token(key: jax.Array, logits: jnp.ndarray,
                 sc: SamplerConfig) -> jnp.ndarray:
    """Draw one token from ``sampler_probs(logits, sc)``.  logits (..., V)."""
    if sc.kind == "greedy":
        return jnp.argmax(logits, axis=-1)
    return sample_from_probs(key, sampler_probs(logits, sc))
