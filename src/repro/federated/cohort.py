"""Cohort construction for the batched round engine.

``jax.vmap`` over clients requires every stacked operand to be
shape-homogeneous: same expert budget ``k_i`` (static top-k ⇒ static
dispatch capacity), same adapter rank (leaf shapes), same rescaler
presence (pytree structure) and same step batch size.  All of these are
functions of the client's β budget tier plus its shard size, so cohorts
are, in effect, *budget groups*: a round's participants split into one
cohort per distinct budget, and each cohort trains in one compiled
``cohort_update`` call.

The grouping key deliberately uses the *distributed* adapter rank, not the
client's nominal rank: the "trivial" baseline distributes the globally
minimal rank to everyone, so all its clients land in one cohort even
though their nominal ranks differ.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from ..configs.base import TrainConfig
from . import client as client_lib

CohortKey = Tuple[int, int, int, bool, str]


def cohort_key(c: client_lib.ClientState, tc: TrainConfig,
               dist_rank: int) -> CohortKey:
    """Shape-homogeneity key: (k_i, distributed rank, step batch size,
    rescaler presence, rescaler mode)."""
    return (c.k, dist_rank, client_lib.plan_batch_size(c, tc),
            c.rescaler is not None, c.rescaler_mode)


@dataclass
class Cohort:
    """One shape-homogeneous vmap group within a round's participants."""
    key: CohortKey
    members: List[int]            # indices into the round's participant list

    @property
    def k(self) -> int:
        return self.key[0]

    @property
    def rank(self) -> int:
        return self.key[1]


def group_by_key(clients: Sequence[client_lib.ClientState],
                 tc: TrainConfig,
                 rank_of: Optional[Callable[[client_lib.ClientState], int]]
                 = None) -> "OrderedGroups":
    """Partition clients by cohort key, preserving first-appearance order.

    Returns ``(key_order, members)`` where ``members[key]`` lists indices
    into ``clients``.  Shared by :func:`build_cohorts` (one round's
    participants) and the device round driver (the full registry, to fix
    the static cohort-key set across every round of a scanned multi-round
    program)."""
    rank_of = rank_of or (lambda c: c.rank)
    order: List[CohortKey] = []
    members: dict = {}
    for i, c in enumerate(clients):
        key = cohort_key(c, tc, rank_of(c))
        if key not in members:
            members[key] = []
            order.append(key)
        members[key].append(i)
    return order, members


OrderedGroups = Tuple[List[CohortKey], dict]


def build_cohorts(clients: Sequence[client_lib.ClientState],
                  tc: TrainConfig,
                  rank_of: Optional[Callable[[client_lib.ClientState], int]]
                  = None) -> List[Cohort]:
    """Group a round's participating clients into vmap-able cohorts.

    ``clients``: the participants (already sampled); ``rank_of`` maps a
    client to the rank of the adapter the server will *distribute* to it
    (method-dependent — defaults to the client's own rank).  Cohorts are
    returned in first-appearance order, and every participant appears in
    exactly one cohort, so looping cohorts preserves the round's client
    coverage."""
    order, members = group_by_key(clients, tc, rank_of)
    return [Cohort(key=k, members=members[k]) for k in order]
