"""Server-side aggregation schemes.

* ``fedavg``            — Eq. 3–4: dataset-size-weighted average (all leaves).
* ``flame_aggregate``   — Eq. 6–7: activation-aware per-expert weights
                          ``γ_i^j = (a_i^j / S_i)^t · |D_i|`` applied to the
                          per-expert LoRA factors; non-expert adapters fall
                          back to dataset-size weighting (their "activation
                          frequency" is identically 1 — the paper's
                          full-activation edge case).  Natively consumes
                          *stacked* client trees (leading client axis, the
                          batched round engine's output format); legacy
                          Python lists are stacked on entry.
* ``flame_acc_*``       — the STREAMING form of the same aggregation:
                          ``init → update (one cohort chunk at a time) →
                          merge (hierarchical combination) → finalize``.
                          The accumulator holds only the weighted running
                          sums (one fp32 copy of the adapter tree + the
                          per-expert weight mass), so peak memory is
                          O(largest chunk), not O(total clients) — the
                          round driver's thousand-client substrate.
                          ``finalize(streamed chunks) == flame_aggregate
                          (all clients stacked)`` up to fp32 summation
                          order (property-tested for arbitrary splits).
* ``hlora_aggregate``   — HLoRA: zero-padded truncated adapters averaged with
                          per-rank-component sparsity weights.
* ``flexlora_aggregate``— FlexLoRA: aggregate full ΔW = s·A_i·B_i, then SVD
                          back to factors.

Activation frequency: we use the token-level frequency
``a_i^j / S_i := (#tokens client i routed to expert j) / (#tokens processed)``
which realises every edge case the paper's §5 analysis requires: t=0 ⇒ plain
FedAvg; never-activated expert ⇒ zero weight (randomly-initialised local
adapters cannot contaminate the global model); activated for every token ⇒
dataset-size weighting.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from . import lora as lora_lib

PyTree = Any
EPS = 1e-12


# --------------------------------------------------------------------------
# generic weighted tree averaging
# --------------------------------------------------------------------------

def _as_stacked(client_trees) -> PyTree:
    """Normalise aggregation input to the *stacked* form: a single pytree
    whose every leaf carries a leading client axis ``(n, ...)``.

    Python lists/tuples of per-client trees (the legacy interchange format)
    are stacked here; an already-stacked tree (the batched round engine's
    native output) passes through untouched."""
    if isinstance(client_trees, (list, tuple)):
        return lora_lib.stack_adapters(client_trees)
    return client_trees


def _weighted_tree_mean(trees, weights: Sequence[float]) -> PyTree:
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(w.sum(), EPS)
    n = w.shape[0]
    stacked = _as_stacked(trees)

    def avg(leaf):
        acc = (leaf.astype(jnp.float32)
               * w.reshape((n,) + (1,) * (leaf.ndim - 1))).sum(0)
        return acc.astype(leaf.dtype)

    return jax.tree.map(avg, stacked)


def fedavg(client_trees, dataset_sizes: Sequence[float]) -> PyTree:
    """Standard FedAvg (Eq. 3–4).

    ``client_trees``: list of per-client pytrees OR a stacked pytree with a
    leading client axis (see ``flame_aggregate`` for the stacked contract)."""
    return _weighted_tree_mean(client_trees, dataset_sizes)


# --------------------------------------------------------------------------
# FLAME activation-aware aggregation (Eq. 6–7)
# --------------------------------------------------------------------------

def activation_frequency(counts: Dict[str, jnp.ndarray],
                         total_tokens: float) -> Dict[str, jnp.ndarray]:
    """counts: {pos: (n_periods, E)} summed over the client's local steps."""
    return {k: jnp.clip(v / jnp.maximum(total_tokens, EPS), 0.0, 1.0)
            for k, v in counts.items()}


def _stack_freqs(client_freqs, n: int) -> Dict[str, jnp.ndarray]:
    """Normalise activation frequencies to {pos: (n, n_periods, E)}.

    Accepts the stacked dict directly, or a list of per-client
    {pos: (n_periods, E)} dicts.  A client whose shard produced no steps
    reports no frequencies — it is filled with zeros, i.e. zero contribution
    (the paper's zero-activation edge case)."""
    if isinstance(client_freqs, dict):
        return client_freqs
    pos_keys = sorted({k for f in client_freqs for k in f})
    out = {}
    for pos in pos_keys:
        ref = next(f[pos] for f in client_freqs if pos in f)
        out[pos] = jnp.stack([jnp.asarray(client_freqs[i].get(
            pos, jnp.zeros_like(ref))) for i in range(n)])     # (n, P, E)
    return out


def flame_aggregate(client_loras,
                    client_freqs,
                    dataset_sizes: Sequence[float],
                    temperature: int,
                    prev_lora: Optional[PyTree] = None) -> PyTree:
    """Aggregate client LoRA trees with Eq. 6–7.

    Input contract (stacked form — the batched round engine's native output):

    * ``client_loras``: a single pytree whose every leaf has a leading
      client axis, i.e. leaf shape ``(n, n_periods, ...)`` — produced by
      ``lora.stack_adapters`` or directly by ``client.cohort_update``.
      A Python list/tuple of ``n`` per-client trees (the legacy form) is
      accepted and stacked internally.
    * ``client_freqs``: ``{pos: (n, n_periods, E)}`` activation frequencies
      in [0, 1] — or a list of ``n`` per-client ``{pos: (n_periods, E)}``
      dicts (missing keys ⇒ zero frequency).
    * ``dataset_sizes``: length-``n`` vector |D_i| aligned with axis 0 of
      the stacked inputs.
    * ``prev_lora``: the pre-round global adapter tree.  An expert whose
      total weight mass Σ_i γ_i^j is zero — nobody activated it this round
      (t ≥ 1) — has no well-defined weighted mean; with ``prev_lora`` the
      server keeps the previous global adapter for that expert instead of
      collapsing it toward zero (``0 / EPS``), which silently reset the
      expert's accumulated state.  ``None`` preserves the legacy
      zero-fill behaviour.

    Expert adapters (leaves under a ``moe/experts`` path, shape
    ``(n, n_periods, E, ...)``) receive per-expert weights
    ``γ_i^j = freq^t · |D_i|`` normalised over clients; all other adapters
    use plain dataset-size weights.  Everything happens on-device over the
    stacked client axis — no per-client host round-trips."""
    sizes = jnp.asarray(dataset_sizes, jnp.float32)
    n = sizes.shape[0]
    stacked_loras = _as_stacked(client_loras)
    freqs = _stack_freqs(client_freqs, n)

    # per-(client, pos) expert weights γ: (n, n_periods, E)
    gamma = {pos: (f ** temperature) * sizes[:, None, None]
             for pos, f in freqs.items()}
    w_size = sizes / jnp.maximum(sizes.sum(), EPS)

    def aggregate(pos: str, node: PyTree, prev: PyTree, in_experts: bool):
        """Recursively average one block position's stacked sub-tree."""
        if isinstance(node, dict):
            return {k: aggregate(pos, v,
                                 prev.get(k) if isinstance(prev, dict)
                                 else None,
                                 in_experts or k == "experts")
                    for k, v in node.items()}
        leaf = node.astype(jnp.float32)                    # (n, ...)
        if in_experts and pos in gamma:
            # leaf shape (n, n_periods, E, ...) <- weights (n, n_periods, E)
            g = gamma[pos]
            g = g.reshape(g.shape + (1,) * (leaf.ndim - 3))
            mass = g.sum(0)                                # (n_periods, E, 1…)
            out = (leaf * g).sum(0) / jnp.maximum(mass, EPS)
            if prev is not None:
                out = jnp.where(mass > 0, out, prev.astype(jnp.float32))
        else:
            out = (leaf * w_size.reshape((n,) + (1,) * (leaf.ndim - 1))).sum(0)
        return out.astype(node.dtype)

    prev_blocks = (prev_lora or {}).get("blocks", {})
    blocks = {pos: aggregate(pos, node, prev_blocks.get(pos),
                             in_experts=False)
              for pos, node in stacked_loras["blocks"].items()}
    return {"blocks": blocks}


# --------------------------------------------------------------------------
# streaming FLAME aggregation: init → update per chunk → merge → finalize
# --------------------------------------------------------------------------
#
# The accumulator is a plain pytree (jit/scan-friendly):
#
#   {"num":      fp32 adapter tree, NO client axis — Σ_i w_i · leaf_i,
#    "den_gamma": {pos: (n_periods, E)}  — Σ_i γ_i per expert position,
#    "den_size": ()                      — Σ_i |D_i|}
#
# Expert leaves accumulate with w_i = γ_i^j = freq^t·|D_i|, everything else
# with w_i = |D_i|; finalize divides by the matching denominator, so the
# result equals ``flame_aggregate`` over all streamed clients stacked at
# once — up to fp32 summation order — while only ever materialising one
# chunk plus one adapter-tree-sized accumulator.

def flame_acc_init(template_lora: PyTree) -> PyTree:
    """Fresh accumulator shaped after one (unstacked) adapter tree."""
    return {"num": jax.tree.map(
                lambda l: jnp.zeros(l.shape, jnp.float32), template_lora),
            "den_gamma": {},
            "den_size": jnp.zeros((), jnp.float32)}


def flame_acc_update(acc: PyTree, stacked_loras: PyTree, stacked_freqs,
                     dataset_sizes, temperature: int) -> PyTree:
    """Fold one chunk of clients (stacked form, axis 0 = client) into the
    running sums.  A client with ``dataset_sizes[i] == 0`` contributes
    nothing — the round driver's padding slots exploit this."""
    sizes = jnp.asarray(dataset_sizes, jnp.float32)
    n = sizes.shape[0]
    stacked = _as_stacked(stacked_loras)
    freqs = _stack_freqs(stacked_freqs, n)
    gamma = {pos: (f.astype(jnp.float32) ** temperature)
             * sizes[:, None, None] for pos, f in freqs.items()}

    def add(pos: str, node: PyTree, num: PyTree, in_experts: bool):
        if isinstance(node, dict):
            return {k: add(pos, v, num[k], in_experts or k == "experts")
                    for k, v in node.items()}
        leaf = node.astype(jnp.float32)                    # (n, ...)
        if in_experts and pos in gamma:
            g = gamma[pos]
            g = g.reshape(g.shape + (1,) * (leaf.ndim - 3))
            return num + (leaf * g).sum(0)
        return num + (leaf
                      * sizes.reshape((n,) + (1,) * (leaf.ndim - 1))).sum(0)

    num = {"blocks": {pos: add(pos, node, acc["num"]["blocks"][pos],
                               in_experts=False)
                      for pos, node in stacked["blocks"].items()}}
    den_gamma = dict(acc["den_gamma"])
    for pos, g in gamma.items():
        den_gamma[pos] = den_gamma.get(
            pos, jnp.zeros(g.shape[1:], jnp.float32)) + g.sum(0)
    return {"num": num, "den_gamma": den_gamma,
            "den_size": acc["den_size"] + sizes.sum()}


def flame_acc_merge(a: PyTree, b: PyTree) -> PyTree:
    """Hierarchical combination: two accumulators over disjoint client sets
    merge by plain addition (weighted sums are associative) — the two-level
    reduction the round driver applies across a round's cohorts."""
    den_gamma = dict(a["den_gamma"])
    for pos, g in b["den_gamma"].items():
        den_gamma[pos] = (den_gamma[pos] + g if pos in den_gamma else g)
    return {"num": jax.tree.map(jnp.add, a["num"], b["num"]),
            "den_gamma": den_gamma,
            "den_size": a["den_size"] + b["den_size"]}


def flame_acc_finalize(acc: PyTree,
                       prev_lora: Optional[PyTree] = None) -> PyTree:
    """Divide the running sums by their weight mass → the global adapter.

    Zero-mass experts (nobody activated them across every streamed chunk)
    keep ``prev_lora``'s value when given — the same keep-previous guard as
    ``flame_aggregate(prev_lora=...)``; a naive ``num / den`` would emit
    NaN (0/0) straight into the global tree.  Output leaves take
    ``prev_lora``'s dtypes when given, else stay fp32."""
    den_gamma, den_size = acc["den_gamma"], acc["den_size"]

    def rec(pos: str, num: PyTree, prev: PyTree, in_experts: bool):
        if isinstance(num, dict):
            return {k: rec(pos, v,
                           prev.get(k) if isinstance(prev, dict) else None,
                           in_experts or k == "experts")
                    for k, v in num.items()}
        if in_experts and pos in den_gamma:
            den = den_gamma[pos]
            den = den.reshape(den.shape + (1,) * (num.ndim - 2))
            out = num / jnp.maximum(den, EPS)
            fallback = (prev.astype(jnp.float32) if prev is not None
                        else jnp.zeros_like(out))
            out = jnp.where(den > 0, out, fallback)
        else:
            out = num / jnp.maximum(den_size, EPS)
            if prev is not None:
                out = jnp.where(den_size > 0, out,
                                prev.astype(jnp.float32))
        return out.astype(prev.dtype) if prev is not None else out

    prev_blocks = (prev_lora or {}).get("blocks", {})
    return {"blocks": {pos: rec(pos, node, prev_blocks.get(pos),
                                in_experts=False)
                       for pos, node in acc["num"]["blocks"].items()}}


# --------------------------------------------------------------------------
# HLoRA: sparsity-weighted aggregation of rank-truncated adapters
# --------------------------------------------------------------------------

def hlora_aggregate(client_loras: Sequence[PyTree],
                    client_ranks: Sequence[int],
                    dataset_sizes: Sequence[float],
                    r_full: int) -> PyTree:
    """Clients trained adapters truncated to ``client_ranks[i]``; pad to the
    server rank and average each rank component only over the clients that
    actually trained it."""
    n = len(client_loras)
    sizes = jnp.asarray(dataset_sizes, jnp.float32)
    padded = [lora_lib.pad_rank(cl, r_full) for cl in client_loras]
    ranks = jnp.asarray(client_ranks)
    comp = jnp.arange(r_full)
    trained = (ranks[:, None] > comp[None, :]).astype(jnp.float32)  # (n, r)
    w = trained * sizes[:, None]
    w = w / jnp.maximum(w.sum(0, keepdims=True), EPS)               # (n, r)

    def avg_pair(*pairs):
        a = jnp.stack([p["a"].astype(jnp.float32) for p in pairs])  # (n,...,d,r)
        b = jnp.stack([p["b"].astype(jnp.float32) for p in pairs])  # (n,...,r,o)
        wa = w.reshape((n,) + (1,) * (a.ndim - 2) + (r_full,))
        wb = w.reshape((n,) + (1,) * (b.ndim - 3) + (r_full, 1))
        return {"a": (a * wa).sum(0).astype(pairs[0]["a"].dtype),
                "b": (b * wb).sum(0).astype(pairs[0]["b"].dtype)}

    def rec(nodes):
        node0 = nodes[0]
        if isinstance(node0, dict) and set(node0) == {"a", "b"}:
            return avg_pair(*nodes)
        return {k: rec([nd[k] for nd in nodes]) for k in node0}

    return rec(padded)


# --------------------------------------------------------------------------
# FlexLoRA: ΔW aggregation + SVD redistribution
# --------------------------------------------------------------------------

def flexlora_aggregate(client_loras: Sequence[PyTree],
                       dataset_sizes: Sequence[float],
                       r_full: int, scale: float) -> PyTree:
    """Aggregate full-rank updates ΔW_i = scale·A_i·B_i by dataset size, then
    SVD-refactor the averaged ΔW back into rank-``r_full`` factors."""
    deltas = [lora_lib.merge_delta(cl, scale) for cl in client_loras]
    avg_delta = _weighted_tree_mean(deltas, dataset_sizes)
    return lora_lib.svd_refactor(avg_delta, r_full, scale)


# --------------------------------------------------------------------------
# round summary (used by benchmarks / Fig 2)
# --------------------------------------------------------------------------

def stack_client_frequencies(client_freqs: Sequence[Dict[str, jnp.ndarray]]
                             ) -> Dict[str, jnp.ndarray]:
    """{pos: (n_clients, n_periods, E)} — the Figure-2 heatmap tensor."""
    out = {}
    for pos in client_freqs[0]:
        out[pos] = jnp.stack([f[pos] for f in client_freqs])
    return out
