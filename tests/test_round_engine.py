"""Batched round engine vs the sequential reference oracle.

The contract under test (ISSUE 1 acceptance): running a mixed b1–b4 cohort
through the vmapped engine produces per-round client updates allclose to
the per-client sequential loop, and the stacked-tree ``flame_aggregate``
path matches the legacy list-based one.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederatedConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core import aggregation as agg
from repro.core import lora as L
from repro.data.synthetic import DataConfig
from repro.federated import client as client_lib
from repro.federated.cohort import build_cohorts
from repro.federated.simulation import build_experiment

CFG = get_config("olmoe-1.3b-6.9b", "smoke")
TC = TrainConfig(batch_size=8, local_epochs=1)
DATA = DataConfig(vocab_size=CFG.vocab_size, n_examples=96, seq_len=64,
                  n_clusters=4)


def _experiment(engine, *, method="flame", clients=4, backend="vmap"):
    fed = FederatedConfig(num_clients=clients, rounds=1, method=method,
                          temperature=2, round_engine=engine,
                          cohort_backend=backend)
    return build_experiment(CFG, fed=fed, tc=TC, data=DATA)


def _assert_trees_close(a, b, rtol=2e-3, atol=2e-3):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("method", ["flame", "hlora"])
def test_batched_round_matches_sequential_mixed_budgets(method):
    """4 clients spanning b1–b4 (different k_i / ranks ⇒ multiple cohorts):
    one batched round must reproduce the looped round's global adapter,
    per-client losses and activation frequencies."""
    exp_l = _experiment("looped", method=method)
    exp_b = _experiment("batched", method=method)
    res_l = exp_l.server.run_round(0)
    res_b = exp_b.server.run_round(0)

    assert res_l.participating == res_b.participating
    _assert_trees_close(exp_l.server.global_lora, exp_b.server.global_lora)
    np.testing.assert_allclose(res_l.client_losses, res_b.client_losses,
                               rtol=1e-4, atol=1e-4)
    assert len(res_l.client_freqs) == len(res_b.client_freqs)
    for fl, fb in zip(res_l.client_freqs, res_b.client_freqs):
        assert set(fl) == set(fb)
        for pos in fl:
            np.testing.assert_allclose(fl[pos], fb[pos], rtol=1e-5,
                                       atol=1e-5)
    # client-local rescaler state must evolve identically too
    for cl, cb in zip(exp_l.server.clients, exp_b.server.clients):
        if cl.rescaler is not None:
            _assert_trees_close(cl.rescaler, cb.rescaler)


def test_lax_map_backend_matches_vmap():
    exp_v = _experiment("batched", backend="vmap")
    exp_m = _experiment("batched", backend="map")
    exp_v.server.run_round(0)
    exp_m.server.run_round(0)
    _assert_trees_close(exp_v.server.global_lora, exp_m.server.global_lora)


def test_cohorts_group_by_budget():
    """Round-robin β assignment over 8 clients ⇒ 4 budget cohorts of 2,
    covering every participant exactly once."""
    exp = _experiment("batched", clients=8)
    clients = exp.server.clients
    cohorts = build_cohorts(clients, TC, rank_of=exp.server._dist_rank)
    assert len(cohorts) == 4
    seen = sorted(i for co in cohorts for i in co.members)
    assert seen == list(range(8))
    for co in cohorts:
        ks = {clients[i].k for i in co.members}
        assert len(ks) == 1 and co.k in ks


def test_padding_steps_are_exact_noops():
    """local_update on a padded plan equals local_update on the raw plan —
    the Adam state masking makes padding bit-equivalent, which is what
    lets shards of different sizes share one cohort."""
    exp = _experiment("batched", clients=2)
    c = exp.server.clients[0]
    trainable = L.make_trainable(exp.server.global_lora, c.rescaler)
    plan = client_lib.make_batch_plan(c, TC, round_seed=7)
    padded = client_lib.pad_plan(plan, plan.n_steps + 3)

    def run(p):
        return client_lib.local_update(
            CFG, exp.server.params, trainable, jnp.asarray(p.tokens),
            jnp.asarray(p.labels), jnp.asarray(p.mask),
            jnp.asarray(p.valid), k=c.k, tc=TC, rescaler_trainable=True)

    tr_a, counts_a, tok_a, loss_a, n_a = run(plan)
    tr_b, counts_b, tok_b, loss_b, n_b = run(padded)
    assert float(tok_a) == float(tok_b) and float(n_a) == float(n_b)
    np.testing.assert_allclose(float(loss_a), float(loss_b), rtol=1e-6)
    _assert_trees_close(tr_a, tr_b, rtol=1e-6, atol=1e-6)
    _assert_trees_close(counts_a, counts_b, rtol=1e-6, atol=1e-6)


def test_zero_step_client_contributes_nothing():
    """A client with no runnable steps (local_epochs=0 here; empty shards
    behave the same) yields an all-invalid plan: unchanged adapters, zero
    counts/tokens, nan mean loss — the aggregation-side zero-activation
    edge case instead of a crash."""
    exp = _experiment("batched", clients=2)
    c = exp.server.clients[0]
    tc0 = dataclasses.replace(TC, local_epochs=0)
    plan = client_lib.make_batch_plan(c, tc0, round_seed=3)
    assert plan.n_steps == 1 and plan.valid.sum() == 0.0

    trainable = L.make_trainable(exp.server.global_lora, c.rescaler)
    tr, counts, tok, loss, n_valid = client_lib.local_update(
        CFG, exp.server.params, trainable, jnp.asarray(plan.tokens),
        jnp.asarray(plan.labels), jnp.asarray(plan.mask),
        jnp.asarray(plan.valid), k=c.k, tc=tc0, rescaler_trainable=True)
    assert float(tok) == 0.0 and float(n_valid) == 0.0
    _assert_trees_close(tr, trainable, rtol=0, atol=0)
    assert all(float(np.abs(v).sum()) == 0.0 for v in counts.values())


# --------------------------------------------------------------------------
# stacked aggregation path
# --------------------------------------------------------------------------

E, NP, D, R = 4, 1, 8, 4


def _client_lora(seed):
    key = jax.random.PRNGKey(seed)
    return {"blocks": {"pos0": {"moe": {"experts": {
        "w1": {"a": jax.random.normal(key, (NP, E, D, R)),
               "b": jax.random.normal(jax.random.fold_in(key, 1),
                                      (NP, E, R, D))},
    }}, "attn": {"wq": {"a": jax.random.normal(jax.random.fold_in(key, 2),
                                               (NP, D, R)),
                        "b": jnp.zeros((NP, R, D))}}}}}


def test_stacked_flame_aggregate_matches_list_based():
    loras = [_client_lora(s) for s in range(3)]
    freq_rows = [[0.9, 0.1, 0.5, 0.0], [0.2, 0.8, 0.5, 1.0],
                 [0.4, 0.4, 0.0, 0.3]]
    freqs = [{"pos0": jnp.broadcast_to(jnp.asarray(r, jnp.float32), (NP, E))}
             for r in freq_rows]
    sizes = [10.0, 30.0, 25.0]

    by_list = agg.flame_aggregate(loras, freqs, sizes, temperature=2)
    stacked = L.stack_adapters(loras)
    stacked_freqs = {"pos0": jnp.stack([f["pos0"] for f in freqs])}
    by_stack = agg.flame_aggregate(stacked, stacked_freqs, sizes,
                                   temperature=2)
    _assert_trees_close(by_list, by_stack, rtol=1e-6, atol=1e-6)


def test_stacked_fedavg_matches_list_based():
    loras = [_client_lora(s) for s in range(3)]
    sizes = [5.0, 15.0, 80.0]
    _assert_trees_close(agg.fedavg(loras, sizes),
                        agg.fedavg(L.stack_adapters(loras), sizes),
                        rtol=1e-6, atol=1e-6)


def test_stack_unstack_roundtrip():
    loras = [_client_lora(s) for s in range(3)]
    back = L.unstack_adapters(L.stack_adapters(loras))
    assert len(back) == 3
    for orig, rt in zip(loras, back):
        _assert_trees_close(orig, rt, rtol=0, atol=0)
