"""Optimizer, data-pipeline, and checkpoint substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.data.partition import dirichlet_partition, heterogeneity_stats
from repro.data.synthetic import DataConfig, batches, make_corpus, split_corpus
from repro.optim import adam


# ---------------------------------------------------------------- adam

def test_adam_converges_on_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adam.init(params)
    for _ in range(300):
        grads = jax.tree.map(lambda p: 2 * p, params)   # d/dx x^2
        params, state = adam.update(grads, state, params, lr=0.1)
    np.testing.assert_allclose(np.asarray(params["x"]), 0.0, atol=1e-2)


def test_adam_first_step_is_lr_sized():
    """Bias correction: |Δp| == lr on step 1 regardless of grad scale."""
    for g in (1e-4, 1.0, 1e4):
        params = {"x": jnp.zeros(())}
        state = adam.init(params)
        new, _ = adam.update({"x": jnp.asarray(g)}, state, params, lr=0.01)
        np.testing.assert_allclose(abs(float(new["x"])), 0.01, rtol=1e-2)


def test_grad_clip_bounds_update():
    params = {"a": jnp.zeros((4,)), "b": jnp.zeros((4,))}
    grads = {"a": jnp.full((4,), 100.0), "b": jnp.full((4,), 100.0)}
    clipped = adam.clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(adam.global_norm(clipped)), 1.0,
                               rtol=1e-5)


def test_adam_mask_freezes_leaves():
    params = {"train": jnp.ones(()), "frozen": jnp.ones(())}
    state = adam.init(params)
    grads = {"train": jnp.asarray(1.0), "frozen": jnp.asarray(1.0)}
    new, _ = adam.update(grads, state, params, lr=0.1,
                         mask={"train": True, "frozen": False})
    assert float(new["train"]) != 1.0
    assert float(new["frozen"]) == 1.0


# ---------------------------------------------------------------- data

def test_corpus_layout_and_mask():
    cfg = DataConfig(vocab_size=64, n_examples=32, seq_len=48, prompt_len=16)
    c = make_corpus(cfg)
    assert c.tokens.shape == (32, 48)
    assert (c.tokens >= 0).all() and (c.tokens < 64).all()
    # loss only on response region; prompt + final position masked out
    assert (c.mask[:, :cfg.prompt_len + 1] == 0).all()
    assert (c.mask[:, -1] == 0).all()
    assert c.mask.sum() > 0
    # labels are next-token shifted
    np.testing.assert_array_equal(c.labels[:, :-1], c.tokens[:, 1:])


def test_split_fractions():
    c = make_corpus(DataConfig(vocab_size=64, n_examples=100, seq_len=32))
    tr, va, te = split_corpus(c)
    assert len(tr.tokens) == 80 and len(va.tokens) == 10
    assert len(te.tokens) == 10


def test_dirichlet_alpha_controls_skew():
    """α=0.5 must produce more skewed per-client cluster histograms than
    α=50 (the paper's heterogeneity knob)."""
    c = make_corpus(DataConfig(vocab_size=64, n_examples=2048, seq_len=32,
                               n_clusters=8))

    def skew(alpha):
        shards = dirichlet_partition(c, 4, alpha, seed=1)
        h = heterogeneity_stats(shards)["cluster_hist"].astype(float)
        h = h / h.sum(1, keepdims=True)
        return float(np.std(h, axis=0).mean())

    assert skew(0.5) > 1.5 * skew(50.0)


def test_min_shard_guarantee_under_starvation():
    """Heavily skewed Dirichlet draws must still leave every client at or
    above min_per_client (the donor loop's fallback splits the largest
    shard instead of silently giving up)."""
    data = DataConfig(vocab_size=64, n_examples=48, seq_len=8, n_clusters=2)
    corpus = make_corpus(data)
    for seed in range(8):
        shards = dirichlet_partition(corpus, num_clients=12, alpha=0.05,
                                     seed=seed, min_per_client=3)
        sizes = [len(s.tokens) for s in shards]
        assert min(sizes) >= 3, (seed, sizes)
        assert sum(sizes) == len(corpus.tokens)


def test_min_shard_guarantee_caps_at_feasible_floor():
    """min_per_client above len(corpus)//num_clients can't be satisfied;
    the guarantee caps at the feasible floor instead of asserting out."""
    data = DataConfig(vocab_size=64, n_examples=10, seq_len=8, n_clusters=2)
    corpus = make_corpus(data)
    shards = dirichlet_partition(corpus, num_clients=8, alpha=0.1,
                                 seed=0, min_per_client=4)
    sizes = [len(s.tokens) for s in shards]
    assert min(sizes) >= 10 // 8, sizes
    assert sum(sizes) == len(corpus.tokens)


def test_batches_cover_epoch():
    c = make_corpus(DataConfig(vocab_size=64, n_examples=40, seq_len=32))
    rng = np.random.default_rng(0)
    n = sum(len(t) for t, _, _ in batches(c, 8, rng=rng))
    assert n == 40


def test_audio_corpus_codebook_layout():
    c = make_corpus(DataConfig(vocab_size=64, n_examples=8, seq_len=32,
                               num_codebooks=4))
    assert c.tokens.shape == (8, 32, 4)
    assert c.labels.shape == (8, 32, 4)


# ---------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_nested(tmp_path):
    tree = {"a": {"b": np.arange(6).reshape(2, 3).astype(np.float32)},
            "lst": [np.ones(2), np.zeros(3)],
            "scalar": np.asarray(3)}
    path = str(tmp_path / "t.npz")
    ckpt.save(path, tree, meta={"round": 7})
    back, meta = ckpt.load(path)
    assert meta["round"] == 7
    np.testing.assert_array_equal(back["a"]["b"], tree["a"]["b"])
    np.testing.assert_array_equal(back["lst"][1], tree["lst"][1])
    assert int(back["scalar"]) == 3


def test_checkpoint_jax_arrays(tmp_path):
    tree = {"w": jnp.ones((3, 3), jnp.bfloat16)}
    path = str(tmp_path / "w.npz")
    ckpt.save(path, jax.tree.map(lambda t: np.asarray(t, np.float32), tree))
    back, _ = ckpt.load(path)
    np.testing.assert_allclose(back["w"], 1.0)
