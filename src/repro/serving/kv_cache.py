"""KV-cache pools for the serving engine: slotted and block-paged.

:class:`SlotPool` is the slotted (paged-lite) pool: one device-resident
decode cache of ``num_slots`` fixed-capacity slots (``model.init_cache``
with ``batch=num_slots``) plus host-side bookkeeping — a free list and a
per-slot ``cache_pos``.  Whole-slot granularity: a short request pins the
same ``slot_len`` of K/V a long one does.

:class:`BlockPool` is the block-paged pool (the vLLM point on the same
axis): attention K/V live in a global pool of fixed-size blocks, each
request row owns a *block table* mapping its logical positions to pool
blocks, blocks are allocated on demand at prefill/decode time and freed at
eviction — so device KV bytes follow tokens in flight, not
``num_slots × slot_len``.  Block id 0 is a reserved null/trash block:
zeroed block-table entries (free rows, unallocated tail) point at it, its
contents are never read (per-row validity masks them out of scores), and
writes from inactive rows land there harmlessly.

Admission math: a request needs
``blocks_needed(min(prompt_len + max_new - 1, page_span))`` blocks over
its lifetime (``page_span`` = per-request logical capacity; the ring
modulus for sliding-window models).  ``reserve`` books that projection at
admit time so on-demand allocation during decode can never fail; the
``available_blocks`` headroom — free blocks minus outstanding unallocated
reservations — is what the scheduler's can-admit predicate consults.

All per-row cache leaves carry the layout ``(n_periods, batch, ...)``;
paged attention leaves are ``(n_periods, num_blocks + 1, block_size, KV,
head_dim)``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib

PyTree = Any


class _RowPool:
    """Decode-row bookkeeping shared by both KV pools: a free list of
    rows and a per-row ``cache_pos`` — the machinery that decouples
    request lifetime from the compiled step's batch shape."""

    def __init__(self, cfg, num_slots: int, slot_len: int):
        assert num_slots >= 1 and slot_len >= 1, (num_slots, slot_len)
        self.cfg = cfg
        self.num_slots = num_slots
        self.slot_len = slot_len
        # attention rows hold min(window, slot_len) positions (ring cache)
        self.attn_len = model_lib.cache_len_for(cfg, slot_len)
        self.cache_pos = np.zeros((num_slots,), np.int32)
        self._free: List[int] = list(range(num_slots))

    @property
    def free_slots(self) -> List[int]:
        """Free slot ids, lowest first (deterministic allocation order)."""
        return sorted(self._free)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def allocate(self) -> int:
        if not self._free:
            raise RuntimeError(f"{type(self).__name__}: no free rows")
        self._free.sort()
        return self._free.pop(0)

    def take(self, slot: int) -> None:
        """Claim a specific free slot (scheduler-chosen assignment)."""
        if slot not in self._free:
            raise ValueError(
                f"{type(self).__name__}.take({slot}): slot is not free "
                f"(free: {self.free_slots})")
        self._free.remove(slot)

    def _require_live(self, slots: Sequence[int]) -> None:
        """Guard for cache writes: every target row must be claimed.
        Writing into a free row would silently corrupt whatever request
        is admitted there next — raise instead."""
        dead = [s for s in slots if s in self._free]
        if dead:
            raise ValueError(
                f"{type(self).__name__}.write: slots {dead} are free "
                f"(allocate/take them first)")

    def release(self, slot: int) -> None:
        assert 0 <= slot < self.num_slots and slot not in self._free, slot
        self.cache_pos[slot] = 0
        self._free.append(slot)

    def positions(self) -> jnp.ndarray:
        """Per-slot decode positions as a device vector."""
        return jnp.asarray(self.cache_pos)

    def advance(self, slots: Sequence[int]) -> None:
        """One token decoded in each of ``slots``."""
        self.cache_pos[np.asarray(list(slots), np.int32)] += 1

    def truncate_to(self, slot: int, n_tokens: int) -> None:
        """Roll a live row back to ``n_tokens`` written positions — the
        speculative-decode rollback: positions ``>= n_tokens`` (a rejected
        draft suffix) become dead and the next decode write lands at
        ``n_tokens``.  Never grows a row.  Requires an unwrapped cache
        (a wrapped ring has aliased positions; rollback is ill-defined)."""
        if slot in self._free:
            raise ValueError(
                f"{type(self).__name__}.truncate_to({slot}): slot is free")
        held = int(self.cache_pos[slot])
        if self.cfg.attention_window > 0 and held > self.attn_len:
            raise ValueError(
                f"{type(self).__name__}.truncate_to({slot}): ring cache "
                f"has wrapped ({held} > {self.attn_len} positions); "
                f"rollback is ill-defined")
        if not 0 <= n_tokens <= held:
            raise ValueError(
                f"{type(self).__name__}.truncate_to({slot}, {n_tokens}): "
                f"row holds only {held} positions")
        self.cache_pos[slot] = n_tokens

    def slot_full(self, slot: int) -> bool:
        """No room left to write the next decode token (linear cache);
        ring (sliding-window) caches never fill."""
        if self.cfg.attention_window > 0:
            return False
        return int(self.cache_pos[slot]) >= self.attn_len

    def kv_bytes(self) -> int:
        """Device bytes held by the pool's cache tree."""
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache))


class SlotPool(_RowPool):
    """Fixed-capacity slotted KV-cache pool with allocate/release."""

    def __init__(self, cfg, num_slots: int, slot_len: int):
        super().__init__(cfg, num_slots, slot_len)
        self.cache: PyTree = model_lib.init_cache(cfg, num_slots, slot_len)

    # ------------------------------------------------------------- cache I/O
    def write(self, slots: Sequence[int], piece: PyTree,
              lengths: Sequence[int]) -> None:
        """Install a freshly prefilled cache into ``slots``.

        ``piece``: a cache tree with batch size ``>= len(slots)`` on axis 1
        (extra rows — prefill bucket padding — are ignored);
        ``lengths``: per-slot prompt length, i.e. the position the first
        decode step will write.
        """
        self._require_live(slots)
        idx = np.asarray(list(slots), np.int32)
        nb = len(idx)

        def put(pool: jnp.ndarray, pc: jnp.ndarray) -> jnp.ndarray:
            return pool.at[:, idx].set(pc[:, :nb].astype(pool.dtype))

        self.cache = jax.tree.map(put, self.cache, piece)
        self.cache_pos[idx] = np.asarray(list(lengths), np.int32)


class BlockPool(_RowPool):
    """Block-paged KV-cache pool: global block pool + per-row block tables.

    ``num_slots`` decode rows (the compiled step's batch) share
    ``num_blocks`` usable KV blocks of ``block_size`` tokens each (device
    arrays hold one extra trash block at id 0).  Rows and blocks are
    decoupled: admission needs a free row AND the request's projected
    block count (``can_admit``); blocks are reserved at admit, allocated
    lazily (prompt blocks at :meth:`write`, decode blocks at
    :meth:`prepare_decode`), and returned at :meth:`release`.

    Mamba SSM state is O(1)/request and stays per-row (never paged).
    """

    def __init__(self, cfg, num_slots: int, slot_len: int,
                 block_size: int = 16, num_blocks: int = None):
        assert block_size >= 1, block_size
        super().__init__(cfg, num_slots, slot_len)
        self.block_size = block_size
        # attn_len doubles as the per-request logical capacity (the ring
        # modulus for sliding-window models)
        self.blocks_per_slot = -(-self.attn_len // block_size)
        if num_blocks is None:
            # full provisioning: every row can hold a max-length request,
            # so admission degenerates to slot availability (parity with
            # SlotPool); size it down to make blocks the scarce resource.
            num_blocks = num_slots * self.blocks_per_slot
        assert num_blocks >= self.blocks_per_slot, (
            f"num_blocks={num_blocks} cannot hold even one max-length "
            f"request ({self.blocks_per_slot} blocks)")
        self.num_blocks = num_blocks
        self.cache: PyTree = model_lib.init_paged_cache(
            cfg, num_slots, num_blocks, block_size)
        self.block_table = np.zeros((num_slots, self.blocks_per_slot),
                                    np.int32)
        self._free_blocks: List[int] = list(range(1, num_blocks + 1))
        self._reserved = np.zeros((num_slots,), np.int64)
        self._nalloc = np.zeros((num_slots,), np.int64)
        self.peak_blocks = 0

    def tables(self) -> jnp.ndarray:
        """Per-row block tables as a device array for the decode step."""
        return jnp.asarray(self.block_table)

    # ----------------------------------------------------- block bookkeeping
    def blocks_needed(self, n_tokens: int) -> int:
        """Blocks covering ``n_tokens`` logical positions (ring-capped)."""
        return -(-min(max(int(n_tokens), 1), self.attn_len)
                 // self.block_size)

    @property
    def blocks_in_use(self) -> int:
        return int(self._nalloc.sum())

    @property
    def available_blocks(self) -> int:
        """Free blocks not spoken for by outstanding reservations."""
        debt = int((self._reserved - self._nalloc).sum())
        return len(self._free_blocks) - debt

    def can_admit(self, n_tokens: int) -> bool:
        return self.blocks_needed(n_tokens) <= self.available_blocks

    def reserved_for(self, slot: int) -> int:
        """Blocks currently reserved by ``slot``'s request."""
        return int(self._reserved[slot])

    def reserve(self, slot: int, n_tokens: int) -> None:
        """Book the request's lifetime block projection at admit time, so
        later on-demand allocation (prepare_decode) can never fail."""
        need = self.blocks_needed(n_tokens)
        assert self._reserved[slot] == 0 and self._nalloc[slot] == 0, slot
        assert need <= self.available_blocks, (
            f"reserve({slot}, {n_tokens}): need {need} > available "
            f"{self.available_blocks}")
        self._reserved[slot] = need

    def _alloc_block(self, slot: int) -> None:
        assert self._nalloc[slot] < self._reserved[slot], (
            f"slot {slot}: allocation would exceed its reservation "
            f"({self._reserved[slot]} blocks)")
        # pop the list head (NOT lowest-id): deterministic, and it keeps a
        # test-injected permutation (permute_free) in force — physical
        # block order must be invisible to results
        bid = self._free_blocks.pop(0)
        self.block_table[slot, self._nalloc[slot]] = bid
        self._nalloc[slot] += 1
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)

    def alloc_prompt(self, slot: int, prompt_len: int) -> None:
        """Allocate the blocks the prompt's K/V will be installed into."""
        while self._nalloc[slot] < self.blocks_needed(prompt_len):
            self._alloc_block(slot)

    def prepare_decode(self, slots: Sequence[int]) -> None:
        """Allocate, for each active row, the block its next decode write
        lands in (a no-op until the write crosses a block boundary)."""
        for s in slots:
            p = int(self.cache_pos[s])
            logical = p % self.attn_len if self.cfg.attention_window > 0 \
                else min(p, self.attn_len - 1)
            while self._nalloc[s] <= logical // self.block_size:
                self._alloc_block(s)

    def truncate_to(self, slot: int, n_tokens: int) -> None:
        """Speculative rollback: drop the row's positions ``>= n_tokens``
        and return the tail blocks past the kept span to the free list.
        The reservation stays booked — the request's lifetime projection
        is unchanged, so re-allocating the freed tail during later decode
        (prepare_decode) can never fail."""
        super().truncate_to(slot, n_tokens)            # guards + cache_pos
        keep = -(-min(n_tokens, self.attn_len) // self.block_size)
        n = int(self._nalloc[slot])
        if keep < n:
            self._free_blocks.extend(
                int(b) for b in self.block_table[slot, keep:n])
            self.block_table[slot, keep:n] = 0
            self._nalloc[slot] = keep

    def release(self, slot: int) -> None:
        n = int(self._nalloc[slot])
        self._free_blocks.extend(int(b) for b in self.block_table[slot, :n])
        self.block_table[slot, :] = 0
        self._reserved[slot] = 0
        self._nalloc[slot] = 0
        super().release(slot)                  # asserts against double free

    def permute_free(self, seed: int) -> None:
        """Shuffle free-block allocation order.  Physical block placement
        is invisible to results (tests/test_paged_kv.py proves it)."""
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(self._free_blocks))
        self._free_blocks = [self._free_blocks[i] for i in order]

    def check_invariants(self) -> None:
        """Free-list integrity: no double-allocation, no leaks,
        used + free == total after every operation."""
        used_ids = [int(self.block_table[s, j])
                    for s in range(self.num_slots)
                    for j in range(int(self._nalloc[s]))]
        free_ids = list(self._free_blocks)
        assert len(set(used_ids)) == len(used_ids), "double-allocated block"
        assert 0 not in used_ids, "trash block handed out"
        assert not set(used_ids) & set(free_ids), \
            "block simultaneously used and free"
        assert len(used_ids) + len(free_ids) == self.num_blocks, \
            f"leak: used {len(used_ids)} + free {len(free_ids)} != " \
            f"{self.num_blocks}"
        assert all(1 <= b <= self.num_blocks for b in used_ids + free_ids)
        for s in range(self.num_slots):
            n = int(self._nalloc[s])
            assert (self.block_table[s, n:] == 0).all(), \
                f"slot {s}: stale table entries past nalloc"
            assert self._nalloc[s] <= self._reserved[s], \
                f"slot {s}: allocated past its reservation"
        assert self.available_blocks >= 0

    # ------------------------------------------------------------- cache I/O
    def write(self, slots: Sequence[int], piece: PyTree,
              lengths: Sequence[int]) -> None:
        """Install freshly prefilled caches into ``slots``.

        ``piece`` is a contiguous (slotted-layout) cache tree with batch
        ``>= len(slots)`` on axis 1 — exactly what ``model.prefill``
        returns — whose first ``min(len, attn_len)`` columns are scattered
        into each row's (freshly allocated) blocks; Mamba leaves install
        per row.  ``lengths``: per-slot prompt length, i.e. the position
        the first decode step will write.
        """
        slots = [int(s) for s in slots]
        lengths = [int(n) for n in lengths]
        self._require_live(slots)
        for s, L in zip(slots, lengths):
            self.alloc_prompt(s, L)

        bs = self.block_size
        n_cols = [min(L, self.attn_len) for L in lengths]
        row_idx = np.asarray(slots, np.int32)

        # one scatter per (n_cols group, leaf), vectorised across slots —
        # a per-slot .at[].set chain would copy the whole pool array once
        # per slot on the host
        by_nc: Dict[int, List[int]] = {}
        for j, nc in enumerate(n_cols):
            by_nc.setdefault(nc, []).append(j)

        def put_paged(pool: jnp.ndarray, pc: jnp.ndarray) -> jnp.ndarray:
            for nc, js in by_nc.items():
                cols = np.arange(nc)
                blks = np.stack([self.block_table[slots[j], cols // bs]
                                 for j in js])              # (nb, nc)
                offs = np.broadcast_to(cols % bs, blks.shape)
                pool = pool.at[:, blks, offs].set(
                    pc[:, np.asarray(js), :nc].astype(pool.dtype))
            return pool

        def put_rows(pool: jnp.ndarray, pc: jnp.ndarray) -> jnp.ndarray:
            return pool.at[:, row_idx].set(
                pc[:, :len(slots)].astype(pool.dtype))

        new_cache: Dict[str, PyTree] = {}
        for pos_key, c in self.cache.items():
            if "attn" in c:
                new_cache[pos_key] = {"attn": jax.tree.map(
                    put_paged, c["attn"], piece[pos_key]["attn"])}
            else:
                new_cache[pos_key] = {"ssm": jax.tree.map(
                    put_rows, c["ssm"], piece[pos_key]["ssm"])}
        self.cache = new_cache
        self.cache_pos[row_idx] = np.asarray(lengths, np.int32)

    # ------------------------------------------------------------ reporting
    def block_bytes(self) -> int:
        """Device bytes of ONE block across all attention leaves."""
        total = 0
        for c in self.cache.values():
            if "attn" in c:
                for leaf in jax.tree.leaves(c["attn"]):
                    total += leaf.nbytes // leaf.shape[1]
        return total

    def peak_kv_bytes(self) -> int:
        """High-watermark of device KV bytes actually holding live pages
        (+ the per-row SSM state, which is always resident)."""
        row_bytes = sum(
            leaf.nbytes for c in self.cache.values() if "ssm" in c
            for leaf in jax.tree.leaves(c["ssm"]))
        return self.peak_blocks * self.block_bytes() + row_bytes
