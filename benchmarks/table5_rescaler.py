"""Table 5/7 — rescaler ablation: learnable s_i vs static k/k_i vs none.

The paper's finding: learnable ≥ none > static in most settings, with the
gap largest at constrained budgets."""
from __future__ import annotations

from .common import emit, run_setting


def run(rounds=3) -> None:
    rows = []
    for budget in ("b3", "b4"):
        for mode in ("learnable", "static", "none"):
            r = run_setting("flame", budget=budget, alpha=0.5, clients=4,
                            rounds=rounds, rescaler=mode)
            rows.append({"budget": budget, "rescaler": mode,
                         "score": r["score"], "test_loss": r["test_loss"],
                         "wall_s": r["wall_s"]})
    emit("table5_rescaler", rows,
         ["budget", "rescaler", "score", "test_loss", "wall_s"])


if __name__ == "__main__":
    run()
