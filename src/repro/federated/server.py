"""Server-side federated orchestration: one round per method.

Implements the four compared methods end-to-end:

  * ``flame``    — distribute full-rank per-expert LoRA; clients train with
                   their k_i; aggregate with Eq. 6–7 (activation-aware).
  * ``trivial``  — every client uses the globally smallest rank; plain
                   FedAvg (the paper's "trivial" baseline: small uniform
                   LoRA for all experts).
  * ``hlora``    — distribute rank-truncated adapters per client budget;
                   sparsity-weighted aggregation over rank components.
  * ``flexlora`` — clients train truncated adapters; server aggregates full
                   ΔW = s·A·B and SVD-refactors back to the server rank.

Round execution (``fed.round_engine``):

  * ``"batched"`` (default) — participants are grouped into budget cohorts
    (see federated/cohort.py) and each cohort's local training runs as ONE
    compiled ``client.cohort_update`` call (vmap or lax.map over the client
    axis).  For FLAME each cohort's stacked adapters and activation counts
    stream into a running accumulator (``core.aggregation.flame_acc_*``)
    as soon as the cohort finishes — device-resident end-to-end, with peak
    aggregation memory bounded by one cohort, not the participant count.
  * ``"looped"`` — the sequential per-client reference oracle (one
    ``client.local_train`` per participant).  Kept as the correctness
    baseline; tests assert the batched path matches it allclose.

Round-loop driver (``fed.round_driver``, FLAME only):

  * ``"host"`` (default, the oracle) — ``run`` iterates :meth:`run_round`
    in Python; every round re-traces nothing but still syncs to the host
    between cohorts and rounds.
  * ``"device"`` — the whole multi-round loop folds into ONE compiled
    ``lax.scan`` program per checkpoint segment: per-round client
    subsampling is pre-drawn on the host with the *same* RNG stream the
    host loop uses (so participant sets match the oracle exactly), budget
    cohorts are re-grouped per round against a static cohort-key set
    (rounds where a cohort is short of its capacity run exact-no-op
    padding slots with zero aggregation weight), client-local rescalers
    live in a device-resident bank gathered/scattered by client slot, and
    aggregation streams cohort accumulators merged hierarchically inside
    the scan body.  ``run(checkpoint_to=...)`` syncs to the host every
    ``fed.checkpoint_every`` rounds to stream a resume-compatible
    checkpoint; otherwise the run is a single program.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import io as ckpt_io
from ..configs.base import FederatedConfig, ModelConfig, TrainConfig
from ..core import aggregation as agg
from ..core import lora as lora_lib
from ..obs.expert_load import ActivationDriftTracker
from ..obs.metrics import MetricsRegistry
from ..obs.trace import NULL_TRACER, PID_FEDERATED, Tracer
from . import client as client_lib
from .cohort import CohortKey, build_cohorts, group_by_key

PyTree = Any

# the paper's budget grids (Appendix A1)
FLAME_BUDGET_K = {"b1": 8, "b2": 4, "b3": 2, "b4": 1}
MOE_BUDGET_RANKS = {"b1": 20, "b2": 12, "b3": 8, "b4": 6}
DENSE_BUDGET_RANKS = {"b1": 40, "b2": 24, "b3": 16, "b4": 12}


@dataclass
class RoundResult:
    round_idx: int
    client_losses: List[float]
    client_freqs: List[Dict[str, np.ndarray]]
    participating: List[int]
    # per-MoE-position activation telemetry for the round (repro.obs):
    # {pos: {"entropy": [per period], "entropy_mean": f, "l1_drift": f|None}}
    # — l1_drift is None on the first observed round (nothing to diff)
    activation_drift: Optional[Dict[str, Dict[str, Any]]] = None


class FederatedServer:
    """Holds the global LoRA state and runs communication rounds.

    ``tracer``/``metrics`` (optional, repro.obs): per-round spans
    (distribute → cohort_update/local_train → aggregate, on the
    federated track) and round metrics (round counter, mean client
    loss, per-position activation entropy + L1 drift).  Activation
    drift itself is always computed — it is host-side arithmetic on
    arrays each round already produced — and stored on
    :class:`RoundResult`.
    """

    def __init__(self, cfg: ModelConfig, params: PyTree, global_lora: PyTree,
                 clients: Sequence[client_lib.ClientState],
                 fed: FederatedConfig, tc: TrainConfig,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.params = params
        self.global_lora = global_lora
        self.clients = list(clients)
        self.fed = fed
        self.tc = tc
        self.history: List[RoundResult] = []
        self._rng = np.random.default_rng(fed.seed + 999)
        self._round_offset = 0        # rounds completed before a resume
        self._drift = ActivationDriftTracker()
        self._metrics = metrics
        self._set_tracer(tracer)

    def _set_tracer(self, tracer: Optional[Tracer]) -> None:
        self._tracer = tracer if tracer is not None else NULL_TRACER
        if self._tracer.enabled:
            self._tracer.process_name(PID_FEDERATED, "federated")
            self._tracer.thread_name(PID_FEDERATED, 0, "rounds")

    # ----------------------------------------------------------- distribution
    def _dist_rank(self, c: client_lib.ClientState) -> int:
        """Rank of the adapter the server distributes to client ``c`` —
        the shape the cohort builder must group by."""
        m = self.fed.method
        if m == "flame":
            return max(cl.rank for cl in self.clients)   # full rank, always
        if m == "trivial":
            return min(cl.rank for cl in self.clients)
        if m in ("hlora", "flexlora"):
            return c.rank
        raise ValueError(f"unknown method {m!r}")

    def _distribute(self, c: client_lib.ClientState) -> PyTree:
        m = self.fed.method
        if m == "flame":
            return self.global_lora                      # full rank, always
        if m == "trivial":
            r_min = min(cl.rank for cl in self.clients)
            return lora_lib.truncate_rank(self.global_lora, r_min)
        if m in ("hlora", "flexlora"):
            return lora_lib.truncate_rank(self.global_lora, c.rank)
        raise ValueError(f"unknown method {m!r}")

    # ------------------------------------------------------------ aggregation
    def _aggregate(self, loras, freqs, sizes: List[float],
                   parts: List[int]) -> PyTree:
        """``loras``/``freqs`` may be Python lists (looped path) or stacked
        trees with a leading client axis (batched FLAME path)."""
        m = self.fed.method
        r_full = max(cl.rank for cl in self.clients)
        if m == "flame":
            # prev_lora: an expert nobody activated this round keeps the
            # previous global adapter instead of collapsing to zero
            return agg.flame_aggregate(loras, freqs, sizes,
                                       self.fed.temperature,
                                       prev_lora=self.global_lora)
        if m == "trivial":
            r_min = min(cl.rank for cl in self.clients)
            small = agg.fedavg(loras, sizes)
            # pad the uniformly-small global back to server rank storage
            return lora_lib.pad_rank(small, r_full)
        if m == "hlora":
            ranks = [self.clients[i].rank for i in parts]
            return agg.hlora_aggregate(loras, ranks, sizes, r_full)
        if m == "flexlora":
            return agg.flexlora_aggregate(loras, sizes, r_full,
                                          self.cfg.lora.scale)
        raise ValueError(m)

    # ----------------------------------------------------------------- rounds
    def _sample_participants(self) -> List[int]:
        n = len(self.clients)
        n_part = max(1, int(round(self.fed.participation * n)))
        return sorted(self._rng.choice(n, size=n_part, replace=False)
                      .tolist())

    def run_round(self, round_idx: int) -> RoundResult:
        tr = self._tracer
        t0 = tr.now()
        if self.fed.round_engine == "looped":
            res = self._run_round_looped(round_idx)
        else:
            res = self._run_round_batched(round_idx)
        res.activation_drift = self._round_drift(res)
        if tr.enabled:
            tr.complete(f"round {round_idx}", t0, tr.now(),
                        pid=PID_FEDERATED, cat="federated",
                        args={"participants": len(res.participating),
                              "method": self.fed.method})
        self._emit_round_metrics(res)
        return res

    def _emit_round_metrics(self, res: RoundResult) -> None:
        """Per-round metrics (repro.obs) — shared by the host loop and the
        device driver's post-segment bookkeeping."""
        if self._metrics is None:
            return
        self._metrics.counter("fed.rounds").inc()
        finite = [l for l in res.client_losses if np.isfinite(l)]
        if finite:
            self._metrics.gauge("fed.round.mean_loss").set(
                float(np.mean(finite)))
        self._metrics.gauge("fed.participants").set(len(res.participating))
        self._drift.publish(self._metrics, res.activation_drift)

    def _round_drift(self, res: RoundResult) -> Dict[str, Dict[str, Any]]:
        """Population activation signal for the round: the unweighted
        mean of participating clients' activation frequencies per MoE
        position (aggregation itself weighs by dataset size; telemetry
        tracks what the cohort as a whole routed), pushed through the
        drift tracker — entropy per period + L1 drift vs the previous
        round."""
        freqs = [f for f in res.client_freqs if f]
        if not freqs:
            return {}
        mean = {pos: np.mean([np.asarray(f[pos], np.float64)
                              for f in freqs], axis=0)
                for pos in freqs[0]}
        return self._drift.update(mean)

    def _run_round_looped(self, round_idx: int) -> RoundResult:
        """Sequential reference path: one local_train call per client."""
        parts = self._sample_participants()
        tr = self._tracer
        loras, freqs, sizes, losses = [], [], [], []
        for i in parts:
            c = self.clients[i]
            with tr.span("distribute", pid=PID_FEDERATED, cat="federated",
                         args={"client": i}):
                dist = self._distribute(c)
            with tr.span("local_train", pid=PID_FEDERATED, cat="federated",
                         args={"client": i, "k": c.k}):
                trained, f, _, info = client_lib.local_train(
                    self.cfg, self.params, dist, c, self.tc,
                    round_seed=self.fed.seed * 1000 + round_idx)
            loras.append(trained)
            freqs.append(f)
            sizes.append(float(c.dataset_size))
            losses.append(info["mean_loss"])

        with tr.span("aggregate", pid=PID_FEDERATED, cat="federated",
                     args={"method": self.fed.method}):
            self.global_lora = self._aggregate(loras, freqs, sizes, parts)
        res = RoundResult(round_idx, losses, freqs, parts)
        self.history.append(res)
        return res

    def _run_round_batched(self, round_idx: int) -> RoundResult:
        """Batched round engine: one compiled cohort_update per budget
        cohort; FLAME aggregation streams each cohort's stacked outputs
        into a running accumulator (core.aggregation.flame_acc_*), so the
        round's peak aggregation footprint is one cohort plus one
        adapter-tree-sized accumulator — it no longer grows with the
        participant count."""
        parts = self._sample_participants()
        round_seed = self.fed.seed * 1000 + round_idx
        part_clients = [self.clients[i] for i in parts]
        sizes = [float(c.dataset_size) for c in part_clients]
        cohorts = build_cohorts(part_clients, self.tc,
                                rank_of=self._dist_rank)

        # per-participant results, keyed by position in `parts`
        loras_by_pos: Dict[int, PyTree] = {}
        freqs_by_pos: Dict[int, Dict[str, np.ndarray]] = {}
        losses_by_pos: Dict[int, float] = {}
        # FLAME: streaming accumulator, fed cohort-by-cohort
        flame_acc = (agg.flame_acc_init(self.global_lora)
                     if self.fed.method == "flame" else None)

        tr = self._tracer
        for ci, co in enumerate(cohorts):
            members = [part_clients[i] for i in co.members]
            with tr.span("distribute", pid=PID_FEDERATED, cat="federated",
                         args={"cohort": ci, "clients": len(members)}):
                trainables = [lora_lib.make_trainable(self._distribute(c),
                                                      c.rescaler)
                              for c in members]
                stacked_tr = lora_lib.stack_adapters(trainables)
                plan = client_lib.stack_plans(
                    [client_lib.make_batch_plan(c, self.tc, round_seed)
                     for c in members])
            rescaler_trainable = (co.key[4] == "learnable")
            with tr.span("cohort_update", pid=PID_FEDERATED,
                         cat="federated",
                         args={"cohort": ci, "k": co.k,
                               "clients": len(members)}):
                out_tr, counts, tok, loss_sum, n_valid = \
                    client_lib.cohort_update(
                        self.cfg, self.params, stacked_tr,
                        jnp.asarray(plan.tokens), jnp.asarray(plan.labels),
                        jnp.asarray(plan.mask), jnp.asarray(plan.valid),
                        k=co.k, tc=self.tc,
                        rescaler_trainable=rescaler_trainable,
                        backend=self.fed.cohort_backend)

            # stacked activation frequencies {pos: (C, n_periods, E)}
            denom = jnp.maximum(tok, 1.0)[:, None, None]
            freqs = {pos: c / denom for pos, c in counts.items()}

            if "rescaler" in out_tr:
                for c, r in zip(members,
                                lora_lib.unstack_adapters(
                                    out_tr["rescaler"], len(members))):
                    c.rescaler = r                       # persist s_i locally

            # nan (not 0.0) for zero-valid-step clients — the looped
            # reference path reports nan via local_train; the engines must
            # agree on this edge case too
            n_valid_np = np.asarray(n_valid)
            loss_means = np.where(
                n_valid_np > 0,
                np.asarray(loss_sum) / np.maximum(n_valid_np, 1.0),
                np.nan)
            for j, pos in enumerate(co.members):
                losses_by_pos[pos] = float(loss_means[j])
                freqs_by_pos[pos] = {p: np.asarray(f[j])
                                     for p, f in freqs.items()}

            if self.fed.method == "flame":
                # stream this cohort into the running sums — the stacked
                # trees are released as soon as the update is consumed
                flame_acc = agg.flame_acc_update(
                    flame_acc, out_tr["lora"], freqs,
                    jnp.asarray([sizes[pos] for pos in co.members],
                                jnp.float32),
                    self.fed.temperature)
            else:
                for j, pos in enumerate(co.members):
                    loras_by_pos[pos] = jax.tree.map(lambda l, j=j: l[j],
                                                     out_tr["lora"])

        with tr.span("aggregate", pid=PID_FEDERATED, cat="federated",
                     args={"method": self.fed.method}):
            if self.fed.method == "flame":
                self.global_lora = agg.flame_acc_finalize(
                    flame_acc, prev_lora=self.global_lora)
            else:
                loras = [loras_by_pos[i] for i in range(len(parts))]
                freqs_l = [freqs_by_pos[i] for i in range(len(parts))]
                self.global_lora = self._aggregate(loras, freqs_l, sizes,
                                                   parts)

        res = RoundResult(round_idx,
                          [losses_by_pos[i] for i in range(len(parts))],
                          [freqs_by_pos[i] for i in range(len(parts))],
                          parts)
        self.history.append(res)
        return res

    # ---------------------------------------------------- device round driver
    def _device_validate(self) -> None:
        """The device driver folds rounds into one lax.scan program — only
        the FLAME path (streaming accumulator, uniform full-rank
        distribution) lowers to it."""
        if self.fed.method != "flame":
            raise ValueError(
                "round_driver='device' supports method='flame' only "
                f"(got {self.fed.method!r}) — the compression baselines "
                "need host-side rank surgery between rounds")
        if self.fed.round_engine != "batched":
            raise ValueError(
                "round_driver='device' requires round_engine='batched' "
                f"(got {self.fed.round_engine!r})")
        if self.fed.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        has = [c.rescaler is not None for c in self.clients]
        if any(has) and not all(has):
            raise ValueError(
                "round_driver='device' needs homogeneous rescaler presence "
                "across the registry (the rescaler bank is one stacked "
                "tree) — got a mix of with/without")

    def _prep_device_rounds(self, start: int):
        """Host-side prep for rounds ``[start, fed.rounds)``.

        Draws every remaining round's participant set from the *same* RNG
        stream the host loop would consume (`_sample_participants`), groups
        each round's participants by cohort key, and fixes the static
        cohort-key set of the scanned program: the union of keys over all
        remaining rounds, each with capacity = the max member count it
        reaches in any round.  Rounds where a key runs below capacity (or
        not at all) fill the gap with ``client.empty_plan`` slots — every
        step invalid (the local update is an exact no-op) and dataset size
        0 (zero aggregation weight), so padded execution is equivalent to
        the host loop's exact per-round cohorts.

        Returns ``(keys, caps, xs, meta)``: ``xs[f"k{i}"]`` holds arrays
        with a leading round axis (tokens/labels/mask/valid slot-stacked
        plans, ``slot`` client-registry ids with ``n_clients`` marking
        padding, ``size`` fp32 dataset sizes); ``meta[r]`` maps scan
        outputs back to participant order.
        """
        rounds = list(range(start, self.fed.rounds))
        per_round = []
        for r in rounds:
            parts = self._sample_participants()
            part_clients = [self.clients[i] for i in parts]
            order, members = group_by_key(part_clients, self.tc,
                                          rank_of=self._dist_rank)
            per_round.append((r, parts, order, members))

        # static key set: first-appearance order across all rounds
        keys: List[CohortKey] = []
        for _, _, order, _ in per_round:
            for key in order:
                if key not in keys:
                    keys.append(key)
        caps = [max(len(members.get(key, []))
                    for _, _, _, members in per_round) for key in keys]

        # materialise every (round, key) plan list; track per-key max steps
        plans: Dict[int, List[List[client_lib.BatchPlan]]] = {
            i: [] for i in range(len(keys))}
        steps = [1] * len(keys)
        for r, parts, _, members in per_round:
            seed = self.fed.seed * 1000 + r
            for i, key in enumerate(keys):
                ps = [client_lib.make_batch_plan(
                          self.clients[parts[pos]], self.tc, seed)
                      for pos in members.get(key, [])]
                steps[i] = max([steps[i]] + [p.n_steps for p in ps])
                plans[i].append(ps)

        xs: Dict[str, Dict[str, np.ndarray]] = {}
        meta = []
        for ri, (r, parts, _, members) in enumerate(per_round):
            meta.append({"round": r, "parts": parts,
                         "members": {i: members.get(keys[i], [])
                                     for i in range(len(keys))}})
        n_clients = len(self.clients)
        for i, key in enumerate(keys):
            template = client_lib.pad_plan(
                next(p for ps in plans[i] for p in ps), steps[i])
            toks, labs, msks, vals, slots, sizes = [], [], [], [], [], []
            for ri, (r, parts, _, members) in enumerate(per_round):
                padded = [client_lib.pad_plan(p, steps[i])
                          for p in plans[i][ri]]
                mem = members.get(key, [])
                pad_n = caps[i] - len(padded)
                padded += [client_lib.empty_plan(template)] * pad_n
                stacked = client_lib.stack_plans(padded)
                toks.append(stacked.tokens)
                labs.append(stacked.labels)
                msks.append(stacked.mask)
                vals.append(stacked.valid)
                slots.append(np.asarray(
                    [parts[pos] for pos in mem] + [n_clients] * pad_n,
                    np.int32))
                sizes.append(np.asarray(
                    [float(self.clients[parts[pos]].dataset_size)
                     for pos in mem] + [0.0] * pad_n, np.float32))
            xs[f"k{i}"] = {"tokens": np.stack(toks),
                           "labels": np.stack(labs),
                           "mask": np.stack(msks),
                           "valid": np.stack(vals),
                           "slot": np.stack(slots),
                           "size": np.stack(sizes)}
        return keys, caps, xs, meta

    def _device_segment_fn(self, keys: List[CohortKey], caps: List[int]):
        """Build the jitted multi-round program: ``lax.scan`` over a
        segment's rounds; the body runs every static cohort (unrolled —
        cohort ``k`` is a jit static arg), streams each cohort into its own
        accumulator, left-fold-merges the cohort accumulators (two-level
        hierarchical combination; bitwise equal to the host loop's
        sequential streaming because merging with a zero-initialised
        accumulator is exact), finalizes against the carried global
        adapter, and carries ``(global_lora, rescaler_bank)`` to the next
        round."""
        cfg, tc, fed = self.cfg, self.tc, self.fed
        n_clients = len(self.clients)

        def body(params, carry, x):
            gl, bank = carry
            accs, outs = [], {}
            for i, key in enumerate(keys):
                k, _rank, _bs, has_resc, mode = key
                xk = x[f"k{i}"]
                slot = xk["slot"]
                stacked_tr = {"lora": jax.tree.map(
                    lambda l: jnp.broadcast_to(l, (caps[i],) + l.shape),
                    gl)}
                if has_resc:
                    # padding slots gather an arbitrary (clamped) bank row;
                    # their update is a no-op and the scatter drops them
                    stacked_tr["rescaler"] = jax.tree.map(
                        lambda l: l[jnp.minimum(slot, n_clients - 1)], bank)
                out_tr, counts, tok, loss_sum, n_valid = \
                    client_lib.cohort_update(
                        cfg, params, stacked_tr,
                        xk["tokens"], xk["labels"], xk["mask"], xk["valid"],
                        k=k, tc=tc,
                        rescaler_trainable=(mode == "learnable"),
                        backend=fed.cohort_backend)
                if has_resc:
                    bank = jax.tree.map(
                        lambda bl, nl: bl.at[slot].set(nl, mode="drop"),
                        bank, out_tr["rescaler"])
                denom = jnp.maximum(tok, 1.0)[:, None, None]
                freqs = {pos: c / denom for pos, c in counts.items()}
                accs.append(agg.flame_acc_update(
                    agg.flame_acc_init(gl), out_tr["lora"], freqs,
                    xk["size"], fed.temperature))
                outs[f"k{i}"] = {"loss_sum": loss_sum, "n_valid": n_valid,
                                 "tok": tok, "counts": counts}
            acc = accs[0]
            for a in accs[1:]:
                acc = agg.flame_acc_merge(acc, a)
            gl = agg.flame_acc_finalize(acc, prev_lora=gl)
            return (gl, bank), outs

        @jax.jit
        def seg(params, global_lora, bank, xs):
            return jax.lax.scan(lambda c, x: body(params, c, x),
                                (global_lora, bank), xs)

        return seg

    def _device_round_result(self, j: int, ys, meta_row) -> RoundResult:
        """Rebuild one round's :class:`RoundResult` from scan outputs —
        row ``j`` of the segment, mapped back to participant order."""
        parts = meta_row["parts"]
        losses: Dict[int, float] = {}
        freqs: Dict[int, Dict[str, np.ndarray]] = {}
        for i, mem in meta_row["members"].items():
            yk = ys[f"k{i}"]
            loss_sum = np.asarray(yk["loss_sum"][j])
            n_valid = np.asarray(yk["n_valid"][j])
            tok = np.asarray(yk["tok"][j])
            counts = {pos: np.asarray(c[j]) for pos, c in yk["counts"].items()}
            for s, pos in enumerate(mem):
                losses[pos] = (float(loss_sum[s]) / float(n_valid[s])
                               if n_valid[s] > 0 else float("nan"))
                freqs[pos] = {p: c[s] / max(float(tok[s]), 1.0)
                              for p, c in counts.items()}
        return RoundResult(meta_row["round"],
                           [losses[i] for i in range(len(parts))],
                           [freqs[i] for i in range(len(parts))],
                           parts)

    def _run_rounds_device(self, start: int,
                           checkpoint_to: Optional[str]) -> List[RoundResult]:
        """Drive rounds ``[start, fed.rounds)`` as scanned device programs,
        one segment per ``checkpoint_every`` rounds when checkpointing
        (host sync points), else one program for the whole run."""
        self._device_validate()
        if start >= self.fed.rounds:
            return []
        keys, caps, xs, meta = self._prep_device_rounds(start)

        if self.clients and self.clients[0].rescaler is not None:
            bank = jax.tree.map(lambda *ls: jnp.stack(ls),
                                *[c.rescaler for c in self.clients])
        else:
            bank = {}
        gl = jax.tree.map(jnp.asarray, self.global_lora)

        n_rounds = self.fed.rounds - start
        seg_len = (min(self.fed.checkpoint_every, n_rounds)
                   if checkpoint_to else n_rounds)
        seg_fns: Dict[int, Any] = {}          # one compile per segment length
        tr = self._tracer
        out: List[RoundResult] = []
        for a in range(start, self.fed.rounds, seg_len):
            b = min(a + seg_len, self.fed.rounds)
            sl = slice(a - start, b - start)
            xs_seg = {kk: {name: jnp.asarray(arr[sl])
                           for name, arr in d.items()}
                      for kk, d in xs.items()}
            n_seg = b - a
            if n_seg not in seg_fns:
                seg_fns[n_seg] = self._device_segment_fn(keys, caps)
            t0 = tr.now()
            (gl, bank), ys = seg_fns[n_seg](self.params, gl, bank, xs_seg)
            jax.block_until_ready(gl)
            t1 = tr.now()

            self.global_lora = gl
            # persist bank rows back into client-local state so
            # checkpoints (and later host-driver rounds) see trained s_i
            if bank:
                for i, c in enumerate(self.clients):
                    c.rescaler = jax.tree.map(lambda l, i=i: l[i], bank)
            ys_host = jax.tree.map(np.asarray, ys)
            for j in range(n_seg):
                res = self._device_round_result(j, ys_host, meta[a - start + j])
                res.activation_drift = self._round_drift(res)
                self.history.append(res)
                out.append(res)
                if tr.enabled:
                    # segment wall-clock amortized evenly over its rounds —
                    # the scan has no per-round host sync to time exactly
                    rt0 = t0 + (t1 - t0) * j / n_seg
                    rt1 = t0 + (t1 - t0) * (j + 1) / n_seg
                    tr.complete(f"round {res.round_idx}", rt0, rt1,
                                pid=PID_FEDERATED, cat="federated",
                                args={"participants": len(res.participating),
                                      "method": self.fed.method,
                                      "driver": "device", "amortized": True})
                self._emit_round_metrics(res)
            if checkpoint_to:
                self.save_checkpoint(checkpoint_to)
        return out

    # ------------------------------------------------------------ checkpoints
    def save_checkpoint(self, path: str) -> None:
        """Persist the round-resumable federated state: the global LoRA,
        every client's local rescaler ``s_i`` (client-local state the
        server would otherwise lose), and the next round index."""
        ckpt_io.save(path, {"global_lora": self.global_lora,
                            "rescalers": [c.rescaler for c in self.clients]},
                     meta={"round_idx": self._round_offset + len(self.history),
                           "method": self.fed.method,
                           "num_clients": len(self.clients)})

    def restore_checkpoint(self, path: str) -> int:
        """Load a checkpoint into the server; returns the round to resume
        from.  The participant-sampling RNG is replayed past the completed
        rounds so a resumed run samples the same cohorts a straight-through
        run would."""
        tree, meta = ckpt_io.load(path)
        if (meta is None or "num_clients" not in meta
                or "global_lora" not in tree):
            raise ValueError(
                f"{path} is not a FederatedServer checkpoint (legacy or "
                "foreign format) — re-create it with save_checkpoint / "
                "run(checkpoint_to=...)")
        assert meta["num_clients"] == len(self.clients), \
            (meta["num_clients"], len(self.clients))
        assert meta["method"] == self.fed.method, \
            (meta["method"], self.fed.method)
        self.global_lora = ckpt_io.to_device(tree["global_lora"])
        for c, r in zip(self.clients, tree["rescalers"]):
            c.rescaler = None if r is None else ckpt_io.to_device(r)
        start = int(meta["round_idx"])
        self._round_offset = start
        for _ in range(start):
            self._sample_participants()
        return start

    def run(self, resume_from: Optional[str] = None,
            checkpoint_to: Optional[str] = None,
            metrics_to: Optional[str] = None,
            trace_to: Optional[str] = None) -> List[RoundResult]:
        """Run (the remaining) rounds.

        ``resume_from``: checkpoint path written by :meth:`save_checkpoint`
        (or by a previous ``run(checkpoint_to=...)``) — loads (global LoRA,
        rescalers, round idx) and continues from there;
        ``checkpoint_to``: write a checkpoint after every completed round
        (host driver) or every ``fed.checkpoint_every`` rounds (device
        driver — the segment boundaries are the host sync points).

        ``metrics_to``/``trace_to``: observability outputs — a registry
        snapshot (JSON) and a Chrome trace-event file of the round spans,
        written when the rounds finish.  Each creates the corresponding
        repro.obs object on demand when the server was constructed
        without one.
        """
        if metrics_to and self._metrics is None:
            self._metrics = MetricsRegistry()
        if trace_to and not self._tracer.enabled:
            self._set_tracer(Tracer())
        start = self.restore_checkpoint(resume_from) if resume_from else 0
        if self.fed.round_driver == "device":
            out = self._run_rounds_device(start, checkpoint_to)
        else:
            out = []
            for r in range(start, self.fed.rounds):
                out.append(self.run_round(r))
                if checkpoint_to:
                    self.save_checkpoint(checkpoint_to)
        if metrics_to:
            self._metrics.dump(metrics_to)
        if trace_to:
            self._tracer.dump(trace_to)
        return out
