"""Dispatch-equivalence suite: the ragged (sort-based) MoE dispatch is
bit-consistent with the loss-free oracles, for outputs AND gradients.

Three independent implementations of "route every selected token":

* **ragged** — counting-sort dispatch (kernels/ragged_dispatch.py), the
  serving default: loss-free AND budget-proportional;
* **dense no-drop** — one-hot dispatch with capacity pinned to the token
  count (``dispatch="dense"``), the pre-ragged loss-free mode;
* **naive** — a per-token numpy float64 loop straight off the math:
  softmax -> top-k -> renormalise -> sum of expert FFNs.

The differential sweeps both kernel backends (reference jnp / Pallas
interpreter), k in {1, 2, full}, mixed per-slot budget tuples, and
prefill/decode shapes; gradients flow through the ragged ops' custom_vjp
(kernel forward, reference backward).  GShard-capacity dispatch joins the
equivalence class whenever its capacity provably does not bind.

The property section (hypothesis under the derandomized CI profile — see
tests/test_properties.py — with an always-on seeded sweep of the same
drivers) locks the dispatch invariants: token conservation, permutation
invariance, and free-slot isolation.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_moe
from repro.configs.base import KernelConfig
from repro.kernels import ragged_dispatch as ragged_mod
from repro.kernels.ref import adaptive_topk_router_ref
from repro.models import model as M
from repro.models import moe_layer

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

CFG = tiny_moe()                       # 4 experts, top_k 2, fp32
E = CFG.moe.num_experts
D = CFG.d_model
H = CFG.moe.d_expert
KEY = jax.random.PRNGKey(0)
P = moe_layer.init_moe(KEY, CFG)
LORA_SCALE = 0.5
BACKENDS = ("reference", "pallas")
SHAPES = ((6, 1), (2, 8))              # decode-like, prefill-like


def _make_lora(key, rank: int = 2) -> dict:
    ks = jax.random.split(key, 6)
    mk = lambda k_, i, o: jax.random.normal(k_, (E, i, o), jnp.float32) * .05
    return {"experts": {
        "w1": {"a": mk(ks[0], D, rank), "b": mk(ks[1], rank, H)},
        "w3": {"a": mk(ks[2], D, rank), "b": mk(ks[3], rank, H)},
        "w2": {"a": mk(ks[4], H, rank), "b": mk(ks[5], rank, D)},
    }}


LORA = _make_lora(jax.random.fold_in(KEY, 7))


def _cfg(backend: str):
    return CFG.replace(kernels=KernelConfig(backend=backend))


def _x(key, B, S):
    return jax.random.normal(key, (B, S, D), jnp.float32)


# ==========================================================================
# the naive per-token loop oracle (numpy float64)
# ==========================================================================

def naive_moe(x, k_tok, *, lora=None, lora_scale: float = 0.0):
    """Per-token reference straight off the math, in float64: softmax over
    experts, iterative-argmax top-``k_tok[t]``, renormalise, sum the
    selected experts' SwiGLU FFNs (+ LoRA bypass) weighted by the
    renormalised probabilities."""
    B, S, _ = x.shape
    xv = np.asarray(x, np.float64).reshape(-1, D)
    router = np.asarray(P["router"], np.float64)
    exp = {n: np.asarray(P["experts"][n], np.float64)
           for n in ("w1", "w3", "w2")}
    lp = {}
    if lora is not None:
        lp = {n: (np.asarray(lora["experts"][n]["a"], np.float64),
                  np.asarray(lora["experts"][n]["b"], np.float64))
              for n in ("w1", "w3", "w2")}

    def mm(v, name, e):
        y = v @ exp[name][e]
        if lp:
            a, b = lp[name]
            y = y + (v @ a[e]) @ b[e] * lora_scale
        return y

    out = np.zeros_like(xv)
    for t, xt in enumerate(xv):
        logits = xt @ router
        probs = np.exp(logits - logits.max())
        probs /= probs.sum()
        sel = np.argsort(-probs, kind="stable")[:int(k_tok[t])]
        if len(sel) == 0:
            continue
        w = probs[sel] / probs[sel].sum()
        for e, wi in zip(sel, w):
            gate = mm(xt, "w1", e)
            up = mm(xt, "w3", e)
            h = (gate / (1.0 + np.exp(-gate))) * up      # silu(gate) * up
            out[t] += wi * mm(h, "w2", e)
    return out.reshape(B, S, D)


def _k_tok(k, B, S):
    ks = (k,) * B if isinstance(k, int) else k
    return np.repeat(np.asarray(ks, np.int64), S)


# ==========================================================================
# three-way differential: ragged == dense no-drop == naive loop
# ==========================================================================

@pytest.mark.parametrize("shape", SHAPES, ids=["decode", "prefill"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_three_way_outputs(backend, shape):
    B, S = shape
    cfg = _cfg(backend)
    x = _x(jax.random.fold_in(KEY, 11 * B + S), B, S)
    mixed = tuple(([1, 2, E] * B)[:B])
    for k in (1, 2, E, mixed):
        dense, _ = moe_layer.apply_moe(P, cfg, x, k=k, dispatch="dense",
                                       lora=LORA, lora_scale=LORA_SCALE)
        ragged, _ = moe_layer.apply_moe(P, cfg, x, k=k, dispatch="ragged",
                                        lora=LORA, lora_scale=LORA_SCALE)
        np.testing.assert_allclose(np.asarray(ragged), np.asarray(dense),
                                   rtol=1e-5, atol=1e-6)
        want = naive_moe(x, _k_tok(k, B, S), lora=LORA,
                         lora_scale=LORA_SCALE)
        np.testing.assert_allclose(np.asarray(ragged), want,
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_three_way_grads(backend):
    """Gradients through the ragged custom_vjp ops equal the dense
    no-drop gradients — w.r.t. tokens, router, expert weights, AND the
    LoRA factors — for uniform and mixed per-slot budgets."""
    cfg = _cfg(backend)
    B, S = 4, 2
    x = _x(jax.random.fold_in(KEY, 21), B, S)
    cot = jax.random.normal(jax.random.fold_in(KEY, 22), (B, S, D))

    def loss(p_, x_, lora_, k, mode):
        out, _ = moe_layer.apply_moe(p_, cfg, x_, k=k, dispatch=mode,
                                     lora=lora_, lora_scale=LORA_SCALE)
        return (out * cot).sum()

    for k in (2, (1, 2, E, 1)):
        gd = jax.grad(loss, argnums=(0, 1, 2))(P, x, LORA, k, "dense")
        gr = jax.grad(loss, argnums=(0, 1, 2))(P, x, LORA, k, "ragged")
        for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(gr)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-4, atol=1e-5)


def test_ragged_grads_backend_parity():
    """The pallas path's gradients are the reference gradients evaluated
    at kernel-forward primals (backend.py contract) — so the two backends
    must agree on the ragged path like they do on every other op."""
    B, S = 4, 2
    x = _x(jax.random.fold_in(KEY, 31), B, S)
    cot = jax.random.normal(jax.random.fold_in(KEY, 32), (B, S, D))

    def loss(p_, x_, lora_, backend):
        out, _ = moe_layer.apply_moe(p_, _cfg(backend), x_, k=(1, 2, 2, E),
                                     dispatch="ragged", lora=lora_,
                                     lora_scale=LORA_SCALE)
        return (out * cot).sum()

    g_ref = jax.grad(loss, argnums=(0, 1, 2))(P, x, LORA, "reference")
    g_pl = jax.grad(loss, argnums=(0, 1, 2))(P, x, LORA, "pallas")
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pl)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_capacity_parity_when_not_binding(backend):
    """GShard-capacity dispatch joins the equivalence class whenever its
    capacity provably cannot bind: with capacity_factor = E the queue
    capacity exceeds the total assignment count, so no token can drop."""
    cfg = _cfg(backend).replace(moe=dataclasses.replace(
        CFG.moe, capacity_factor=float(E)))
    B, S = 4, 4
    x = _x(jax.random.fold_in(KEY, 41), B, S)
    for k in (1, 2):
        # premise check: C = assignments·E/E + 1 > any per-expert count
        n_assign = B * S * k
        C = moe_layer._capacity(B * S, E, k, float(E))
        assert C > n_assign
        cap, _ = moe_layer.apply_moe(P, cfg, x, k=k, dispatch="capacity")
        rag, _ = moe_layer.apply_moe(P, cfg, x, k=k, dispatch="ragged")
        np.testing.assert_allclose(np.asarray(rag), np.asarray(cap),
                                   rtol=1e-5, atol=1e-6)


def test_model_level_dispatch_threading():
    """prefill/decode_step thread ``dispatch`` end to end: ragged and
    dense produce the same logits and the same decode caches."""
    prompts = jnp.asarray(np.random.default_rng(3).integers(
        0, CFG.vocab_size, (2, 6)), jnp.int32)
    lr, cr = M.prefill(CFG, P_MODEL, prompts, k=2, cache_len=8,
                       dispatch="ragged")
    ld, cd = M.prefill(CFG, P_MODEL, prompts, k=2, cache_len=8,
                       dispatch="dense")
    np.testing.assert_allclose(np.asarray(lr), np.asarray(ld),
                               rtol=1e-5, atol=1e-6)
    tok = jnp.argmax(lr, axis=-1).astype(jnp.int32)
    sr, _ = M.decode_step(CFG, P_MODEL, cr, tok, 6, k=(1, 2),
                          dispatch="ragged")
    sd, _ = M.decode_step(CFG, P_MODEL, cd, tok, 6, k=(1, 2),
                          dispatch="dense")
    np.testing.assert_allclose(np.asarray(sr), np.asarray(sd),
                               rtol=1e-5, atol=1e-6)


P_MODEL = M.init_params(jax.random.PRNGKey(5), CFG)


# ==========================================================================
# dispatch invariants (hypothesis in CI, seeded sweep everywhere)
# ==========================================================================

def _random_routing(seed: int):
    """Random adaptive routing instance: (T, E) router outputs plus the
    per-token budget vector (0 = masked out entirely)."""
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 13))
    logits = jnp.asarray(rng.normal(size=(T, E)), jnp.float32)
    k_tok = jnp.asarray(rng.integers(0, E + 1, (T,)), jnp.int32)
    weights, mask, _ = adaptive_topk_router_ref(logits, k_tok, max_k=E)
    return T, k_tok, weights, mask


def _drive_token_conservation(seed: int) -> None:
    """Every selected (token, expert) pair occupies exactly one live
    buffer row of the right expert segment; the inverse plan visits each
    such row exactly once with the router's combine weight; nothing else
    is live."""
    T, k_tok, weights, mask = _random_routing(seed)
    bm = ragged_mod.BLOCK_M
    plan = ragged_mod.ragged_plan(mask, weights, budget=T * E, max_k=E)
    src = np.asarray(plan.src)
    valid = np.asarray(plan.valid)
    be = np.asarray(plan.block_expert)
    rows = np.asarray(plan.rows)
    wrank = np.asarray(plan.wrank)
    m = np.asarray(mask)
    w = np.asarray(weights)

    # forward plan: live rows <-> selected assignments, 1:1
    live = {(int(src[i]), int(be[i // bm]))
            for i in range(len(src)) if valid[i]}
    selected = {(t, e) for t in range(T) for e in range(E) if m[t, e] > 0}
    assert valid.sum() == m.sum() == len(live)
    assert live == selected

    # inverse plan: each token's live ranks hit distinct rows of its own
    # assignments, carrying exactly the router weight for that expert
    for t in range(T):
        hit = [(int(rows[t, j]), float(wrank[t, j]))
               for j in range(E) if wrank[t, j] > 0]
        assert len({r for r, _ in hit}) == len(hit) == int(k_tok[t])
        for r, wt in hit:
            assert src[r] == t and valid[r]
            e = int(be[r // bm])
            np.testing.assert_allclose(wt, w[t, e], rtol=1e-6)
        # combining all-ones expert outputs yields the weight sum: one
        # combine per selected token, total weight exactly 1
        np.testing.assert_allclose(
            wrank[t].sum(), 1.0 if int(k_tok[t]) else 0.0, rtol=1e-5,
            atol=1e-7)


def _drive_permutation_invariance(seed: int) -> None:
    """Shuffling rows of a decode batch (and their budgets) permutes the
    outputs identically — dispatch order is invisible."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(2, 9))
    ks = tuple(int(v) for v in rng.integers(1, E + 1, (B,)))
    x = jnp.asarray(rng.normal(size=(B, 1, D)), jnp.float32)
    perm = rng.permutation(B)
    out, _ = moe_layer.apply_moe(P, CFG, x, k=ks, dispatch="ragged")
    out_p, _ = moe_layer.apply_moe(
        P, CFG, x[perm], k=tuple(ks[i] for i in perm), dispatch="ragged")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out)[perm],
                               rtol=1e-6, atol=1e-7)


def _drive_free_slot_isolation(seed: int) -> None:
    """slot_mask-zeroed rows can never influence live rows: filling the
    masked rows with arbitrary garbage leaves the live rows' outputs
    byte-identical, and the masked rows' outputs zero."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(3, 9))
    n_dead = int(rng.integers(1, B))
    mask_np = np.ones((B,), np.float32)
    mask_np[rng.choice(B, n_dead, replace=False)] = 0.0
    slot_mask = jnp.asarray(mask_np)
    base = rng.normal(size=(B, 1, D))
    fills = [base.copy(), base.copy()]
    fills[1][mask_np == 0] = rng.normal(size=(n_dead, 1, D)) * 100.0
    outs = []
    for f in fills:
        out, _ = moe_layer.apply_moe(P, CFG, jnp.asarray(f, jnp.float32),
                                     k=2, slot_mask=slot_mask,
                                     dispatch="ragged")
        outs.append(np.asarray(out))
    np.testing.assert_array_equal(outs[0][mask_np > 0],
                                  outs[1][mask_np > 0])
    np.testing.assert_allclose(outs[1][mask_np == 0], 0.0)


# seeded sweep: always runs, hypothesis or not
@pytest.mark.parametrize("seed", range(10))
def test_token_conservation_seeded(seed):
    _drive_token_conservation(seed)


@pytest.mark.parametrize("seed", range(6))
def test_permutation_invariance_seeded(seed):
    _drive_permutation_invariance(seed)


@pytest.mark.parametrize("seed", range(6))
def test_free_slot_isolation_seeded(seed):
    _drive_free_slot_isolation(seed)


if HAVE_HYPOTHESIS:
    # same deterministic profile as tests/test_properties.py: derandomized,
    # bounded examples, no deadline
    _SETTINGS = settings(max_examples=25, deadline=None, derandomize=True)

    @_SETTINGS
    @given(st.integers(0, 2 ** 32 - 1))
    def test_token_conservation_hypothesis(seed):
        _drive_token_conservation(seed)

    @_SETTINGS
    @given(st.integers(0, 2 ** 32 - 1))
    def test_permutation_invariance_hypothesis(seed):
        _drive_permutation_invariance(seed)

    @_SETTINGS
    @given(st.integers(0, 2 ** 32 - 1))
    def test_free_slot_isolation_hypothesis(seed):
        _drive_free_slot_isolation(seed)
