import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Perf-loop profile view: compile one (arch × shape) and print the top
collectives by trip-weighted bytes with jaxpr provenance.

  PYTHONPATH=src python -m repro.launch.inspect_pair llama3-405b train_4k \
      [--multi-pod] [--n-micro 8] [--act-mode seq]
"""
import argparse
import json

from . import hlo_parse
from .dryrun import run_pair


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--act-mode", default=None)
    ap.add_argument("--mesh", default=None, help="e.g. 64x4")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    overrides = {}
    if args.n_micro is not None:
        overrides["n_micro"] = args.n_micro
    if args.act_mode is not None:
        overrides["act_mode"] = args.act_mode

    mesh_shape = (tuple(int(x) for x in args.mesh.split("x"))
                  if args.mesh else None)
    rec = run_pair(args.arch, args.shape, multi_pod=args.multi_pod,
                   overrides=overrides, keep_hlo=True,
                   mesh_shape=mesh_shape)
    hlo = rec.pop("hlo_text")
    r = rec["roofline"]
    print(f"\n{args.arch} × {args.shape} ({rec['mesh']}): "
          f"peak {rec['memory']['peak_gb']:.2f} GB | "
          f"tc {r['t_compute_ms']:.0f} tm {r['t_memory_ms']:.0f} "
          f"tx {r['t_collective_ms']:.0f} ms | knobs {rec['meta'].get('n_micro'), rec['meta'].get('act_mode'), rec['meta'].get('num_groups')}")
    print(f"\ntop {args.top} collectives (trip-weighted):")
    print(f"{'kind':20s} {'GB_total':>9s} {'mult':>7s} {'shape':28s} op_name")
    for row in hlo_parse.top_collectives(hlo, args.top):
        print(f"{row['kind']:20s} {row['bytes_total']/2**30:9.2f} "
              f"{row['mult']:7.0f} {row['shape'][:28]:28s} "
              f"{row['op_name'][-80:]}")


if __name__ == "__main__":
    main()
