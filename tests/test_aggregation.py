"""Aggregation-scheme tests — the paper's §5 edge-case analysis, verified.

  * t = 0       -> FLAME aggregation ≡ standard FedAvg (Eq. 3–4);
  * zero freq   -> that client contributes NOTHING to that expert;
  * full freq   -> dataset-size weighting (plain FedAvg weights);
  * HLoRA       -> rank components average only over clients that trained them;
  * FlexLoRA    -> ΔW-space FedAvg reproduced through the SVD refactor.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core import lora as L

E, NP, D, R = 4, 1, 8, 4        # experts, periods, dim, rank


def _client_lora(seed):
    key = jax.random.PRNGKey(seed)
    return {"blocks": {"pos0": {"moe": {"experts": {
        "w1": {"a": jax.random.normal(key, (NP, E, D, R)),
               "b": jax.random.normal(jax.random.fold_in(key, 1),
                                      (NP, E, R, D))},
    }}, "attn": {"wq": {"a": jax.random.normal(jax.random.fold_in(key, 2),
                                               (NP, D, R)),
                        "b": jnp.zeros((NP, R, D))}}}}}


def _freq(values):
    return {"pos0": jnp.broadcast_to(jnp.asarray(values, jnp.float32),
                                     (NP, E))}


def test_t0_equals_fedavg():
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [10.0, 30.0]
    freqs = [_freq([0.9, 0.1, 0.5, 0.0]), _freq([0.2, 0.8, 0.5, 1.0])]
    flame = agg.flame_aggregate(loras, freqs, sizes, temperature=0)
    fed = agg.fedavg(loras, sizes)
    for a, b in zip(jax.tree.leaves(flame), jax.tree.leaves(fed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_zero_activation_contributes_nothing():
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [10.0, 10.0]
    # client 0 never activated expert 2; client 1 always did
    freqs = [_freq([0.5, 0.5, 0.0, 0.5]), _freq([0.5, 0.5, 1.0, 0.5])]
    out = agg.flame_aggregate(loras, freqs, sizes, temperature=2)
    got = out["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"][:, 2]
    want = loras[1]["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"][:, 2]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_full_activation_reduces_to_dataset_weighting():
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [10.0, 30.0]
    freqs = [_freq([1.0] * E), _freq([1.0] * E)]
    out = agg.flame_aggregate(loras, freqs, sizes, temperature=4)
    fed = agg.fedavg(loras, sizes)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(fed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_non_expert_adapters_use_dataset_weights():
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [25.0, 75.0]
    freqs = [_freq([0.1] * E), _freq([0.9] * E)]
    out = agg.flame_aggregate(loras, freqs, sizes, temperature=4)
    got = out["blocks"]["pos0"]["attn"]["wq"]["a"]
    want = 0.25 * loras[0]["blocks"]["pos0"]["attn"]["wq"]["a"] + \
        0.75 * loras[1]["blocks"]["pos0"]["attn"]["wq"]["a"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_temperature_sharpens_weighting():
    """Higher t pushes the aggregate toward the high-activation client."""
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [10.0, 10.0]
    freqs = [_freq([0.9] * E), _freq([0.3] * E)]
    hi = loras[0]["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"]

    def dist_to_hi(t):
        out = agg.flame_aggregate(loras, freqs, sizes, temperature=t)
        got = out["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"]
        return float(jnp.abs(got - hi).mean())

    d = [dist_to_hi(t) for t in (0, 1, 2, 4, 8)]
    assert all(d[i] > d[i + 1] for i in range(len(d) - 1)), d


def test_hlora_components_average_over_trainers_only():
    """Client 0 trained rank 2, client 1 rank 4: components 2–3 must come
    from client 1 alone."""
    full = [_client_lora(0), _client_lora(1)]
    truncated = [L.truncate_rank(full[0], 2), full[1]]
    out = agg.hlora_aggregate(truncated, client_ranks=[2, 4],
                              dataset_sizes=[10.0, 10.0], r_full=4)
    got = out["blocks"]["pos0"]["attn"]["wq"]["a"]
    want_hi = full[1]["blocks"]["pos0"]["attn"]["wq"]["a"][..., 2:4]
    np.testing.assert_allclose(np.asarray(got[..., 2:4]),
                               np.asarray(want_hi), rtol=1e-5, atol=1e-6)
    want_lo = 0.5 * (full[0]["blocks"]["pos0"]["attn"]["wq"]["a"][..., :2]
                     + full[1]["blocks"]["pos0"]["attn"]["wq"]["a"][..., :2])
    np.testing.assert_allclose(np.asarray(got[..., :2]),
                               np.asarray(want_lo), rtol=1e-5, atol=1e-6)


def test_flexlora_aggregates_in_delta_space():
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [20.0, 60.0]
    scale = 0.5
    out = agg.flexlora_aggregate(loras, sizes, r_full=R + 6, scale=scale)
    recon = L.merge_delta(out, scale)
    deltas = [L.merge_delta(c, scale) for c in loras]
    want = jax.tree.map(lambda a, b: 0.25 * a + 0.75 * b, *deltas)
    for a, b in zip(jax.tree.leaves(recon), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_activation_frequency_clipped_unit_range():
    f = agg.activation_frequency({"pos0": jnp.asarray([[5.0, 0.0, 12.0]])},
                                 total_tokens=10.0)
    assert float(f["pos0"].max()) <= 1.0 and float(f["pos0"].min()) >= 0.0


# ==========================================================================
# streaming accumulator: init -> update(chunks) -> merge -> finalize must
# equal the one-shot stacked flame_aggregate for ANY split of the client
# set (hypothesis in CI, seeded sweep everywhere — never silently skipped)
# ==========================================================================

import pytest  # noqa: E402

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


def _population(seed, n):
    """n clients with random loras, activation frequencies and sizes."""
    rng = np.random.default_rng(seed)
    loras = [_client_lora(seed * 100 + i) for i in range(n)]
    freqs = [_freq(rng.uniform(0.0, 1.0, size=E)) for _ in range(n)]
    sizes = rng.uniform(1.0, 50.0, size=n).tolist()
    return loras, freqs, sizes


def _stream(loras, freqs, sizes, chunks, prev, *, merge=False):
    """Feed the population through flame_acc_* in ``chunks``-sized pieces,
    either sequentially into one accumulator or via per-chunk accumulators
    combined with flame_acc_merge (the device driver's two-level shape)."""
    template = jax.tree.map(jnp.zeros_like, loras[0])
    accs, lo = [], 0
    for size in chunks:
        hi = lo + size
        acc = agg.flame_acc_update(
            agg.flame_acc_init(template), loras[lo:hi], freqs[lo:hi],
            sizes[lo:hi], temperature=2)
        accs.append(acc)
        lo = hi
    if merge:
        acc = accs[0]
        for a in accs[1:]:
            acc = agg.flame_acc_merge(acc, a)
    else:
        acc = agg.flame_acc_init(template)
        for a in accs:
            acc = agg.flame_acc_merge(acc, a)
    return agg.flame_acc_finalize(acc, prev_lora=prev)


def _assert_close(a, b, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def _check_split_matches_stacked(seed: int, cuts, merge: bool) -> None:
    loras, freqs, sizes = _population(seed, n=sum(cuts))
    prev = _client_lora(seed + 7777)
    want = agg.flame_aggregate(loras, freqs, sizes, temperature=2,
                               prev_lora=prev)
    got = _stream(loras, freqs, sizes, cuts, prev, merge=merge)
    _assert_close(want, got)


if HAVE_HYPOTHESIS:
    settings.register_profile("ci_agg", max_examples=25, deadline=None,
                              derandomize=True)
    settings.load_profile("ci_agg")

    @given(st.integers(0, 2 ** 16), st.lists(st.integers(1, 3), min_size=1,
                                             max_size=4), st.booleans())
    def test_streaming_matches_stacked_any_split(seed, cuts, merge):
        _check_split_matches_stacked(seed, cuts, merge)


def test_streaming_matches_stacked_seeded_sweep():
    """Seeded fallback for the hypothesis property above — runs in every
    environment, hypothesis installed or not."""
    for seed, cuts, merge in [(0, [3], False), (1, [1, 1, 1], True),
                              (2, [2, 3], False), (3, [1, 4], True),
                              (4, [2, 1, 2, 1], True)]:
        _check_split_matches_stacked(seed, cuts, merge)


def test_streaming_permutation_invariant():
    """Client order must not matter (beyond fp summation noise)."""
    loras, freqs, sizes = _population(11, n=5)
    prev = _client_lora(123)
    base = _stream(loras, freqs, sizes, [2, 3], prev)
    perm = np.random.default_rng(0).permutation(5)
    shuffled = _stream([loras[i] for i in perm], [freqs[i] for i in perm],
                       [sizes[i] for i in perm], [3, 2], prev)
    _assert_close(base, shuffled, rtol=1e-4, atol=1e-5)


def test_streaming_single_client_identity():
    """One client with everywhere-positive activation: the aggregate IS
    that client's adapter tree."""
    lora = _client_lora(5)
    freqs = [_freq([0.6] * E)]
    out = _stream([lora], freqs, [13.0], [1], prev=_client_lora(6))
    _assert_close(lora, out)


def test_streaming_conserves_weight_mass():
    """den_gamma / den_size accumulate exactly Σ γ_i and Σ |D_i| across
    any chunking — the invariant that makes merge/finalize exact."""
    loras, freqs, sizes = _population(21, n=4)
    template = jax.tree.map(jnp.zeros_like, loras[0])
    acc = agg.flame_acc_init(template)
    for lo, hi in [(0, 1), (1, 3), (3, 4)]:
        acc = agg.flame_acc_update(acc, loras[lo:hi], freqs[lo:hi],
                                   sizes[lo:hi], temperature=2)
    want_gamma = sum(np.asarray(f["pos0"], np.float64) ** 2 * s
                     for f, s in zip(freqs, sizes))
    np.testing.assert_allclose(np.asarray(acc["den_gamma"]["pos0"]),
                               want_gamma, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(acc["den_size"]), sum(sizes),
                               rtol=1e-6)


# ==========================================================================
# regression: an expert NOBODY activated in the whole round
# ==========================================================================

def test_round_wide_zero_activation_keeps_previous_expert():
    """If every participant reports zero activation for an expert, its
    weight mass is exactly zero: the stacked path used to EPS-divide the
    zero numerator (silently resetting the expert's adapters to ~0), and
    a naive streaming num/den would emit NaN.  Both paths must instead
    keep the previous global adapter for that expert — and stay NaN-free
    even without a previous tree."""
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [10.0, 30.0]
    # expert 1 never activated by anyone; others active
    freqs = [_freq([0.5, 0.0, 0.4, 0.8]), _freq([0.7, 0.0, 0.2, 0.1])]
    prev = _client_lora(42)

    stacked = agg.flame_aggregate(loras, freqs, sizes, temperature=2,
                                  prev_lora=prev)
    streamed = _stream(loras, freqs, sizes, [1, 1], prev)
    for out in (stacked, streamed):
        pair = out["blocks"]["pos0"]["moe"]["experts"]["w1"]
        prev_pair = prev["blocks"]["pos0"]["moe"]["experts"]["w1"]
        for leaf in jax.tree.leaves(out):
            assert not bool(np.isnan(np.asarray(leaf)).any())
        for name in ("a", "b"):
            np.testing.assert_allclose(
                np.asarray(pair[name][:, 1]),
                np.asarray(prev_pair[name][:, 1]), rtol=1e-6, atol=1e-7)
            # active experts still aggregate normally (not prev)
            assert not np.allclose(np.asarray(pair[name][:, 0]),
                                   np.asarray(prev_pair[name][:, 0]))

    # legacy behaviour (no prev tree): zero-filled, but never NaN
    for out in (agg.flame_aggregate(loras, freqs, sizes, temperature=2),
                _stream(loras, freqs, sizes, [2], prev=None)):
        for leaf in jax.tree.leaves(out):
            assert not bool(np.isnan(np.asarray(leaf)).any())
        np.testing.assert_allclose(
            np.asarray(out["blocks"]["pos0"]["moe"]["experts"]["w1"]
                       ["a"][:, 1]), 0.0, atol=1e-6)
