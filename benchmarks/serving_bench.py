"""Serving-engine benchmark: continuous batching + per-slot adaptive k.

Three claims, measured on the bench MoE config (2L, d_model 128, 8
experts top-4) with greedy decode on this host's devices:

  1. **Continuous batching wins**: serving N>=8 concurrent requests
     through the engine's slotted decode beats the sequential
     per-request prefill+decode loop (the pre-engine launch/serve.py
     path) in requests/sec.
  2. **Per-slot k is cheaper**: on the same mixed batch, slots decoding
     at k=1 shrink the MoE dispatch capacity (it follows sum(slot_k)),
     so the compiled step is measurably faster than the all-full-k step
     (measured in capacity-limited dispatch mode,
     ``dispatch="capacity"``).
  2b. **Ragged dispatch keeps that win loss-free**: the engine's default
     sort-based dispatch (``dispatch="ragged"``) decodes k=1 pools
     measurably faster than full-k at equal batch — its expert buffer
     holds ~sum(slot_k) rows — while the dense no-drop mode
     (``dispatch="dense"``, loss-free via worst-case padding) is flat in
     slot_k.
  3. **Paging packs more requests into the same KV bytes**: on a mixed
     short-economy/long-premium workload, the block-paged pool serves
     2x the concurrent rows of the slotted pool from a matched device
     KV budget — 512 usable cache tokens each (the paged pool carries
     one extra trash block, ~3%, reported in the emitted bytes) — and
     wins requests/s because short requests pin blocks, not whole
     slots.

Steady-state numbers: each configuration is warmed up first so compile
time is excluded.  Emits the usual CSV rows (into the ``--out`` JSON
artifact) plus ``# CLAIM`` / ``# BENCH JSON`` summary lines.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.models import model as model_lib
from repro.models.moe_layer import _capacity_from_assignments
from repro.serving import Request, ServingEngine, WorkloadConfig, make_trace

from .common import bench_model, emit


def _requests(cfg, n, prompt_len, new_tokens, k=None, seed=0):
    trace = make_trace(WorkloadConfig(
        n_requests=n, prompt_lens=(prompt_len,), new_tokens=(new_tokens,),
        vocab_size=cfg.vocab_size, seed=seed))
    if k is not None:
        for r in trace:
            r.k = k
    return trace


def _sequential_wall(cfg, params, requests, slot_len: int) -> float:
    """The pre-engine serving path: one request at a time, batch 1 —
    jitted prefill + jitted cache-donating decode, so the comparison
    isolates BATCHING, not compilation artefacts."""
    import jax.numpy as jnp
    k = cfg.moe.top_k

    prefill = jax.jit(lambda p, toks: model_lib.prefill(
        cfg, p, toks, k=k, cache_len=slot_len))
    decode = jax.jit(
        lambda p, c, t, pos: model_lib.decode_step(cfg, p, c, t, pos, k=k),
        donate_argnums=(1,))

    def serve_one(req):
        logits, cache = prefill(params, jnp.asarray(req.prompt[None]))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in range(req.max_new_tokens - 1):
            logits, cache = decode(params, cache, tok,
                                   req.prompt_len + i)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return tok

    serve_one(requests[0]).block_until_ready()          # compile warmup
    t0 = time.perf_counter()
    for req in requests:
        serve_one(req).block_until_ready()
    return time.perf_counter() - t0


def _engine_report(cfg, params, requests, *, num_slots, slot_len,
                   slot_k=None, **engine_kw):
    """Warmed-up engine run (a first run compiles prefill + decode)."""
    engine = ServingEngine(cfg, params, num_slots=num_slots,
                           slot_len=slot_len, slot_k=slot_k, **engine_kw)
    warm = [Request(rid=-1 - s, prompt=requests[0].prompt,
                    max_new_tokens=2, k=engine.slot_k[s])
            for s in range(num_slots)]
    engine.run(warm)
    reqs = [Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, k=r.k,
                    arrival=r.arrival) for r in requests]
    return engine.run(reqs)


def run(smoke: bool = False) -> None:
    cfg = bench_model(moe=True)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    top_k = cfg.moe.top_k
    n_req = 16 if smoke else 32
    new_tokens = 8 if smoke else 16
    prompt_len = 16
    num_slots = 8
    slot_len = prompt_len + new_tokens

    # ---- 1. continuous batching vs the sequential per-request loop ----
    reqs = _requests(cfg, n_req, prompt_len, new_tokens, k=top_k)
    seq_wall = _sequential_wall(cfg, params, reqs, slot_len)
    # dispatch="capacity": the sequential baseline runs capacity-limited
    # dispatch, so the engine must too for a like-for-like comparison
    report = _engine_report(cfg, params, reqs, num_slots=num_slots,
                            slot_len=slot_len, dispatch="capacity")
    s = report.summary()
    rows = [
        {"mode": "sequential", "slots": 1, "requests": n_req,
         "req_per_s": n_req / seq_wall, "gen_tok_per_s":
             n_req * new_tokens / seq_wall,
         "ttft_p95_ms": float("nan"), "latency_p95_ms": seq_wall / n_req
         * 1e3},
        {"mode": "engine", "slots": num_slots, "requests": n_req,
         "req_per_s": s["requests_per_s"],
         "gen_tok_per_s": s["gen_tokens_per_s"],
         "ttft_p95_ms": s["ttft_p95_ms"],
         "latency_p95_ms": s["latency_p95_ms"]},
    ]
    emit("serving_throughput", rows,
         ["mode", "slots", "requests", "req_per_s", "gen_tok_per_s",
          "ttft_p95_ms", "latency_p95_ms"])
    speedup = s["requests_per_s"] / (n_req / seq_wall)
    print(f"# CLAIM serving: continuous batching {speedup:.2f}x requests/s "
          f"vs sequential decode ({n_req} requests, {num_slots} slots)")

    # ---- 2. per-slot adaptive k: step time follows sum(slot_k) ----
    # Run the comparison at a pool size where the dispatch capacity
    # C = ceil(sum(slot_k)·cf / E) clears its 8-slot lane floor — below
    # ~32 concurrent tokens the floor hides the effect at bench scale.
    k_slots = 32 if smoke else 64
    E, factor = cfg.moe.num_experts, cfg.moe.capacity_factor
    configs = [("full_k", (top_k,) * k_slots),
               ("mixed", (top_k,) * (k_slots // 2)
                + (1,) * (k_slots - k_slots // 2)),
               ("k1", (1,) * k_slots)]

    def _k_step_ms(slot_k, dispatch):
        """Steady-state decode-step time at this slot_k mix: min over the
        run's steps (the median absorbs host-side scheduling noise)."""
        kreqs = [Request(rid=i, prompt=reqs[i % n_req].prompt,
                         max_new_tokens=new_tokens, k=slot_k[i])
                 for i in range(k_slots)]
        rep = _engine_report(cfg, params, kreqs, num_slots=k_slots,
                             slot_len=slot_len, slot_k=slot_k,
                             dispatch=dispatch)
        return float(np.min(rep.decode_step_s)) * 1e3, rep

    k_rows = []
    step_ms = {}
    for name, slot_k in configs:
        ms, rep = _k_step_ms(slot_k, "capacity")
        step_ms[name] = ms
        k_rows.append({"slot_k": name, "slots": k_slots,
                       "sum_k": sum(slot_k),
                       "capacity": _capacity_from_assignments(
                           sum(slot_k), E, factor),
                       "decode_step_ms": ms,
                       "gen_tok_per_s": rep.summary()["gen_tokens_per_s"]})
    emit("serving_adaptive_k", k_rows,
         ["slot_k", "slots", "sum_k", "capacity", "decode_step_ms",
          "gen_tok_per_s"])
    k_speed = step_ms["full_k"] / max(step_ms["k1"], 1e-9)
    print(f"# CLAIM serving: k=1 slots cut the decode step to "
          f"{step_ms['k1']:.2f} ms vs {step_ms['full_k']:.2f} ms at full k "
          f"({k_speed:.2f}x) on the same {k_slots}-slot batch "
          f"(capacity-limited dispatch)")

    # ---- 2b. ragged dispatch: loss-free AND sum(slot_k)-proportional ----
    # The engine's DEFAULT loss-free mode (docs/kernels.md §MoE dispatch
    # modes): the ragged expert buffer holds ~sum(slot_k) rows, so the
    # decode step must get cheaper as budgets shrink — where the dense
    # no-drop mode (loss-free via worst-case padding, the pre-ragged
    # default) dispatches E·num_slots expert rows whatever the budget and
    # stays flat.
    from repro.kernels.ragged_dispatch import BLOCK_M, ragged_rows
    from repro.models.moe_layer import dense_capacity
    dense_rows = E * dense_capacity(k_slots)
    r_rows = []
    r_step = {}
    for mode in ("ragged", "dense"):
        # two points suffice to show dense is flat; ragged gets the sweep
        sweep = configs if mode == "ragged" else [configs[0], configs[-1]]
        for name, slot_k in sweep:
            ms, rep = _k_step_ms(slot_k, mode)
            r_step[(mode, name)] = ms
            r_rows.append({
                "dispatch": mode, "slot_k": name, "slots": k_slots,
                "sum_k": sum(slot_k),
                "expert_rows": (ragged_rows(sum(slot_k), E, BLOCK_M)
                                if mode == "ragged" else dense_rows),
                "decode_step_ms": ms,
                "gen_tok_per_s": rep.summary()["gen_tokens_per_s"]})
    emit("serving_ragged", r_rows,
         ["dispatch", "slot_k", "slots", "sum_k", "expert_rows",
          "decode_step_ms", "gen_tok_per_s"])
    rag_speed = (r_step[("ragged", "full_k")]
                 / max(r_step[("ragged", "k1")], 1e-9))
    dense_ratio = (r_step[("dense", "full_k")]
                   / max(r_step[("dense", "k1")], 1e-9))
    print(f"# CLAIM serving: ragged dispatch keeps loss-free decode "
          f"sum(slot_k)-proportional — k=1 steps at "
          f"{r_step[('ragged', 'k1')]:.2f} ms vs "
          f"{r_step[('ragged', 'full_k')]:.2f} ms at full k "
          f"({rag_speed:.2f}x) on the same {k_slots}-slot batch, while "
          f"dense no-drop stays flat ({dense_ratio:.2f}x)")

    # ---- 3. paged vs slotted on a mixed-length tiered workload ----
    # Short economy requests (8 prompt + 24 new => 2 blocks of 16) and
    # long premium requests (32 + 32 => 4 blocks) at a 3:1 ratio,
    # decode-heavy so the structural effect (fewer decode steps at 2x the
    # concurrency) dominates prefill noise.  Both pools get the same
    # device KV budget (8 slots x 64 tokens == 32 blocks x 16 tokens);
    # the paged pool spends it on 2x the decode rows, because short
    # requests pin blocks instead of whole slots.
    mix_len = 64
    mix_n = 24 if smoke else 48
    rng = np.random.default_rng(5)
    mixed = []
    for i in range(mix_n):
        if i % 4 == 0:                         # premium long
            L, new, kk = 32, 32, top_k
        else:                                  # economy short
            L, new, kk = 8, 24, 1
        mixed.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, (L,))
            .astype(np.int32), max_new_tokens=new, k=kk))
    # rows are cheap under paging (no per-row KV commitment), so the
    # paged pool provisions BOTH tiers generously and lets block quotas
    # (proportional to slot share) self-limit: 6 premium rows get a
    # 12-block quota => 3 concurrent longs (vs 2 slotted), 10 economy
    # rows => a 20-block quota => 10 concurrent shorts (vs 6 slotted),
    # on the same 512-token budget
    layouts = [
        ("slotted", dict(num_slots=8, slot_len=mix_len,
                         slot_k=(top_k,) * 2 + (1,) * 6,
                         kv_layout="slotted")),
        ("paged", dict(num_slots=16, slot_len=mix_len,
                       slot_k=(top_k,) * 6 + (1,) * 10,
                       kv_layout="paged", block_size=16, num_blocks=32)),
    ]
    import jax.numpy as jnp
    engines = {}
    for name, kw in layouts:
        eng = ServingEngine(cfg, params, **kw)
        # precompile every prefill bucket the run could hit — block-gated
        # admission makes group sizes timing-dependent, and one jit
        # compile mid-measurement swamps the 0.5s closed-batch run.
        # Bucket sizes cap at the tier's slot count: a group can never
        # hold more requests than the tier has slots.
        for L, kk in ((8, 1), (32, top_k)):
            tier_slots = sum(1 for v in kw["slot_k"] if v == kk)
            b = 1
            while b // 2 < tier_slots:
                eng._prefill_fn(eng.params, eng._prefill_trainable(kk),
                                jnp.zeros((b, L), jnp.int32),
                                jnp.ones((b,), jnp.float32), k=kk)
                b *= 2
        eng.run(mixed)                         # decode compile + warmup
        engines[name] = eng
    # best-of-5 with the layouts INTERLEAVED per repetition: host noise
    # at bench scale is sustained (minutes), so back-to-back blocks of
    # runs would hand whichever layout ran in the quiet minute the win
    best = {name: None for name, _ in layouts}
    for _ in range(5):
        for name, _ in layouts:
            rep = engines[name].run(mixed)
            if best[name] is None or (rep.summary()["requests_per_s"]
                                      > best[name].summary()
                                      ["requests_per_s"]):
                best[name] = rep
    mix_rows = []
    mix_stats = {}
    for name, kw in layouts:
        eng, o = engines[name], best[name].summary()
        peak = (eng.pool.peak_kv_bytes() if name == "paged"
                else eng.pool.kv_bytes())
        mix_stats[name] = {"req_per_s": o["requests_per_s"],
                           "kv_bytes": eng.pool.kv_bytes(),
                           "peak_kv_bytes": peak}
        mix_rows.append({"layout": name, "rows": kw["num_slots"],
                         "kv_bytes": eng.pool.kv_bytes(),
                         "peak_kv_bytes": peak,
                         "req_per_s": o["requests_per_s"],
                         "gen_tok_per_s": o["gen_tokens_per_s"],
                         "latency_p95_ms": o["latency_p95_ms"]})
    emit("serving_paged_mixed", mix_rows,
         ["layout", "rows", "kv_bytes", "peak_kv_bytes", "req_per_s",
          "gen_tok_per_s", "latency_p95_ms"])
    paged_speed = (mix_stats["paged"]["req_per_s"]
                   / max(mix_stats["slotted"]["req_per_s"], 1e-9))
    print(f"# CLAIM serving: paged KV serves the mixed-length workload at "
          f"{paged_speed:.2f}x the slotted requests/s from a matched KV "
          f"budget — 512 usable tokens each; the paged pool adds one "
          f"trash block ({mix_stats['paged']['kv_bytes'] / 2**20:.2f} vs "
          f"{mix_stats['slotted']['kv_bytes'] / 2**20:.2f} MiB pool, peak "
          f"used {mix_stats['paged']['peak_kv_bytes'] / 2**20:.2f} MiB)")

    # ---- 4. self-speculative decoding: draft at k=1, verify at full k ----
    # Acceptance — and therefore speedup — depends on how well the k=1
    # draft distribution agrees with full k.  Random init is the
    # adversarial floor: expert outputs are independent noise, so the
    # k=1 argmax almost never matches k=4 and acceptance sits near 1/V.
    # Tying the experts (broadcast expert 0 across the expert axis, which
    # makes the MoE output k-independent) is the high-agreement limit a
    # trained FLAME model approaches — the draft IS the target, so
    # acceptance -> 1 and the measured ratio isolates the machinery's
    # best case: W+1 tokens for one cheap fused draft scan + one full-k
    # verify step instead of W+1 full decode launches.  Both ends are
    # reported; the claim tracks the high-agreement end.
    #
    # The batch is kept SMALL (8 slots) on purpose: speculation trades
    # extra verify FLOPs for fewer launches, so it pays in the
    # launch-bound low-batch regime it exists for — at 32 slots the
    # plain step is already compute-bound and the S=W+1 verify step's
    # extra work eats the launch saving (measured ~0.9-1.0x there).
    from repro.serving import SpeculativeConfig
    import jax.numpy as jnp2
    tied = jax.tree.map(lambda x: x, params)
    for blk in tied["blocks"].values():
        if "moe" in blk:
            blk["moe"]["experts"] = jax.tree.map(
                lambda t: jnp2.broadcast_to(t[:, :1], t.shape),
                blk["moe"]["experts"])
    spec_slots = 8
    spec_new = 32 if smoke else 48
    spec_len = prompt_len + spec_new
    rng_s = np.random.default_rng(7)
    spec_reqs = [Request(rid=i, prompt=rng_s.integers(
                     0, cfg.vocab_size, (prompt_len,)).astype(np.int32),
                 max_new_tokens=spec_new, k=top_k)
                 for i in range(2 * spec_slots)]

    def _spec_engine(p, spec):
        eng = ServingEngine(cfg, p, num_slots=spec_slots,
                            slot_len=spec_len, slot_k=(top_k,) * spec_slots,
                            speculative=spec)
        eng.run([Request(rid=r.rid, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens, k=r.k)
                 for r in spec_reqs])           # compile + warmup
        return eng

    # windows 4 and 8: at acceptance ~1 the verify step cost is nearly
    # flat in W (one batched S=W+1 launch), so doubling the window almost
    # doubles the tokens a round's fixed launch+sync overhead amortises
    spec_cases = [("tied", tied, None), ("tied", tied, 4), ("tied", tied, 8),
                  ("random", params, None), ("random", params, 4)]
    spec_engines = [(pname, W, _spec_engine(
        p, None if W is None else SpeculativeConfig(window=W, draft_k=1)))
        for pname, p, W in spec_cases]
    spec_best = {}
    for _ in range(2):                          # interleave vs host noise
        for pname, W, eng in spec_engines:
            rep = eng.run([Request(rid=r.rid, prompt=r.prompt,
                                   max_new_tokens=r.max_new_tokens, k=r.k)
                           for r in spec_reqs])
            o = rep.summary()
            key = (pname, W)
            if (key not in spec_best
                    or o["gen_tokens_per_s"]
                    > spec_best[key]["gen_tokens_per_s"]):
                spec_best[key] = o
    spec_rows = []
    spec_stats = {}
    for pname, W, _ in spec_engines:
        o = spec_best[(pname, W)]
        plain = spec_best[(pname, None)]["gen_tokens_per_s"]
        ratio = o["gen_tokens_per_s"] / max(plain, 1e-9)
        spec_rows.append({
            "params": pname,
            "mode": "plain" if W is None else f"spec_W{W}",
            "window": 0 if W is None else W,
            "acceptance": o.get("acceptance_rate", float("nan")),
            "draft_ms": o.get("draft_step_ms_mean", float("nan")),
            "verify_ms": o.get("verify_step_ms_mean", float("nan")),
            "gen_tok_per_s": o["gen_tokens_per_s"],
            "ratio_vs_plain": ratio})
        if W is not None:
            spec_stats[f"{pname}_W{W}"] = {
                "acceptance": o["acceptance_rate"],
                "tok_per_s": o["gen_tokens_per_s"],
                "ratio_vs_plain": ratio}
    emit("serving_speculative", spec_rows,
         ["params", "mode", "window", "acceptance", "draft_ms",
          "verify_ms", "gen_tok_per_s", "ratio_vs_plain"])
    best_key = max((k for k in spec_stats if k.startswith("tied")),
                   key=lambda k: spec_stats[k]["ratio_vs_plain"])
    bs = spec_stats[best_key]
    fl = spec_stats["random_W4"]
    print(f"# CLAIM serving: self-speculative decoding (draft k=1, one "
          f"fused cache-read-only scan; verify full k in one step) serves "
          f"{bs['ratio_vs_plain']:.2f}x plain tokens/s at window "
          f"{best_key.split('_W')[1]} on the launch-bound "
          f"{spec_slots}-slot batch with acceptance "
          f"{bs['acceptance']:.2f} on the high-agreement (tied-expert) "
          f"workload; the random-init floor is "
          f"{fl['ratio_vs_plain']:.2f}x at acceptance "
          f"{fl['acceptance']:.2f} — speculation pays exactly when the "
          f"cheap budget agrees with the full one")

    # ---- 5. overload: prefix cache + SLO admission + preemption ----
    # A flash-crowd trace: long heavy-tail economy generations saturate
    # the block pool (4 economy slots x 10 reserved blocks > 32-block
    # pool), then a burst of short premium turns lands 150 ms later.
    # Every prompt starts with one of two 16-token system prefixes (2
    # full 8-token blocks).  Three engines measure the SAME trace: cold
    # (PR 4/5 behaviour), prefix (block sharing only), traffic (sharing
    # + EDF admission on per-tier TTFT targets + decode preemption).
    # The structural claim is the KV one — shared prefixes pull peak KV
    # bytes below cold at equal traffic — while per-tier p50/p99 TTFT,
    # SLO attainment and swap-out counts are recorded for the SLO story
    # (TTFT margins at this model scale sit near host noise, so they are
    # reported, not claimed).  Interleaved best-of-3 like section 4.
    ov_n = 20 if smoke else 40
    ov_slo = {top_k: 250.0, 1: 10000.0}

    def ov_shaped(seed):
        trace = make_trace(WorkloadConfig(
            n_requests=ov_n, rate=60.0, arrival="burst",
            burst_every_s=0.5, burst_len_s=0.15, burst_factor=6.0,
            prompt_lens=(24,), shared_prefix_len=16, n_shared_prefixes=2,
            length_dist="zipf", new_tokens=(48,), max_new_cap=56,
            tier_mix=((top_k, 0.4), (1, 0.6)), vocab_size=cfg.vocab_size,
            seed=seed))
        for r in trace:
            if r.k == top_k:           # premium = short interactive turns
                r.max_new_tokens = 4   # ...landing after the pool fills
                r.arrival += 0.15
        return trace

    ov_warm, ov_trace = ov_shaped(12), ov_shaped(11)
    ov_cases = [
        ("cold", {}),
        ("prefix", {"prefix_cache": True}),
        ("traffic", {"prefix_cache": True, "preemption": True,
                     "slo_ms": ov_slo}),
    ]
    ov_counters = ("prefix_hit_blocks", "prefix_hit_tokens",
                   "prefix_cow_copies", "prefix_evictions",
                   "swap_outs", "swap_ins")
    ov_engines = {}
    for name, extra in ov_cases:
        eng = ServingEngine(cfg, params, num_slots=8, slot_len=80,
                            slot_k=(top_k,) * 4 + (1,) * 4,
                            block_size=8, num_blocks=32, **extra)
        # block-gated admission makes prefill group sizes
        # timing-dependent: precompile every bucket the run could hit
        # (caps at the 4 slots per tier), then run a same-shape warm
        # trace (different seed: its cached prefixes never match the
        # measured prompts) to compile the decode/swap/scatter paths.
        # Prefix engines additionally prefill matched rows through the
        # SUFFIX variant (cold misses run the same plain exact-length
        # prefill as the cold engine), so they warm its (suffix bucket,
        # page-span bucket, batch bucket, tier) grid too: the 24-token
        # prompts with a 16-token shared head hit suffix bucket 8 over
        # a 4-block page span.
        use_suffix = bool(extra.get("prefix_cache"))
        for kk in (1, top_k):
            b = 1
            while b // 2 < 4:
                eng._prefill_fn(eng.params, eng._prefill_trainable(kk),
                                jnp.zeros((b, 24), jnp.int32),
                                jnp.ones((b,), jnp.float32), k=kk)
                if use_suffix:
                    from repro.serving.engine import _bucket
                    w, st = 8, 16
                    span_b = min(_bucket(-(-(st + w) // 8)),
                                 eng.pool.blocks_per_slot)
                    eng._suffix_prefill_fn(
                        eng.params, eng._prefill_trainable(kk),
                        eng.pool.cache,
                        jnp.zeros((b, w), jnp.int32),
                        jnp.zeros((b, span_b), jnp.int32),
                        jnp.zeros((b,), jnp.int32),
                        jnp.ones((b,), jnp.int32),
                        jnp.ones((b,), jnp.float32), k=kk)
                b *= 2
        eng.run([Request(rid=-1 - r.rid, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens, k=r.k,
                         arrival=r.arrival) for r in ov_warm])
        ov_engines[name] = eng

    ov_stats = {}
    # suffix-prefill compute: best-of (min) prefill wall-clock across the
    # reps, paired with that rep's computed-token count — cold prefills
    # every prompt in full, the prefix engines only the unmatched
    # suffixes, so both must drop
    ov_prefill = {}
    for rep_i in range(3):
        for name, _ in ov_cases:
            eng = ov_engines[name]
            eng.pool.peak_blocks = 0
            for c in ov_counters:
                setattr(eng.pool, c, 0)
            rep = eng.run([Request(rid=r.rid, prompt=r.prompt,
                                   max_new_tokens=r.max_new_tokens,
                                   k=r.k, arrival=r.arrival)
                           for r in ov_trace])
            o = rep.summary()
            psum = float(np.sum(rep.prefill_s))
            if (name not in ov_prefill
                    or psum < ov_prefill[name]["prefill_wall_s"]):
                ov_prefill[name] = {"prefill_wall_s": psum,
                                    "prefill_tokens": rep.prefill_tokens}
            cur = {
                "peak_kv_bytes": eng.pool.peak_kv_bytes(),
                "peak_blocks": eng.pool.peak_blocks,
                "req_per_s": o["requests_per_s"],
                "preemptions": rep.preemptions,
                "prefix_hit_tokens": rep.prefix.get("hit_tokens", 0),
                "per_tier": {
                    t: {"ttft_p50_ms": row["ttft_p50_ms"],
                        "ttft_p99_ms": row["ttft_p99_ms"],
                        "gen_tokens_per_s": row["gen_tokens_per_s"],
                        "slo_attainment": row.get("slo_attainment")}
                    for t, row in o["per_tier"].items()},
            }
            best = ov_stats.get(name)
            if (best is None
                    or cur["per_tier"][str(top_k)]["ttft_p50_ms"]
                    < best["per_tier"][str(top_k)]["ttft_p50_ms"]):
                ov_stats[name] = cur

    for name, _ in ov_cases:
        ov_stats[name].update(ov_prefill[name])
    ov_rows = []
    for name, _ in ov_cases:
        st = ov_stats[name]
        for t, row in st["per_tier"].items():
            ov_rows.append({
                "engine": name, "tier_k": t,
                "peak_kv_bytes": st["peak_kv_bytes"],
                "req_per_s": st["req_per_s"],
                "ttft_p50_ms": row["ttft_p50_ms"],
                "ttft_p99_ms": row["ttft_p99_ms"],
                "slo_attainment": (float("nan")
                                   if row["slo_attainment"] is None
                                   else row["slo_attainment"]),
                "preemptions": st["preemptions"],
                "prefix_hit_tokens": st["prefix_hit_tokens"],
                "prefill_tokens": st["prefill_tokens"],
                "prefill_wall_ms": st["prefill_wall_s"] * 1e3})
    emit("serving_overload", ov_rows,
         ["engine", "tier_k", "peak_kv_bytes", "req_per_s", "ttft_p50_ms",
          "ttft_p99_ms", "slo_attainment", "preemptions",
          "prefix_hit_tokens", "prefill_tokens", "prefill_wall_ms"])
    kv_save = (1.0 - ov_stats["prefix"]["peak_kv_bytes"]
               / max(ov_stats["cold"]["peak_kv_bytes"], 1)) * 100.0
    # suffix-only prefill must make cached prompts cheaper to ADMIT, not
    # just to store: strictly fewer computed prefill tokens and strictly
    # less prefill wall-clock than the cold engine on the same trace
    assert (ov_stats["prefix"]["prefill_tokens"]
            < ov_stats["cold"]["prefill_tokens"]), \
        (ov_stats["prefix"]["prefill_tokens"],
         ov_stats["cold"]["prefill_tokens"])
    assert (ov_stats["prefix"]["prefill_wall_s"]
            < ov_stats["cold"]["prefill_wall_s"]), \
        (ov_stats["prefix"]["prefill_wall_s"],
         ov_stats["cold"]["prefill_wall_s"])
    prm = str(top_k)
    tr = ov_stats["traffic"]["per_tier"]
    cold_tier = ov_stats["cold"]["per_tier"]
    print(f"# CLAIM serving: under a flash-crowd shared-prefix overload "
          f"the prefix cache cuts peak KV bytes {kv_save:.0f}% below cold "
          f"({ov_stats['prefix']['peak_kv_bytes']} vs "
          f"{ov_stats['cold']['peak_kv_bytes']}, "
          f"{ov_stats['prefix']['prefix_hit_tokens']} prompt tokens served "
          f"from cache) and suffix-only prefill computes "
          f"{ov_stats['prefix']['prefill_tokens']} prompt tokens vs "
          f"{ov_stats['cold']['prefill_tokens']} cold "
          f"({ov_stats['prefix']['prefill_wall_s'] * 1e3:.0f} vs "
          f"{ov_stats['cold']['prefill_wall_s'] * 1e3:.0f} ms prefill "
          f"wall-clock); under per-tier SLOs premium TTFT p50 held at "
          f"{tr[prm]['ttft_p50_ms']:.0f} ms (cold FIFO "
          f"{cold_tier[prm]['ttft_p50_ms']:.0f} ms) with SLO attainment "
          f"{tr[prm]['slo_attainment']:.2f} against the 250 ms target "
          f"({ov_stats['traffic']['preemptions']} decode swap-outs)")

    print("# BENCH JSON: " + json.dumps(
        {"bench": "serving", "requests": n_req, "slots": num_slots,
         "seq_req_per_s": n_req / seq_wall,
         "engine_req_per_s": s["requests_per_s"],
         "batching_speedup": speedup,
         "decode_step_ms": step_ms,
         "adaptive_k_step_speedup": k_speed,
         "ragged_step_ms": {f"{m}/{n}": v for (m, n), v in r_step.items()},
         "ragged_k_step_speedup": rag_speed,
         "dense_nodrop_step_ratio": dense_ratio,
         "paged_mixed": mix_stats,
         "paged_mixed_speedup": paged_speed,
         "speculative": spec_stats,
         "overload": ov_stats}))

    if not smoke:
        # ---- open-loop Poisson trace with a premium/economy tier mix ----
        wl = WorkloadConfig(
            n_requests=2 * n_req, rate=50.0, prompt_lens=(8, 16),
            new_tokens=(8, 16), vocab_size=cfg.vocab_size,
            tier_mix=((top_k, 0.5), (1, 0.5)), seed=1)
        slot_k = (top_k,) * (num_slots // 2) + (1,) * (num_slots // 2)
        rep = _engine_report(cfg, params, make_trace(wl),
                             num_slots=num_slots, slot_len=slot_len,
                             slot_k=slot_k)
        o = rep.summary()
        emit("serving_open_loop",
             [{"rate_req_s": 50.0, "requests": 2 * n_req,
               "req_per_s": o["requests_per_s"],
               "ttft_p50_ms": o["ttft_p50_ms"],
               "ttft_p95_ms": o["ttft_p95_ms"],
               "latency_p95_ms": o["latency_p95_ms"]}],
             ["rate_req_s", "requests", "req_per_s", "ttft_p50_ms",
              "ttft_p95_ms", "latency_p95_ms"])


if __name__ == "__main__":
    run()
