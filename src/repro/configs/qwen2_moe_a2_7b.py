"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]

Shared experts are always active so their adapters hit FLAME Eq. 6's
full-activation edge case (dataset-size weighting)."""
from .base import LoRAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408,
                  num_shared_experts=4, d_shared_expert=5632),
    lora=LoRAConfig(rank=16),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

SMOKE = FULL.replace(
    name="qwen2-moe-smoke",
    num_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=128,
                  num_shared_experts=1, d_shared_expert=256),
    lora=LoRAConfig(rank=4),
)

SWA_WINDOW = 8192
