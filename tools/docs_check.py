#!/usr/bin/env python
"""Docs smoke-checker (`make docs-check`).

Every dotted ``repro.*`` reference in the given markdown files — inside
fenced code blocks, inline code spans, or prose — must resolve to an
importable module, or to an attribute reachable from one. Keeps the
README / docs honest: renaming or deleting a module/function without
updating the docs fails CI.

``--flags FILE=MODULE:FUNC`` additionally checks every ``--long-flag``
token the file mentions against the argparse parser built by
``MODULE.FUNC()`` — so the operations guide cannot document a launcher
flag that does not exist (docs/serving.md vs repro.launch.serve).

Usage:  PYTHONPATH=src python tools/docs_check.py README.md docs/*.md \\
            --flags docs/serving.md=repro.launch.serve:build_parser
"""
from __future__ import annotations

import importlib
import re
import sys
from typing import List, Tuple

DOTTED = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")
FROM_IMPORT = re.compile(
    r"^\s*from\s+(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)*)\s+import\s+([\w ,]+)",
    re.MULTILINE)
# long CLI flags as the docs write them: --kv-layout, --slo-ms 8:250, ...
CLI_FLAG = re.compile(r"(?<![\w-])(--[a-z][a-z0-9-]+)\b")


def resolve(dotted: str) -> Tuple[bool, str]:
    """Import the longest module prefix of ``dotted``, then getattr-walk
    the rest.  Returns (ok, reason)."""
    parts = dotted.split(".")
    obj = None
    depth = 0
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
            depth = i
            break
        except ImportError:
            continue
    if obj is None:
        return False, "no importable module prefix"
    for attr in parts[depth:]:
        if not hasattr(obj, attr):
            return False, (f"module {'.'.join(parts[:depth])!r} has no "
                           f"attribute path {'.'.join(parts[depth:])!r}")
        obj = getattr(obj, attr)
    return True, ""


def check_file(path: str) -> List[str]:
    text = open(path).read()
    errors = []
    refs = set(DOTTED.findall(text))
    for mod, names in FROM_IMPORT.findall(text):
        refs.add(mod)
        refs.update(f"{mod}.{n.strip()}" for n in names.split(",")
                    if n.strip())
    for ref in sorted(refs):
        ok, why = resolve(ref)
        if not ok:
            errors.append(f"{path}: `{ref}` does not resolve ({why})")
    return errors


def check_flags(path: str, target: str) -> List[str]:
    """Every ``--flag`` mentioned in ``path`` must be an option of the
    argparse parser built by ``target`` (``MODULE:FUNC``)."""
    mod_name, func_name = target.split(":")
    parser = getattr(importlib.import_module(mod_name), func_name)()
    known = {opt for action in parser._actions
             for opt in action.option_strings}
    text = open(path).read()
    errors = []
    for flag in sorted(set(CLI_FLAG.findall(text))):
        if flag not in known:
            errors.append(
                f"{path}: `{flag}` is not an option of {target}()")
    return errors


def main(argv: List[str]) -> int:
    flag_checks: List[Tuple[str, str]] = []
    paths: List[str] = []
    it = iter(argv)
    for arg in it:
        if arg == "--flags":
            spec = next(it, None)
            if spec is None or "=" not in spec or ":" not in spec:
                print("--flags needs FILE=MODULE:FUNC", file=sys.stderr)
                return 2
            path, target = spec.split("=", 1)
            flag_checks.append((path, target))
        else:
            paths.append(arg)
    if not paths and not flag_checks:
        print("usage: docs_check.py FILE.md [FILE.md ...] "
              "[--flags FILE=MODULE:FUNC]", file=sys.stderr)
        return 2
    errors = []
    checked = 0
    for path in paths:
        errs = check_file(path)
        errors.extend(errs)
        checked += 1
    for path, target in flag_checks:
        errors.extend(check_flags(path, target))
    for e in errors:
        print(f"FAIL {e}")
    print(f"docs-check: {checked} file(s), "
          f"{len(flag_checks)} flag check(s), "
          f"{'OK' if not errors else f'{len(errors)} broken reference(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
