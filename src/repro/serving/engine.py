"""Continuous-batching serving engine with per-slot adaptive k.

The engine drives ONE jitted decode step over the whole slot pool every
iteration.  Requests at different depths coexist because the per-slot
``cache_pos`` vector is threaded into attention (scatter write + per-row
validity mask); requests of different tiers coexist because the MoE layer
takes a static per-slot expert-budget tuple (``slot_k``): premium slots
decode at full k, constrained slots at k=1–2, and the dispatch capacity —
hence the expert FLOPs — follows ``sum(slot_k)`` instead of
``num_slots * k_max`` (models/moe_layer.py).  The FLAME rescaler is
applied per slot the same way: each tier's trained ``s_i`` is stacked into
a ``(n_periods, num_slots)`` leaf that the scan slices per layer.

The KV cache behind the slots is block-paged by default
(``kv_layout="paged"``, kv_cache.BlockPool): attention K/V live in a
shared pool of fixed-size blocks, each row carries a block table, and
admission is gated on the request's projected block need — device KV
bytes follow tokens in flight instead of ``num_slots × slot_len``.  The
PR 3 monolithic pool survives as ``kv_layout="slotted"``, the
differential-test oracle (tests/test_paged_kv.py proves the two are
token-for-token identical).

Engine loop (one ``step()``):

  1. requests whose arrival time has passed join the scheduler queue;
  2. the scheduler packs waiting requests into free slots (FIFO per
     tier, block-availability predicate when paged); admitted requests
     are prefilled — batched by prompt length, padded to power-of-two
     batch buckets to bound recompiles — and their caches installed into
     the pool (``write``), emitting the first generated token (TTFT);
  3. one decode step advances every active slot by a token; finished
     sequences (budget reached / slot full) are evicted and their slots
     (and KV blocks) released.

Sampling is greedy (argmax) by default, or any :class:`SamplerConfig`
(temperature / top-p; serving/sampler.py) with per-request PRNG keys
folded from ``seed`` and the request id, so a request's draws are
independent of what shares its batch.  A request may instead carry
``forced`` continuation tokens, which the engine feeds back while
accumulating their NLL — teacher-forced quality evaluation through the
serving path.  ``speculative=SpeculativeConfig(...)`` switches decode to
self-speculative rounds: draft W tokens per slot at ``draft_k``, verify
in one full-k multi-token step, accept by the rejection rule and roll
rejected K/V back (serving/speculative.py).

Production traffic controls (docs/serving.md is the operations guide):

* ``prefix_cache=True`` (paged only) — prompts are content-matched
  against the pool's block index at admission
  (``BlockPool.attach_prefix``), so requests sharing a system prompt
  hold its KV blocks once (refcounts + copy-on-write in
  kv_cache.BlockPool) — and, for attention-only models, prefill runs
  over the UNMATCHED SUFFIX only (``model.prefill_suffix``), attending
  back into the attached prefix pages: prefill compute follows unseen
  tokens instead of prompt length, and admission buckets matched rows
  by suffix length, so a flash crowd of long shared-head prompts
  collapses into small buckets (cold misses run the plain exact-length
  prefill, identical to a cold engine's launches).  Token-for-token
  invisible: the suffix step reproduces
  the cold logits to float tolerance, and the greedy differential in
  tests/test_traffic.py locks exact token identity against a cold
  engine.
* ``slo_ms={tier_k: target_ms}`` — per-tier TTFT targets; the scheduler
  switches to earliest-deadline-first admission and ``summary()`` gains
  per-tier p50/p99 TTFT, tokens/s and SLO attainment.
* ``preemption=True`` (paged, needs ``slo_ms``) — when a waiter is past
  its TTFT deadline and blocked on blocks, the engine swaps out the
  active request with the most lenient deadline (host copy via
  ``BlockPool.swap_out``), frees its blocks AND reservation, and lets
  the victim resume later through normal re-admission — token-for-token
  identical to an uncontended run, because the swap round-trips the
  row's exact KV/SSM state and the per-request PRNG event counter lives
  in the preserved ``_ActiveSlot``.  Composes with speculative decoding:
  preemption fires between draft/verify rounds, and a swap-out of a slot
  with an open draft window first rolls the window back to the last
  verified token (``SpeculativeDecoder.rollback_open``), so the swapped
  state never carries unverified draft positions.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib
from ..obs.expert_load import ExpertLoadTracker
from ..obs.metrics import Histogram, MetricsRegistry
from ..obs.trace import NULL_TRACER, PID_ENGINE, PID_REQUESTS, Tracer
from .kv_cache import BlockPool, SlotPool
from .sampler import SamplerConfig, sample_token
from .scheduler import Completion, Request, Scheduler
from .speculative import SpeculativeConfig, SpeculativeDecoder
from .workload import percentile

PyTree = Any


def _log_softmax_np(x: np.ndarray) -> np.ndarray:
    m = x.max(axis=-1, keepdims=True)
    lse = np.log(np.exp(x - m).sum(axis=-1, keepdims=True)) + m
    return x - lse


def _bucket(n: int) -> int:
    """Next power of two >= n (prefill batch buckets)."""
    b = 1
    while b < n:
        b *= 2
    return b


def _tier_salt(k: Optional[int]) -> bytes:
    """Prefix-digest salt for an expert-budget tier.

    A block's K/V depends on the tokens AND on the MoE expert budget
    ``k`` the writer ran at — every layer's hidden states (hence K/V)
    change with the number of experts mixed in.  Salting the digest
    chain with the tier keeps equal prompts served at different ``k``
    from ever aliasing each other's numerically different pages.
    """
    return b"" if k is None else str(int(k)).encode()


@dataclass
class _ActiveSlot:
    req: Request
    tokens: List[int]
    nll: float
    admitted: float
    first_token: float
    max_new: int
    # per-request PRNG event counter: every sampler draw folds
    # (seed, rid, events) into its key, so draws are keyed by the
    # request's own draw order — independent of co-batched rows
    events: int = 0
    # times this request was swapped out mid-decode; capped by the
    # engine's max_preemptions so repeated preemption cannot livelock
    preemptions: int = 0
    # engine time of the most recent swap-out (tracer: the swapped_out
    # span runs from here to the swap_in that resumes the request)
    swap_t: float = 0.0


def _pct_ms(xs: Sequence[float], q: float) -> Optional[float]:
    """Percentile in milliseconds, None (not NaN) on an empty sample —
    keeps ``summary()`` JSON-safe for zero-completion runs."""
    return percentile(list(xs), q) * 1e3 if xs else None


@dataclass
class ServingReport:
    """Everything a serving run produced, plus latency/throughput views."""
    completions: List[Completion]
    decode_step_s: List[float] = field(default_factory=list)
    prefill_s: List[float] = field(default_factory=list)
    wall_s: float = 0.0
    num_slots: int = 0
    slot_k: Tuple[Optional[int], ...] = ()
    # speculative-decode accounting (zero when speculation is off)
    draft_step_s: List[float] = field(default_factory=list)
    verify_step_s: List[float] = field(default_factory=list)
    spec_rounds: int = 0
    spec_drafted: int = 0
    spec_accepted: int = 0
    # production-traffic accounting
    preemptions: int = 0                     # swap-outs over the run
    # tokens the prefill steps actually computed: full prompts on a cold
    # engine, unmatched suffixes only under suffix-prefill — the bench's
    # direct measure of prefill compute saved by the prefix cache
    prefill_tokens: int = 0
    prefix: Dict[str, int] = field(default_factory=dict)
    slo_ms: Optional[Dict[Optional[int], float]] = None
    # step-time histograms (ms; repro.obs.metrics.Histogram) — always
    # populated (one bisect per step), the source for summary()'s
    # p50/p99 and for registry snapshots
    decode_hist: Histogram = field(default_factory=Histogram)
    prefill_hist: Histogram = field(default_factory=Histogram)
    draft_hist: Histogram = field(default_factory=Histogram)
    verify_hist: Histogram = field(default_factory=Histogram)
    # expert-load telemetry snapshot (engine expert_telemetry=True)
    expert_load: Optional[Dict[str, Any]] = None

    def tokens_by_rid(self) -> Dict[int, np.ndarray]:
        """Generated tokens keyed by request id."""
        return {c.rid: c.tokens for c in self.completions}

    def per_tier(self) -> Dict[str, Dict[str, float]]:
        """Per-tier latency/throughput: p50/p99 TTFT, tokens/s and (when
        ``slo_ms`` targets are set) the fraction of requests whose TTFT
        met the tier's target.  Keys are ``str(k)`` (``"0"`` = non-MoE)."""
        by_tier: Dict[int, List[Completion]] = {}
        for c in self.completions:
            by_tier.setdefault(c.k, []).append(c)
        out: Dict[str, Dict[str, float]] = {}
        for k, cs in sorted(by_tier.items()):
            ttfts = [c.ttft for c in cs]
            row = {
                "n_requests": len(cs),
                "ttft_p50_ms": percentile(ttfts, 50) * 1e3,
                "ttft_p99_ms": percentile(ttfts, 99) * 1e3,
                "gen_tokens_per_s": (sum(c.n_generated for c in cs)
                                     / max(self.wall_s, 1e-9)),
            }
            slo = (self.slo_ms or {}).get(k)
            if slo is not None:
                row["slo_attainment"] = (
                    sum(t * 1e3 <= slo for t in ttfts) / len(cs))
            out[str(k)] = row
        return out

    def summary(self) -> Dict[str, Any]:
        """Flat run summary (JSON-safe): aggregate latency/throughput,
        per-tier breakdown, and speculation / prefix-cache / preemption
        accounting when those features were on."""
        n = len(self.completions)
        gen = sum(c.n_generated for c in self.completions)
        ttfts = [c.ttft for c in self.completions]
        lats = [c.latency for c in self.completions]
        # zero-completion runs yield a well-formed summary: every
        # percentile/mean field is None (never NaN — json.dumps(nan)
        # emits invalid JSON), every count/rate field a real 0
        out = {
            "n_requests": n,
            "gen_tokens": gen,
            "wall_s": self.wall_s,
            "requests_per_s": n / max(self.wall_s, 1e-9),
            "gen_tokens_per_s": gen / max(self.wall_s, 1e-9),
            "ttft_p50_ms": _pct_ms(ttfts, 50),
            "ttft_p95_ms": _pct_ms(ttfts, 95),
            "ttft_p99_ms": _pct_ms(ttfts, 99),
            "latency_p50_ms": _pct_ms(lats, 50),
            "latency_p95_ms": _pct_ms(lats, 95),
            "decode_step_ms_mean": (float(np.mean(self.decode_step_s)) * 1e3
                                    if self.decode_step_s else None),
            "decode_step_ms_p50": self.decode_hist.percentile(50),
            "decode_step_ms_p99": self.decode_hist.percentile(99),
            "decode_steps": len(self.decode_step_s),
            "prefill_tokens": self.prefill_tokens,
            "truncated": sum(c.truncated for c in self.completions),
            "per_tier": self.per_tier(),
        }
        if self.preemptions:
            out["preemptions"] = self.preemptions
        if self.prefix:
            out["prefix_cache"] = dict(self.prefix)
        if self.spec_rounds:
            out.update({
                "spec_rounds": self.spec_rounds,
                "spec_drafted": self.spec_drafted,
                "spec_accepted": self.spec_accepted,
                "acceptance_rate": (self.spec_accepted
                                    / max(self.spec_drafted, 1)),
                "draft_step_ms_mean": float(np.mean(self.draft_step_s)) * 1e3,
                "draft_step_ms_p50": self.draft_hist.percentile(50),
                "draft_step_ms_p99": self.draft_hist.percentile(99),
                "verify_step_ms_mean": (float(np.mean(self.verify_step_s))
                                        * 1e3),
                "verify_step_ms_p50": self.verify_hist.percentile(50),
                "verify_step_ms_p99": self.verify_hist.percentile(99),
            })
        if self.expert_load is not None:
            out["expert_load"] = {
                k: self.expert_load[k]
                for k in ("steps", "gini", "entropy", "hot_expert",
                          "assignments_total")}
        return out


class ServingEngine:
    """Continuous batching over a :class:`SlotPool` with per-slot k.

    ``slot_k``: per-slot expert budgets (tuple of ints, len ``num_slots``);
    defaults to ``cfg.moe.top_k`` everywhere; ignored (None) for non-MoE
    models.  The tuple is STATIC — it fixes the compiled step's dispatch
    capacity — so tiers are a property of the pool, and the scheduler
    matches requests to slots of their tier.

    ``lora``: optional unmerged adapter tree (serving without merging);
    ``rescaler_by_k``: optional ``{k: rescaler tree}`` — each tier's
    trained FLAME ``s_i``, applied per slot during decode and per batch
    during prefill.

    ``kv_layout``: ``"paged"`` (default) backs the slots with a
    :class:`BlockPool` — attention K/V in ``num_blocks`` shared
    ``block_size``-token blocks, admission gated on each request's
    projected block need, so device KV bytes follow tokens in flight;
    ``"slotted"`` keeps the PR 3 monolithic :class:`SlotPool` (the
    differential-test oracle).  Both layouts are token-for-token
    identical (tests/test_paged_kv.py).  Models with no attention layers
    (pure SSM) have O(1)/request state and always use the slotted pool.

    ``dispatch`` (default ``"ragged"``): MoE token-dispatch mode.  With
    capacity-limited GShard dispatch (``"capacity"``), which tokens
    overflow an expert depends on which rows share a prefill bucket or
    decode step, so a request's OUTPUT would depend on the admission
    schedule — serving must not let batching change results (it is also
    what makes the paged-vs-slotted differential well-defined).  Both
    loss-free modes guarantee schedule-independence:

    * ``"ragged"`` — sort-based dispatch (kernels/ragged_dispatch.py):
      row-isolated by construction AND expert compute follows
      ``sum(slot_k)``, so constrained slots genuinely decode cheaper.
      The default.
    * ``"dense"`` — one-hot dispatch with capacity pinned to the token
      count (the pre-ragged loss-free mode, kept as the differential
      oracle): worst-case padding, compute flat in ``slot_k``.
    * ``"capacity"`` — the capacity-limited throughput mode the
      adaptive-k bench measures; batching MAY change results.

    ``no_drop`` is the legacy alias (``True`` -> ``"dense"``, ``False``
    -> ``"capacity"``); leave both unset for the ragged default.

    Production traffic knobs (see the module docstring and
    docs/serving.md): ``prefix_cache`` (paged-only block sharing for
    prompts, plus suffix-only prefill on attention-only models — only
    the unmatched prompt suffix is computed, attending into the attached
    prefix pages), ``slo_ms`` (per-tier TTFT targets in milliseconds,
    keyed by tier ``k`` — switches admission to
    earliest-deadline-first), ``preemption`` (paged-only decode swap-out
    under deadline pressure; requires ``slo_ms``; composes with
    ``speculative`` — an open draft window is rolled back before the
    swap) and ``max_preemptions`` (per-request swap-out cap — the
    anti-livelock bound).

    Observability knobs (repro.obs; docs/observability.md) — all
    opt-in-pay, the defaults cost one attribute check per event site:
    ``tracer`` (a :class:`repro.obs.Tracer`) records request-lifecycle
    spans (queued/prefill/decode + swap instants per rid) and
    engine-loop spans (admit/prefill/decode_step), exports Chrome
    trace-event JSON, and is flight-dumped if ``run()`` raises;
    ``metrics`` (a :class:`repro.obs.MetricsRegistry`) receives engine
    counters and registers the pool and scheduler as snapshot-time
    sources; ``expert_telemetry=True`` (MoE, non-speculative) compiles
    the decode step to also return per-expert activation counts, which
    feed ``report.expert_load`` host-side — no kernel changes.
    """

    def __init__(self, cfg, params: PyTree, *, lora: Optional[PyTree] = None,
                 rescaler_by_k: Optional[Dict[int, PyTree]] = None,
                 num_slots: int = 8, slot_len: int = 64,
                 slot_k: Optional[Sequence[int]] = None,
                 kv_layout: str = "paged", block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 no_drop: Optional[bool] = None,
                 dispatch: Optional[str] = None,
                 sampler: Optional[SamplerConfig] = None,
                 speculative: Optional[SpeculativeConfig] = None,
                 prefix_cache: bool = False,
                 preemption: bool = False,
                 slo_ms: Optional[Dict[Optional[int], float]] = None,
                 max_preemptions: int = 4,
                 seed: int = 0,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 expert_telemetry: bool = False):
        assert cfg.num_codebooks == 0, "serving engine: text models only"
        assert kv_layout in ("paged", "slotted"), kv_layout
        if dispatch is None:
            dispatch = ("ragged" if no_drop is None
                        else ("dense" if no_drop else "capacity"))
        assert dispatch in ("ragged", "dense", "capacity"), dispatch
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.slot_len = slot_len
        has_attn = any(cfg.layer_kind(p) == "attn"
                       for p in range(cfg.pattern_period))
        self.paged = kv_layout == "paged" and has_attn
        if prefix_cache and not self.paged:
            raise ValueError(
                "prefix_cache needs the paged KV layout (block sharing "
                "has no meaning in the slotted pool)")
        if preemption:
            if not self.paged:
                raise ValueError(
                    "preemption needs the paged KV layout (swap-out "
                    "frees blocks, not whole slots)")
            if not slo_ms:
                raise ValueError(
                    "preemption needs slo_ms targets: victim selection "
                    "is driven by TTFT deadlines")
        if cfg.moe.enabled:
            resolved = tuple(int(v) for v in (
                slot_k if slot_k is not None
                else (cfg.moe.top_k,) * num_slots))
            assert len(resolved) == num_slots, (resolved, num_slots)
            assert all(1 <= v <= cfg.moe.num_experts for v in resolved)
            self.slot_k: Tuple[Optional[int], ...] = resolved
            self._moe_k: Optional[Tuple[int, ...]] = resolved
        else:
            assert slot_k is None, "slot_k is meaningless without MoE"
            self.slot_k = (None,) * num_slots
            self._moe_k = None

        self._lora = lora
        self._rescaler_by_k = rescaler_by_k
        self._decode_trainable = self._build_decode_trainable()

        if self.paged:
            self.pool = BlockPool(cfg, num_slots, slot_len,
                                  block_size=block_size,
                                  num_blocks=num_blocks,
                                  prefix_cache=prefix_cache)
            # per-tier block quotas (proportional to the tier's slot
            # share, floored at one full request): a tier may exceed its
            # quota only while no OTHER tier has requests waiting, so a
            # flood of long premium requests can saturate an idle pool
            # but can never starve economy admission once economy
            # traffic queues up — freed blocks then flow to the
            # under-quota tier (tests/test_serving.py adversarial traces)
            counts: Dict[Optional[int], int] = {}
            for t in self.slot_k:
                counts[t] = counts.get(t, 0) + 1
            self._tier_quota = {
                t: max(self.pool.blocks_per_slot,
                       self.pool.num_blocks * c // num_slots)
                for t, c in counts.items()}
            self._tier_reserved = {t: 0 for t in counts}
        else:
            self.pool = SlotPool(cfg, num_slots, slot_len)
        self.prefix_cache = prefix_cache
        # suffix-only cached prefill: prefill computes only the prompt
        # suffix past the matched prefix span, attending back into the
        # attached pages (model.prefill_suffix).  Attention-only models
        # only — an SSM layer's state is cumulative over the whole
        # prompt, so a mixed model falls back to full prefill (the
        # blocks still share; only the compute saving is lost).
        self._use_suffix = prefix_cache and all(
            cfg.layer_kind(p) == "attn" for p in range(cfg.pattern_period))
        self.slo_ms = dict(slo_ms) if slo_ms else None
        self._preemption = preemption
        self._max_preemptions = max_preemptions
        # rid -> (pool swap state, _ActiveSlot, last sampled token):
        # everything a preempted request needs to resume bit-exactly
        self._swapped: Dict[int, Tuple[Dict[str, Any], _ActiveSlot,
                                       int]] = {}
        if self.slo_ms:
            self.scheduler = Scheduler(
                policy="slo",
                tier_slo_s={t: ms / 1e3 for t, ms in self.slo_ms.items()})
        else:
            self.scheduler = Scheduler()
        self._active: List[Optional[_ActiveSlot]] = [None] * num_slots
        self._last_tok = np.zeros((num_slots, 1), np.int32)

        self.dispatch = dispatch
        self.no_drop = dispatch != "capacity"    # loss-free?
        self._sampler = sampler or SamplerConfig()
        self._seed = seed
        self._req_keys: Dict[int, jax.Array] = {}

        # ---- observability (all opt-in-pay; see repro.obs) ----
        self._tracer = tracer if tracer is not None else NULL_TRACER
        self._metrics = metrics
        if expert_telemetry and not cfg.moe.enabled:
            raise ValueError("expert_telemetry needs an MoE model: a "
                             "dense model routes nothing to observe")
        if expert_telemetry and speculative is not None:
            raise ValueError(
                "expert_telemetry under speculative decoding is not "
                "supported yet: the fused draft window does not surface "
                "activation counts")
        self._expert_telemetry = bool(expert_telemetry)
        self._expert_tracker = (ExpertLoadTracker(cfg.moe.num_experts)
                                if self._expert_telemetry else None)
        if metrics is not None:
            metrics.add_source(self.pool.publish)
            metrics.add_source(self.scheduler.publish)
            self._ctr_completions = metrics.counter("serving.completions")
            self._ctr_tokens = metrics.counter("serving.gen_tokens")
            self._ctr_admitted = metrics.counter("serving.admitted")
            self._ctr_preempt = metrics.counter("serving.preemptions")
        if self._tracer.enabled:
            self._tracer.process_name(PID_ENGINE, "serving-engine")
            self._tracer.thread_name(PID_ENGINE, 0, "engine loop")
            self._tracer.process_name(PID_REQUESTS, "requests")

        @partial(jax.jit, static_argnames=("k",))
        def _prefill_fn(params, trainable, prompts, real, k):
            if dispatch == "ragged" and cfg.moe.enabled:
                # ragged dispatch is row-isolated by construction (each
                # token's output depends only on its own assignments), so
                # prefill runs ONE routing group per bucket — no per-row
                # group workaround, and bucket-padding rows cannot touch
                # real rows
                logits, cache = model_lib.prefill(
                    cfg, params, prompts, trainable=trainable, k=k,
                    cache_len=slot_len, dispatch="ragged")
            elif dispatch == "dense" and cfg.moe.enabled:
                # loss-free one-hot prefill, one routing group PER ROW
                # with capacity = the row's own token count: a row's
                # result cannot depend on co-batched rows (bucket-padding
                # rows isolate themselves), and dispatch cost stays
                # linear in the bucket instead of quadratic (C would
                # otherwise be the whole bucket's token count)
                logits, cache = model_lib.prefill(
                    cfg, params, prompts, trainable=trainable, k=k,
                    cache_len=slot_len, num_groups=prompts.shape[0],
                    dispatch="dense")
            else:
                logits, cache = model_lib.prefill(
                    cfg, params, prompts, trainable=trainable, k=k,
                    cache_len=slot_len,
                    slot_mask=real if cfg.moe.enabled else None)
            return logits[:, 0].astype(jnp.float32), cache

        suffix_attn = self.pool.attn_len if self.paged else 0
        suffix_bs = self.pool.block_size if self.paged else 1

        @partial(jax.jit, static_argnames=("k",))
        def _suffix_prefill_fn(params, trainable, cache, tokens, tables,
                               prefix_len, suffix_len, real, k):
            # cache is READ-ONLY here (not donated): the suffix step
            # gathers the attached prefix pages per row and returns the
            # new K/V as a contiguous piece — BlockPool.write scatters
            # it host-side, exactly like the cold path.  Shapes depend
            # only on (batch bucket, suffix bucket, page-span bucket, k):
            # prefix_len and suffix_len are traced per-row vectors.
            # ``tables`` arrives SLICED to the pow-2 block span covering
            # the group's live prefix+suffix — the page gather (the
            # launch-dominating cost at short suffixes) follows the data
            # actually attended, not the slot's full capacity; every
            # live prefix position is < the span by construction and
            # anything past it was masked invalid anyway.
            suffix_span = min(tables.shape[1] * suffix_bs, suffix_attn)
            if dispatch == "ragged" and cfg.moe.enabled:
                # row-isolated by construction — one routing group, and
                # dispatch cost follows sum(suffix_len · k), the
                # resource-proportionality point of suffix prefill
                logits, piece = model_lib.prefill_suffix(
                    cfg, params, tokens, prefix_len, suffix_len, cache,
                    tables, page_span=suffix_span, trainable=trainable,
                    k=k, dispatch="ragged")
            elif dispatch == "dense" and cfg.moe.enabled:
                logits, piece = model_lib.prefill_suffix(
                    cfg, params, tokens, prefix_len, suffix_len, cache,
                    tables, page_span=suffix_span, trainable=trainable,
                    k=k, num_groups=tokens.shape[0], dispatch="dense")
            else:
                # capacity dispatch: per-TOKEN validity, not per-row —
                # bucket-padding columns inside real rows must not
                # consume expert capacity or a request's output would
                # depend on what shares its bucket
                mask = None
                if cfg.moe.enabled:
                    S = tokens.shape[1]
                    mask = (real[:, None] *
                            (jnp.arange(S)[None, :]
                             < suffix_len[:, None]).astype(jnp.float32))
                logits, piece = model_lib.prefill_suffix(
                    cfg, params, tokens, prefix_len, suffix_len, cache,
                    tables, page_span=suffix_span, trainable=trainable,
                    k=k, slot_mask=mask, dispatch=dispatch)
            return logits[:, 0].astype(jnp.float32), piece

        self._decode_fn = self._build_decode_fn(
            self._moe_k, return_counts=self._expert_telemetry)
        self._prefill_fn = _prefill_fn
        self._suffix_prefill_fn = _suffix_prefill_fn
        self._spec = (SpeculativeDecoder(self, speculative)
                      if speculative is not None else None)

    # -------------------------------------------------------- compiled steps
    def _build_decode_fn(self, moe_k: Optional[Tuple[int, ...]],
                         return_counts: bool = False):
        """One jitted single-token decode step over the whole pool.

        The pool cache is donated: the engine replaces its reference with
        the returned cache every step, and donation lets XLA update the
        slot arrays in place instead of copying the whole pool per token.
        ``active`` masks free slots out of MoE routing (budget 0), so
        garbage rows can never consume expert capacity a real request
        needs.  ``moe_k`` is baked in — the speculative decoder compiles
        its own fused draft window with every slot at ``draft_k``.

        ``return_counts`` (expert telemetry) additionally returns the
        step's per-expert activation counts ``{posN: (n_periods, E)}`` —
        a distinct compiled executable, built only when the engine was
        constructed with ``expert_telemetry=True`` so the default step
        pays nothing.
        """
        cfg, dispatch = self.cfg, self.dispatch
        page_span = self.pool.attn_len if self.paged else None
        if self.paged:
            @partial(jax.jit, donate_argnums=(2,))
            def _decode_fn(params, trainable, cache, tokens, pos, active,
                           tables):
                out = model_lib.decode_step(
                    cfg, params, cache, tokens, pos, trainable=trainable,
                    k=moe_k, slot_mask=active if cfg.moe.enabled else None,
                    block_table=tables, page_span=page_span,
                    dispatch=dispatch, return_counts=return_counts)
                return (out[0][:, 0].astype(jnp.float32),) + out[1:]
        else:
            @partial(jax.jit, donate_argnums=(2,))
            def _decode_fn(params, trainable, cache, tokens, pos, active):
                out = model_lib.decode_step(
                    cfg, params, cache, tokens, pos, trainable=trainable,
                    k=moe_k, slot_mask=active if cfg.moe.enabled else None,
                    dispatch=dispatch, return_counts=return_counts)
                return (out[0][:, 0].astype(jnp.float32),) + out[1:]
        return _decode_fn

    def _build_verify_fn(self):
        """The speculative verify step: full tier k over ``(B, W+1)``
        teacher-forced window tokens, returning logits at EVERY window
        position.  Shape-driven: one compile per distinct window width
        (bounded by the speculative window, like the prefill buckets)."""
        cfg, dispatch, moe_k = self.cfg, self.dispatch, self._moe_k
        page_span = self.pool.attn_len if self.paged else None
        if self.paged:
            @partial(jax.jit, donate_argnums=(2,))
            def _verify_fn(params, trainable, cache, tokens, pos, active,
                           tables):
                logits, new_cache = model_lib.decode_step(
                    cfg, params, cache, tokens, pos, trainable=trainable,
                    k=moe_k, slot_mask=active if cfg.moe.enabled else None,
                    block_table=tables, page_span=page_span,
                    dispatch=dispatch)
                return logits.astype(jnp.float32), new_cache
        else:
            @partial(jax.jit, donate_argnums=(2,))
            def _verify_fn(params, trainable, cache, tokens, pos, active):
                logits, new_cache = model_lib.decode_step(
                    cfg, params, cache, tokens, pos, trainable=trainable,
                    k=moe_k, slot_mask=active if cfg.moe.enabled else None,
                    dispatch=dispatch)
                return logits.astype(jnp.float32), new_cache
        return _verify_fn

    # ------------------------------------------------------------- trainables
    def _build_decode_trainable(self) -> Optional[PyTree]:
        tr: dict = {}
        if self._lora is not None:
            tr["lora"] = self._lora
        if self._rescaler_by_k:
            ks = [k for k in self.slot_k if k is not None]
            missing = sorted(set(ks) - set(self._rescaler_by_k))
            assert not missing, f"rescaler_by_k missing tiers {missing}"
            # stack tiers per slot: leaf (n_periods,) -> (n_periods, S);
            # the stack scan slices the leading axis, so each MoE layer
            # sees a (num_slots,) vector — the per-slot rescaler path in
            # moe_layer.apply_moe
            tr["rescaler"] = jax.tree.map(
                lambda *leaves: jnp.stack(leaves, axis=-1),
                *[self._rescaler_by_k[k] for k in ks])
        return tr or None

    def _build_draft_trainable(self, draft_k: int) -> Optional[PyTree]:
        """Trainable tree for the speculative draft window: every slot at
        the same scalar ``draft_k``, so the per-period rescaler tree is
        used as-is (no per-slot stacking).  Uses the ``draft_k`` tier's
        trained rescaler when one was provided; otherwise the draft runs
        unrescaled — the draft distribution q may be anything without
        breaking the rejection rule's exactness, only the acceptance
        rate."""
        tr: dict = {}
        if self._lora is not None:
            tr["lora"] = self._lora
        if self._rescaler_by_k and draft_k in self._rescaler_by_k:
            tr["rescaler"] = self._rescaler_by_k[draft_k]
        return tr or None

    def _prefill_trainable(self, k: Optional[int]) -> Optional[PyTree]:
        tr: dict = {}
        if self._lora is not None:
            tr["lora"] = self._lora
        if self._rescaler_by_k and k is not None:
            tr["rescaler"] = self._rescaler_by_k[k]
        return tr or None

    # ------------------------------------------------------------------ admit
    @staticmethod
    def _max_new(req: Request) -> int:
        if req.forced is not None:
            return min(req.max_new_tokens, len(req.forced))
        return req.max_new_tokens

    def _projected_tokens(self, req: Request) -> int:
        """Cache positions the request will write over its lifetime: the
        prompt plus one decode write per generated token after the first
        (the prefill token costs no extra position).  Floored at the
        prompt length: prefill installs all L positions even when
        ``max_new`` is 0 (the engine still emits the prefill token)."""
        return req.prompt_len + max(self._max_new(req), 1) - 1

    def _admit(self, report: ServingReport) -> int:
        """One admission round: a normal packing pass, then — with
        preemption on — swap out lenient-deadline victims while a waiter
        is past its TTFT deadline and another pass can seat it."""
        t0 = self._now()
        n = self._admit_pass(report)
        if self._preemption:
            for _ in range(self.num_slots):
                victim = self._pick_victim()
                if victim is None:
                    break
                self._preempt(victim, report)
                got = self._admit_pass(report)
                n += got
                if got == 0:
                    # the freed blocks alone didn't seat the waiter
                    # (quota-bound, or it needs more than one victim);
                    # stop rather than strip the pool in one round —
                    # the next engine iteration tries again
                    break
        if n:
            if self._tracer.enabled:
                self._tracer.complete("admit", t0, self._now(), cat="engine",
                                      args={"admitted": n})
            if self._metrics is not None:
                self._ctr_admitted.inc(n)
        return n

    def _pick_victim(self) -> Optional[int]:
        """SLO-driven victim selection: when the most urgent waiter is
        already past its TTFT deadline, choose the active request with
        the most lenient (latest) deadline — strictly later than the
        waiter's, so preemption always moves urgency forward and a
        same-tier earlier arrival can never be evicted for a later one —
        breaking ties toward the most recently admitted (least sunk
        work).  Requests already preempted ``max_preemptions`` times are
        exempt."""
        if not len(self.scheduler):
            return None
        sch = self.scheduler
        now = self._now()
        urgent = [sch.deadline(r) for r in sch.queue
                  if sch.deadline(r) <= now]
        if not urgent:
            return None
        w_deadline = min(urgent)
        best: Optional[int] = None
        best_key: Optional[Tuple[float, float]] = None
        for s, a in enumerate(self._active):
            if a is None or a.preemptions >= self._max_preemptions:
                continue
            v_deadline = sch.deadline(a.req)
            if v_deadline <= w_deadline:
                continue
            key = (v_deadline, a.admitted)
            if best_key is None or key > best_key:
                best, best_key = s, key
        return best

    def _preempt(self, slot: int, report: ServingReport) -> None:
        """Swap ``slot``'s request out to host and hand it back to the
        scheduler: blocks and reservation are freed (BlockPool.swap_out),
        so the waiter's admission sees real headroom; the request's tier
        is pinned to its slot's ``k`` so re-admission resumes it at the
        budget it started decoding with."""
        a = self._active[slot]
        if self._spec is not None:
            # an open draft window (positions advanced past the last
            # verified token) must not leak into the swap state — roll
            # the row back to its window base and drop the draft buffer
            self._spec.rollback_open(slot)
        tier = self.slot_k[slot]
        self._tier_reserved[tier] -= self.pool.reserved_for(slot)
        state = self.pool.swap_out(slot)
        a.preemptions += 1
        a.req.k = tier
        self._swapped[a.req.rid] = (state, a, int(self._last_tok[slot, 0]))
        self._active[slot] = None
        self.scheduler.add(a.req)
        report.preemptions += 1
        if self._tracer.enabled:
            a.swap_t = self._now()
            self._tracer.instant("swap_out", a.swap_t, pid=PID_REQUESTS,
                                 tid=a.req.rid, cat="preempt",
                                 args={"slot": slot})
        if self._metrics is not None:
            self._ctr_preempt.inc()

    def _admit_pass(self, report: ServingReport) -> int:
        free = self.pool.free_slots
        if not free or not len(self.scheduler):
            return 0
        can_admit = None
        if self.paged:
            # account blocks as the scheduler accepts: each accepted
            # request is guaranteed a slot, so its projected need comes
            # off the headroom before the next request is considered.
            # The tier quota binds only under cross-tier contention
            # (another tier waiting) — work-conserving when the pool is
            # otherwise idle, starvation-free when it is not.
            booked = 0
            booked_by_tier: Dict[Optional[int], int] = {}
            # slot tiers contended by the waiting queue: a wildcard
            # (k=None) waiter can sit in any tier, so it contends with
            # all of them
            waiting_tiers: set = set()
            for r in self.scheduler.queue:
                if r.k is None:
                    waiting_tiers.update(self._tier_quota)
                    break
                waiting_tiers.add(r.k)

            # escrow for the oldest starved waiter: the FIRST request of
            # the FIFO scan rejected for block AVAILABILITY (not quota)
            # gets its need earmarked — younger requests may only book
            # blocks beyond it, so freed blocks accumulate for it
            # instead of being re-consumed forever by a cross-tier
            # stream of small requests (its wait is bounded by in-flight
            # request lifetimes)
            escrow = 0
            escrow_rid: Optional[int] = None

            def can_admit(req: Request, slot: int) -> bool:
                nonlocal booked, escrow, escrow_rid
                tier = self.slot_k[slot]
                need = self.pool.blocks_needed(self._projected_tokens(req))
                avail = self.pool.available_blocks - booked
                if escrow_rid is not None and req.rid != escrow_rid:
                    avail -= escrow
                if need > avail:
                    if escrow_rid is None or escrow_rid == req.rid:
                        escrow, escrow_rid = need, req.rid
                    return False
                held = (self._tier_reserved[tier]
                        + booked_by_tier.get(tier, 0) + need)
                if held > self._tier_quota[tier] and waiting_tiers - {tier}:
                    return False
                booked += need
                booked_by_tier[tier] = booked_by_tier.get(tier, 0) + need
                return True
        assignments = self.scheduler.admit(free, self.slot_k, can_admit)
        # group rows into (kind, bucket key, tier) prefill batches.
        # "full" groups key on exact prompt length and run the plain
        # prefill.  Under suffix prefill the match runs NOW
        # (attach_prefix, in admission order so same-pass duplicates
        # share); rows with a usable matched head form "suffix" groups
        # keyed by the power-of-two SUFFIX bucket — a flash crowd of
        # long shared-head prompts collapses into small buckets and
        # O(log max_suffix) compiled variants — while cold misses
        # (suffix == whole prompt) take the exact-length full-prefill
        # path, identical launches to a cold engine's.  Items carry
        # (request, slot, suffix start, matched tokens).
        groups: Dict[Tuple[str, int, Optional[int]],
                     List[Tuple[Request, int, int, int]]] = {}
        for req, slot in assignments:
            self.pool.take(slot)
            if self.paged:
                need = self.pool.blocks_needed(self._projected_tokens(req))
                self.pool.reserve(slot, self._projected_tokens(req))
                self._tier_reserved[self.slot_k[slot]] += need
            if req.rid in self._swapped:
                # resume a preempted request: restore its exact KV/SSM
                # state and bookkeeping instead of prefilling — its
                # admitted/first_token timestamps and PRNG event counter
                # continue from where the swap-out left them
                state, a, last = self._swapped.pop(req.rid)
                self.pool.swap_in(slot, state)
                self._active[slot] = a
                self._last_tok[slot, 0] = last
                if self._tracer.enabled:
                    now = self._now()
                    self._tracer.complete(
                        "swapped_out", a.swap_t, now, pid=PID_REQUESTS,
                        tid=req.rid, cat="preempt")
                    self._tracer.instant("swap_in", now, pid=PID_REQUESTS,
                                         tid=req.rid, cat="preempt",
                                         args={"slot": slot})
                continue
            assert req.prompt_len + 1 <= self.slot_len, \
                f"request {req.rid}: prompt {req.prompt_len} leaves no room" \
                f" in a {self.slot_len}-token slot"
            if self._use_suffix:
                L = req.prompt_len
                covered, ready = self.pool.attach_prefix(
                    slot, req.prompt, L, _tier_salt(self.slot_k[slot]))
                # suffix start: round the READY span (pages written and
                # readable in-graph) down to block granularity — the
                # per-row cache-validity mask is idx < sstart, so it must
                # not admit a partially matched block's foreign tail.
                # Floored at L-1: a full-match prompt still runs a
                # 1-token suffix step, so its first sampled token comes
                # from real logits, never a skipped sample.
                bs = self.pool.block_size
                sstart = min((ready // bs) * bs, L - 1)
                key = (("suffix", _bucket(L - sstart))
                       if sstart > 0 else ("full", L))
                groups.setdefault(
                    key + (self.slot_k[slot],),
                    []).append((req, slot, sstart, covered))
            else:
                groups.setdefault(
                    ("full", req.prompt_len, self.slot_k[slot]),
                    []).append((req, slot, 0, 0))

        for (kind, width, kk), items in groups.items():
            nb = len(items)
            bucket = _bucket(nb)
            admitted = self._now()
            real = jnp.asarray(np.arange(bucket) < nb, jnp.float32)
            if kind == "suffix":
                # width == the group's suffix bucket; prompt lengths may
                # differ within it.  Pad rows: empty table, prefix 0,
                # suffix 1 — their gathers are fully masked (pos 0) and
                # their outputs discarded.
                toks = np.zeros((bucket, width), np.int32)
                pref = np.zeros((bucket,), np.int32)
                suf = np.ones((bucket,), np.int32)
                # page-span bucket: only the blocks covering the group's
                # deepest live prefix + the suffix are gathered in-graph
                # (pow-2 to bound compile variants) — a short suffix on
                # a short prefix must not pay a full-slot gather
                bs = self.pool.block_size
                span_b = min(_bucket(-(-(max(
                    st for _, _, st, _ in items) + width) // bs)),
                    self.pool.blocks_per_slot)
                tbl = np.zeros((bucket, span_b), np.int32)
                for j, (req, slot, sstart, _cov) in enumerate(items):
                    n = req.prompt_len - sstart
                    toks[j, :n] = np.asarray(req.prompt[sstart:], np.int32)
                    pref[j], suf[j] = sstart, n
                    tbl[j] = self.pool.block_table[slot][:span_b]
                logits, piece = self._suffix_prefill_fn(
                    self.params, self._prefill_trainable(kk),
                    self.pool.cache, jnp.asarray(toks), jnp.asarray(tbl),
                    jnp.asarray(pref), jnp.asarray(suf), real, k=kk)
                logits_np = np.asarray(logits)      # blocks until ready
                self.pool.write(
                    [s for _, s, _, _ in items], piece,
                    [r.prompt_len for r, _, _, _ in items],
                    starts=[cov for _, _, _, cov in items],
                    piece_col0=[st for _, _, st, _ in items])
                report.prefill_tokens += int(suf[:nb].sum())
                targs = {"batch": nb, "bucket": bucket,
                         "suffix_bucket": width}
            else:
                prompts = np.stack([r.prompt for r, _, _, _ in items]
                                   + [items[0][0].prompt] * (bucket - nb))
                logits, cache = self._prefill_fn(
                    self.params, self._prefill_trainable(kk),
                    jnp.asarray(prompts), real, k=kk)
                logits_np = np.asarray(logits)      # blocks until ready
                if self._use_suffix:
                    # match/attach/alloc already ran at assignment time:
                    # scatter past the matched span only (a same-batch
                    # duplicate recomputes its whole prompt — pending
                    # pages aren't readable in-graph — but must not
                    # rewrite the shared blocks it attached)
                    self.pool.write(
                        [s for _, s, _, _ in items], cache, [width] * nb,
                        starts=[cov for _, _, _, cov in items])
                else:
                    self.pool.write(
                        [s for _, s, _, _ in items], cache, [width] * nb,
                        tokens=[r.prompt for r, _, _, _ in items],
                        salt=_tier_salt(kk))
                report.prefill_tokens += nb * width
                targs = {"batch": nb, "bucket": bucket,
                         "prompt_len": width}
            tft = self._now()
            report.prefill_s.append(tft - admitted)
            report.prefill_hist.observe((tft - admitted) * 1e3)
            if self._tracer.enabled:
                targs["k"] = kk if kk is not None else 0
                self._tracer.complete("prefill", admitted, tft, cat="engine",
                                      args=targs)

            for j, (req, slot, _st, _cov) in enumerate(items):
                max_new = self._max_new(req)
                a = _ActiveSlot(
                    req=req, tokens=[], nll=0.0, admitted=admitted,
                    first_token=tft, max_new=max_new)
                self._active[slot] = a
                tok, nll = self._pick(logits_np[j], a)
                a.tokens.append(tok)
                a.nll += nll
                self._last_tok[slot, 0] = tok
                if len(a.tokens) >= max_new or self.pool.slot_full(slot):
                    self._finish(slot, report)
        return len(assignments)

    # --------------------------------------------------------------- sampling
    def _req_key(self, rid: int) -> jax.Array:
        """The request's PRNG base key, fold_in(seed key, rid), memoized —
        every draw key folds an event counter into this."""
        key = self._req_keys.get(rid)
        if key is None:
            key = jax.random.fold_in(jax.random.PRNGKey(self._seed), rid)
            self._req_keys[rid] = key
        return key

    def _event_key(self, a: _ActiveSlot) -> jax.Array:
        """Next PRNG key for one request: fold (seed, rid, event counter).
        Keys depend only on the request's own draw order, so sampled
        output is independent of what shares the batch."""
        key = jax.random.fold_in(self._req_key(a.req.rid), a.events)
        a.events += 1
        return key

    def _sample(self, logits_row: np.ndarray, a: _ActiveSlot) -> int:
        """One sampler draw for one slot (no forced/NLL handling)."""
        if self._sampler.kind == "greedy":
            return int(np.argmax(logits_row))
        return int(sample_token(self._event_key(a), jnp.asarray(logits_row),
                                self._sampler))

    def _pick(self, logits_row: np.ndarray,
              a: _ActiveSlot) -> Tuple[int, float]:
        """Next token for one slot: the engine's sampler, or the request's
        forced token (accumulating its NLL)."""
        if a.req.forced is not None:
            tok = int(a.req.forced[len(a.tokens)])
            return tok, float(-_log_softmax_np(logits_row)[tok])
        return self._sample(logits_row, a), 0.0

    # ----------------------------------------------------------------- decode
    def _decode_once(self, report: ServingReport) -> None:
        t_start = time.perf_counter()
        active = [s for s, a in enumerate(self._active) if a is not None]
        active_mask = jnp.asarray(
            [a is not None for a in self._active], jnp.float32)
        extra = ()
        if self.paged:
            # allocate each active row's next write block (guaranteed to
            # succeed: covered by the reservation made at admit)
            self.pool.prepare_decode(active)
            extra = (self.pool.tables(),)
        out = self._decode_fn(
            self.params, self._decode_trainable, self.pool.cache,
            jnp.asarray(self._last_tok), self.pool.positions(), active_mask,
            *extra)
        logits, new_cache = out[0], out[1]
        logits_np = np.asarray(logits)              # blocks until ready
        self.pool.cache = new_cache
        dt = time.perf_counter() - t_start
        report.decode_step_s.append(dt)
        report.decode_hist.observe(dt * 1e3)
        if self._expert_telemetry:
            self._expert_tracker.observe_step(
                {p: np.asarray(c) for p, c in out[2].items()})
        if self._tracer.enabled:
            end = self._now()
            self._tracer.complete("decode_step", end - dt, end, cat="engine",
                                  args={"active": len(active)})

        self.pool.advance(active)
        for slot in active:
            a = self._active[slot]
            tok, nll = self._pick(logits_np[slot], a)
            a.tokens.append(tok)
            a.nll += nll
            self._last_tok[slot, 0] = tok
            if len(a.tokens) >= a.max_new or self.pool.slot_full(slot):
                self._finish(slot, report)

    def _finish(self, slot: int, report: ServingReport) -> None:
        a = self._active[slot]
        c = Completion(
            rid=a.req.rid, prompt_len=a.req.prompt_len,
            tokens=np.asarray(a.tokens, np.int32),
            k=self.slot_k[slot] or 0, arrival=a.req.arrival,
            admitted=a.admitted, first_token=a.first_token,
            finished=self._now(), nll_sum=a.nll,
            truncated=len(a.tokens) < a.max_new,
            preemptions=a.preemptions)
        report.completions.append(c)
        if self._tracer.enabled:
            # the request's lifecycle track, emitted retrospectively from
            # the completion's timestamps: an enclosing span plus the
            # queued → prefill → decode phases (swap events were emitted
            # live as the preemptions happened)
            tr = self._tracer
            tid = c.rid
            tr.thread_name(PID_REQUESTS, tid, f"req {tid}")
            args = {"rid": c.rid, "k": c.k, "prompt_len": c.prompt_len,
                    "gen_tokens": c.n_generated,
                    "preemptions": c.preemptions}
            tr.complete("request", c.arrival, c.finished, pid=PID_REQUESTS,
                        tid=tid, cat="request", args=args)
            tr.complete("queued", c.arrival, c.admitted, pid=PID_REQUESTS,
                        tid=tid, cat="request")
            tr.complete("prefill", c.admitted, c.first_token,
                        pid=PID_REQUESTS, tid=tid, cat="request")
            tr.complete("decode", c.first_token, c.finished,
                        pid=PID_REQUESTS, tid=tid, cat="request")
        if self._metrics is not None:
            self._ctr_completions.inc()
            self._ctr_tokens.inc(c.n_generated)
        self._active[slot] = None
        if self.paged:
            self._tier_reserved[self.slot_k[slot]] -= \
                self.pool.reserved_for(slot)
        self.pool.release(slot)

    # ------------------------------------------------------------------- loop
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    @property
    def n_active(self) -> int:
        return sum(a is not None for a in self._active)

    def run(self, requests: Sequence[Request],
            max_steps: Optional[int] = None) -> ServingReport:
        """Serve an open-loop trace to completion.

        Arrival times are interpreted on the engine's wall clock starting
        at call time; ``arrival=0.0`` everywhere makes the run a
        deterministic closed batch.
        """
        assert self.n_active == 0 and not len(self.scheduler), \
            "engine already mid-run"
        # fail fast: reject unservable requests BEFORE any work starts, so
        # a malformed trace can't abort a run mid-flight and discard the
        # in-flight requests' results
        too_long = [r.rid for r in requests
                    if r.prompt_len + 1 > self.slot_len]
        if too_long:
            raise ValueError(
                f"requests {too_long}: prompt leaves no room for a "
                f"generated token in a {self.slot_len}-token slot")
        if self._spec is not None:
            forced = [r.rid for r in requests if r.forced is not None]
            if forced:
                raise ValueError(
                    f"requests {forced}: teacher-forced (NLL) requests "
                    "cannot run under speculative decoding — the drafts "
                    "would diverge from the forced continuation")
        # (no block-capacity fail-fast needed: blocks_needed caps at the
        # per-request span and the pool holds >= one span by construction,
        # so an empty pool can always admit any slot-length-valid request)
        pending = sorted(requests, key=lambda r: r.arrival)
        report = ServingReport(completions=[], num_slots=self.num_slots,
                               slot_k=self.slot_k, slo_ms=self.slo_ms)
        if self._metrics is not None:
            # expose this run's step histograms through the registry
            # (rebound every run; externally owned, so no copying)
            self._metrics.register("serving.decode_step_ms",
                                   report.decode_hist)
            self._metrics.register("serving.prefill_ms", report.prefill_hist)
            if self._spec is not None:
                self._metrics.register("serving.draft_step_ms",
                                       report.draft_hist)
                self._metrics.register("serving.verify_step_ms",
                                       report.verify_hist)
        if self._expert_tracker is not None:
            self._expert_tracker.reset()
        self._t0 = time.perf_counter()
        tr = self._tracer
        if tr.enabled:
            tr.anchor(0.0)           # tracer time == engine-relative time
        steps = 0
        try:
            while pending or len(self.scheduler) or self.n_active:
                now = self._now()
                while pending and pending[0].arrival <= now:
                    self.scheduler.add(pending.pop(0))
                admitted = self._admit(report)
                if tr.enabled:
                    tr.counter("engine", self._now(),
                               {"active_slots": self.n_active,
                                "queue_depth": len(self.scheduler)})
                if self.n_active:
                    if self._spec is not None:
                        self._spec.round(report)
                    else:
                        self._decode_once(report)
                    steps += 1
                    if max_steps is not None and steps >= max_steps:
                        break
                elif not admitted:
                    if pending:              # idle until the next arrival
                        time.sleep(max(0.0,
                                       min(pending[0].arrival - self._now(),
                                           0.01)))
                    elif len(self.scheduler):
                        stuck = [r.rid for r in self.scheduler.queue]
                        raise RuntimeError(
                            f"requests {stuck} match no slot tier "
                            f"(slot_k={self.slot_k})")
        except Exception:
            # flight recorder: leave the last `ring` trace events on disk
            # for a postmortem of the stuck/crashed run, then re-raise
            path = tr.flight_dump()
            if path is not None:
                import sys
                print(f"serving engine: exception — flight recorder "
                      f"dumped to {path}", file=sys.stderr)
            raise
        report.wall_s = self._now()
        report.completions.sort(key=lambda c: c.rid)
        assert not self._swapped or max_steps is not None, \
            "swapped-out requests left behind after a full run"
        if self.prefix_cache:
            report.prefix = self.pool.prefix_stats()
        if self._expert_tracker is not None:
            report.expert_load = self._expert_tracker.snapshot()
            if self._metrics is not None:
                self._expert_tracker.publish(self._metrics)
        return report
