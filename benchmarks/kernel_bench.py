"""Per-op micro-benchmarks of the kernel layer (CPU timings of the jnp
oracles — TPU numbers come from the §Roofline dry-run, not wall clock;
these timings track relative regressions only).  The end-to-end
reference-vs-pallas training-step comparison lives in
``benchmarks.backend_bench``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import emit, timeit


def run() -> None:
    key = jax.random.PRNGKey(0)
    rows = []

    q = jax.random.normal(key, (1, 8, 512, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2, 512, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, 512, 64), jnp.float32)
    rows.append({"kernel": "flash_attention_ref",
                 "us_per_call": timeit(lambda: jax.block_until_ready(
                     ref.flash_attention_ref(q, k, v)))})

    x = jax.random.normal(key, (512, 512))
    w = jax.random.normal(key, (512, 512))
    a = jax.random.normal(key, (512, 16)) * 0.1
    b = jax.random.normal(key, (16, 512)) * 0.1
    rows.append({"kernel": "lora_matmul_ref",
                 "us_per_call": timeit(lambda: jax.block_until_ready(
                     ref.lora_matmul_ref(x, w, a, b, 0.8)))})

    logits = jax.random.normal(key, (8192, 64))
    rows.append({"kernel": "topk_router_ref_k8",
                 "us_per_call": timeit(lambda: jax.block_until_ready(
                     ref.topk_router_ref(logits, 8)[0]))})
    emit("kernel_bench", rows, ["kernel", "us_per_call"])


if __name__ == "__main__":
    run()
