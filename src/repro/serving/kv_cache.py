"""Slotted (paged-lite) KV-cache pool.

One device-resident decode cache of ``num_slots`` fixed-capacity slots
(``model.init_cache`` with ``batch=num_slots``) plus host-side slot
bookkeeping: a free list and a per-slot ``cache_pos``.  Requests of
different lengths occupy different slots of the SAME arrays, so the engine
drives them all through one compiled ``decode_step`` — the per-slot
positions become a ``(num_slots,)`` vector threaded into attention
(scatter write + per-row validity mask, see models/attention.py).

This is the "paged-lite" point on the vLLM axis: whole-slot granularity
instead of fixed-size pages — no block tables, but the same decoupling of
request lifetime from batch shape that continuous batching needs.

All cache leaves carry the layout ``(n_periods, batch, ...)`` — batch is
axis 1 for both attention K/V and Mamba state — which is what
:meth:`SlotPool.write` relies on.
"""
from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib

PyTree = Any


class SlotPool:
    """Fixed-capacity slotted KV-cache pool with allocate/release."""

    def __init__(self, cfg, num_slots: int, slot_len: int):
        assert num_slots >= 1 and slot_len >= 1, (num_slots, slot_len)
        self.cfg = cfg
        self.num_slots = num_slots
        self.slot_len = slot_len
        # attention slots hold min(window, slot_len) positions (ring cache)
        self.attn_len = model_lib.cache_len_for(cfg, slot_len)
        self.cache: PyTree = model_lib.init_cache(cfg, num_slots, slot_len)
        self.cache_pos = np.zeros((num_slots,), np.int32)
        self._free: List[int] = list(range(num_slots))

    # ------------------------------------------------------------ bookkeeping
    @property
    def free_slots(self) -> List[int]:
        """Free slot ids, lowest first (deterministic allocation order)."""
        return sorted(self._free)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def allocate(self) -> int:
        if not self._free:
            raise RuntimeError("SlotPool exhausted")
        self._free.sort()
        return self._free.pop(0)

    def take(self, slot: int) -> None:
        """Claim a specific free slot (scheduler-chosen assignment)."""
        self._free.remove(slot)

    def release(self, slot: int) -> None:
        assert 0 <= slot < self.num_slots and slot not in self._free, slot
        self.cache_pos[slot] = 0
        self._free.append(slot)

    # ------------------------------------------------------------- cache I/O
    def write(self, slots: Sequence[int], piece: PyTree,
              lengths: Sequence[int]) -> None:
        """Install a freshly prefilled cache into ``slots``.

        ``piece``: a cache tree with batch size ``>= len(slots)`` on axis 1
        (extra rows — prefill bucket padding — are ignored);
        ``lengths``: per-slot prompt length, i.e. the position the first
        decode step will write.
        """
        idx = np.asarray(list(slots), np.int32)
        nb = len(idx)

        def put(pool: jnp.ndarray, pc: jnp.ndarray) -> jnp.ndarray:
            return pool.at[:, idx].set(pc[:, :nb].astype(pool.dtype))

        self.cache = jax.tree.map(put, self.cache, piece)
        self.cache_pos[idx] = np.asarray(list(lengths), np.int32)

    def positions(self) -> jnp.ndarray:
        """Per-slot decode positions as a device vector."""
        return jnp.asarray(self.cache_pos)

    def advance(self, slots: Sequence[int]) -> None:
        """One token decoded in each of ``slots``."""
        self.cache_pos[np.asarray(list(slots), np.int32)] += 1

    def slot_full(self, slot: int) -> bool:
        """No room left to write the next decode token (linear cache);
        ring (sliding-window) caches never fill."""
        if self.cfg.attention_window > 0:
            return False
        return int(self.cache_pos[slot]) >= self.attn_len
