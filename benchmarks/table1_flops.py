"""Table 1 — FLOPs-based limitation analysis of rank compression vs FLAME.

Analytic reproduction (exact, not reduced-scale): the paper's β-grid on
OLMo-1.3B (dense) and OLMoE-1.3B/6.9B (SMoE), 128-token context.
Validates: rank compression moves FLOPs <2%; FLAME's expert reduction
reaches 46.1% of the full budget at β4."""
from __future__ import annotations

from repro.configs.registry import get_config
from repro.core.flops import table1_grid

from .common import emit


def run() -> None:
    dense = get_config("olmo-1.3b", "full")
    moe = get_config("olmoe-1.3b-6.9b", "full")
    rows = []
    grid = table1_grid(dense, moe, tokens=128)
    f_full = max(r.flops for r in grid if r.method == "flame")
    for r in grid:
        rows.append({
            "budget": r.budget, "method": r.method, "rank": r.rank,
            "k": r.k,
            "P_total_B": r.params_total / 1e9,
            "P_active_B": r.params_active / 1e9,
            "trainable_M": r.train_total / 1e6,
            "trainable_active_M": r.train_active / 1e6,
            "GFLOPs": r.flops / 1e9,
            "flops_pct_of_full": 100.0 * r.flops / f_full,
        })
    emit("table1_flops", rows,
         ["budget", "method", "rank", "k", "P_total_B", "P_active_B",
          "trainable_M", "trainable_active_M", "GFLOPs",
          "flops_pct_of_full"])

    # the two headline claims, asserted
    moe_rc = [r for r in grid if r.method == "rank-compress/moe"]
    spread = (max(r.flops for r in moe_rc) - min(r.flops for r in moe_rc)) \
        / max(r.flops for r in moe_rc)
    flame = {r.budget: r.flops for r in grid if r.method == "flame"}
    print(f"# rank-compression FLOPs spread: {100 * spread:.1f}% "
          f"(paper: 1.6%); FLAME beta4/beta1: "
          f"{100 * flame['b4'] / flame['b1']:.1f}% (paper: 46.1%)")


if __name__ == "__main__":
    run()
