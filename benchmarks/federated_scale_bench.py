"""Federated scale-out benchmark: thousand-client rounds, host vs device.

Runs the same multi-round FLAME simulation through both round drivers at
growing registry sizes (64 / 256 / 1024 simulated clients; ``--smoke``
keeps the 64-client row only) and reports:

  * per-round wall-clock for each driver — the device driver folds every
    round (subsampling, cohort training, streaming aggregation) into one
    ``lax.scan`` program, so its per-round cost amortises compilation and
    drops the host sync points the Python loop pays per cohort per round;
  * peak *aggregation* bytes, analytic — the pre-streaming path
    concatenated every participant's adapter tree before one
    ``flame_aggregate`` call (``participants × tree``, linear in the
    round size); the streaming accumulator holds one fp32 adapter tree
    plus the per-expert weight mass regardless of how many clients
    streamed through it (flat).  Analytic (leaf sizes × 4 bytes) rather
    than allocator-sampled: CPU jax exposes no reliable live-bytes
    counter, and the tree arithmetic is exact.

Clients run with step batch size 1: at 1024 clients the Dirichlet shards
are tiny, and a larger batch cap would fragment the budget cohorts by
per-client batch size.
"""
from __future__ import annotations

import dataclasses
import json
import time

from repro.configs.base import FederatedConfig

from .common import BENCH_TC, bench_data, bench_model, emit

SCALES = [64, 256, 1024]
ROUNDS = 3
PARTICIPATION = 0.5     # exercises per-round subsampling + padding slots
# smoke (CI): 64 clients, 2 rounds, full participation — stable cohort
# shapes keep the host loop's jit cache warm, so the row stays CPU-cheap
SMOKE_ROUNDS = 2
SMOKE_PARTICIPATION = 1.0


def _tree_bytes(tree) -> int:
    import jax
    return sum(leaf.size * 4 for leaf in jax.tree.leaves(tree))  # fp32


def _acc_bytes(server) -> int:
    """Streaming accumulator footprint: num (one fp32 adapter tree) +
    den_gamma (per-position (n_periods, E)) + den_size (scalar)."""
    from repro.core import aggregation as agg

    acc = agg.flame_acc_init(server.global_lora)
    num = _tree_bytes(acc["num"])
    cfg = server.cfg
    n_pos = sum(1 for p in range(cfg.pattern_period) if cfg.layer_is_moe(p))
    n_periods = cfg.num_layers // cfg.pattern_period
    return num + n_pos * n_periods * cfg.moe.num_experts * 4 + 4


def _run_driver(driver: str, clients: int, rounds: int,
                participation: float):
    from repro.federated.simulation import build_experiment

    cfg = bench_model(moe=True)
    fed = FederatedConfig(num_clients=clients, rounds=rounds,
                          participation=participation, method="flame",
                          temperature=2, round_driver=driver)
    tc = dataclasses.replace(BENCH_TC, batch_size=1, local_epochs=1)
    exp = build_experiment(cfg, fed=fed, tc=tc,
                           data=bench_data(cfg, n_examples=2 * clients))
    t0 = time.perf_counter()
    results = exp.server.run()
    wall = time.perf_counter() - t0
    max_parts = max(len(r.participating) for r in results)
    return wall / len(results), max_parts, exp.server


def run(smoke: bool = False) -> None:
    scales = SCALES[:1] if smoke else SCALES
    rounds = SMOKE_ROUNDS if smoke else ROUNDS
    participation = SMOKE_PARTICIPATION if smoke else PARTICIPATION
    rows, by_scale = [], {}
    for clients in scales:
        for driver in ("host", "device"):
            round_s, max_parts, server = _run_driver(driver, clients,
                                                     rounds, participation)
            tree_b = _tree_bytes(server.global_lora)
            stacked = max_parts * tree_b      # pre-streaming concat peak
            streaming = _acc_bytes(server)
            rows.append({"clients": clients, "driver": driver,
                         "participants": max_parts,
                         "round_s": round_s,
                         "agg_bytes_stacked": stacked,
                         "agg_bytes_streaming": streaming})
            by_scale.setdefault(clients, {})[driver] = round_s
    emit("federated_scale", rows,
         ["clients", "driver", "participants", "round_s",
          "agg_bytes_stacked", "agg_bytes_streaming"])

    big = rows[-1]
    ratio = big["agg_bytes_stacked"] / max(big["agg_bytes_streaming"], 1)
    print(f"# CLAIM federated-scale: streaming aggregation peak is flat — "
          f"{big['agg_bytes_streaming'] / 1e6:.2f} MB at "
          f"{big['clients']} clients vs {big['agg_bytes_stacked'] / 1e6:.2f}"
          f" MB stacked ({ratio:.0f}x)")
    print("# BENCH JSON: " + json.dumps(
        {"bench": "federated_scale", "participation": participation,
         "rounds": rounds,
         "round_s": {str(c): d for c, d in by_scale.items()},
         "agg_bytes_streaming": big["agg_bytes_streaming"],
         "agg_bytes_stacked_at_max_scale": big["agg_bytes_stacked"]}))


if __name__ == "__main__":
    run()
