"""Metrics registry: counters, gauges, fixed-bucket histograms.

Pure-Python, no numpy in the hot path — a histogram observe is one
``bisect`` plus three adds, cheap enough to live in the engine's decode
loop unconditionally. ``MetricsRegistry.snapshot()`` returns a nested
plain-dict structure that is JSON-safe by construction (non-finite
values become ``None`` so ``json.dumps(..., allow_nan=False)`` always
succeeds).

Stateful components that already keep their own counters (``BlockPool``,
``Scheduler``) register a *source*: a callback run at snapshot time that
sets gauges from live state, so sampling costs nothing between
snapshots.
"""
from __future__ import annotations

import json
import math
from bisect import bisect_left
from typing import Callable, Dict, List, Optional, Sequence, Tuple


def _finite(x: float) -> Optional[float]:
    x = float(x)
    return x if math.isfinite(x) else None


class Counter:
    """Monotonic count. ``inc`` only; reset by replacing the object."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "value": _finite(self.value)}


class Gauge:
    """Last-write-wins level (queue depth, blocks in use, ...)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": _finite(self.value)}


def exp_buckets(lo: float, hi: float, factor: float = 1.15,
                ) -> Tuple[float, ...]:
    """Log-spaced bucket upper bounds covering ``[lo, hi]``."""
    if not (lo > 0 and hi > lo and factor > 1):
        raise ValueError("need 0 < lo < hi and factor > 1")
    out = [lo]
    while out[-1] < hi:
        out.append(out[-1] * factor)
    return tuple(out)


# Default latency buckets: 1 µs .. ~60 s expressed in ms, ~124 buckets.
# 15% growth keeps interpolation error on p50/p99 under ~7.5%.
DEFAULT_MS_BUCKETS = exp_buckets(1e-3, 6e4, 1.15)


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``bounds[i]`` is the inclusive upper edge of bucket ``i``; one extra
    overflow bucket catches everything above ``bounds[-1]``. Exact
    min/max are tracked so percentile interpolation never reports a
    value outside the observed range.
    """

    __slots__ = ("bounds", "counts", "count", "total", "min", "max")

    def __init__(self, bounds: Sequence[float] = DEFAULT_MS_BUCKETS) -> None:
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("bucket bounds must be strictly increasing")
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, x: float) -> None:
        self.counts[bisect_left(self.bounds, x)] += 1
        self.count += 1
        self.total += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def percentile(self, q: float) -> Optional[float]:
        """Interpolated ``q``-th percentile (``0 <= q <= 100``), or
        ``None`` when empty. Linear within the containing bucket,
        clamped to the exact observed [min, max]."""
        if not self.count:
            return None
        target = self.count * min(max(q, 0.0), 100.0) / 100.0
        cum = 0
        for i, n in enumerate(self.counts):
            if n == 0:
                continue
            lo = self.bounds[i - 1] if i > 0 else min(self.min, self.bounds[0])
            hi = self.bounds[i] if i < len(self.bounds) else self.max
            if cum + n >= target:
                frac = (target - cum) / n
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return max(self.min, min(self.max, est))
            cum += n
        return self.max

    def snapshot(self) -> dict:
        """JSON-safe summary; only non-empty buckets are listed as
        ``[upper_bound, count]`` pairs (overflow bound is ``None``)."""
        buckets = [[self.bounds[i] if i < len(self.bounds) else None, n]
                   for i, n in enumerate(self.counts) if n]
        return {
            "type": "histogram", "count": self.count,
            "sum": _finite(self.total), "mean": _finite(self.mean or 0.0)
            if self.count else None,
            "min": _finite(self.min) if self.count else None,
            "max": _finite(self.max) if self.count else None,
            "p50": _finite(self.percentile(50) or 0.0) if self.count else None,
            "p90": _finite(self.percentile(90) or 0.0) if self.count else None,
            "p99": _finite(self.percentile(99) or 0.0) if self.count else None,
            "buckets": buckets,
        }


class MetricsRegistry:
    """Name → metric map with get-or-create accessors.

    ``counter/gauge/histogram`` return the existing instrument if one is
    already registered under that name (and raise if the name is bound
    to a different kind). ``register`` binds an externally owned
    instrument — the engine uses it to expose the per-run report
    histograms without copying.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, object] = {}
        self._sources: List[Callable[["MetricsRegistry"], None]] = []

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(*args)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Sequence[float] = DEFAULT_MS_BUCKETS) -> Histogram:
        return self._get(name, Histogram, bounds)

    def register(self, name: str, metric) -> None:
        """Bind (or rebind) ``name`` to an externally owned instrument."""
        self._metrics[name] = metric

    def add_source(self, fn: Callable[["MetricsRegistry"], None]) -> None:
        """``fn(registry)`` runs at every ``snapshot()`` — components use
        it to publish live state (pool occupancy, queue depth) lazily."""
        self._sources.append(fn)

    def snapshot(self) -> Dict[str, dict]:
        for fn in self._sources:
            fn(self)
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, allow_nan=False)
