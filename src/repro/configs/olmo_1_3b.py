"""OLMo-1.3B [dense] — the paper's dense evaluation model (Table 1–4 left
columns).  16L d_model=2048 16H d_ff=8192 vocab=50304.  [arXiv:2402.00838]"""
from .base import LoRAConfig, ModelConfig

FULL = ModelConfig(
    name="olmo-1.3b",
    family="dense",
    num_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50_304,
    rope_theta=10_000.0,
    lora=LoRAConfig(rank=40),    # β1 rank; clients truncate per budget
    source="arXiv:2402.00838",
)

SMOKE = FULL.replace(
    name="olmo-smoke",
    num_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    lora=LoRAConfig(rank=8),
)

SWA_WINDOW = 8192
