"""KV-cache pools for the serving engine: slotted and block-paged.

:class:`SlotPool` is the slotted (paged-lite) pool: one device-resident
decode cache of ``num_slots`` fixed-capacity slots (``model.init_cache``
with ``batch=num_slots``) plus host-side bookkeeping — a free list and a
per-slot ``cache_pos``.  Whole-slot granularity: a short request pins the
same ``slot_len`` of K/V a long one does.

:class:`BlockPool` is the block-paged pool (the vLLM point on the same
axis): attention K/V live in a global pool of fixed-size blocks, each
request row owns a *block table* mapping its logical positions to pool
blocks, blocks are allocated on demand at prefill/decode time and freed at
eviction — so device KV bytes follow tokens in flight, not
``num_slots × slot_len``.  Block id 0 is a reserved null/trash block:
zeroed block-table entries (free rows, unallocated tail) point at it, its
contents are never read (per-row validity masks them out of scores), and
writes from inactive rows land there harmlessly.

Admission math: a request needs
``blocks_needed(min(prompt_len + max_new - 1, page_span))`` blocks over
its lifetime (``page_span`` = per-request logical capacity; the ring
modulus for sliding-window models).  ``reserve`` books that projection at
admit time so on-demand allocation during decode can never fail; the
``available_blocks`` headroom — free blocks minus outstanding unallocated
reservations — is what the scheduler's can-admit predicate consults.

Prefix caching (``prefix_cache=True``, linear caches only): every prompt
block is content-addressed by a chained SHA-1 digest of the token ids it
holds, blocks carry reference counts, and :meth:`write` attaches a new
request to the longest cached chain matching its prompt instead of
scattering duplicate K/V — N concurrent requests sharing a system prompt
hold its blocks ONCE.  A shared attach is charged against the attaching
request's (unchanged, conservative) reservation as *shared*, not owned:
the reservation keeps covering a private replacement, so the
copy-on-write in :meth:`prepare_decode` — taken when a request's next
decode write lands in a block referenced by other rows — can never fail
for want of a free block.  ``release``/``truncate_to`` decrement
refcounts and return a block to the free list only at refcount zero;
free-but-cached blocks are revived on an exact digest match and their
cache entry is evicted when generic allocation repurposes them.

Preemption support: :meth:`swap_out` copies a victim row's live blocks
(and per-row SSM state) to host memory and releases the row — blocks,
reservation and all — so its capacity is genuinely reusable;
:meth:`swap_in` is the exact inverse into a freshly reserved row.
Dropping the reservation at swap-out is what makes preempt/resume
deadlock-free: a swapped request re-enters through normal admission with
the same projected need it was first admitted with (<= pool capacity by
construction), so it can always eventually resume.

All per-row cache leaves carry the layout ``(n_periods, batch, ...)``;
paged attention leaves are ``(n_periods, num_blocks + 1, block_size, KV,
head_dim)``.
"""
from __future__ import annotations

import hashlib
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib

PyTree = Any


@partial(jax.jit, static_argnames=("segs",), donate_argnums=(0,))
def _fused_scatter(cache, piece, blks, offs, row_idx, segs):
    """One device program per :meth:`BlockPool.write`: every (segment,
    leaf) scatter plus the per-row SSM installs, with the pool donated so
    the update happens in place.  Eagerly, each ``.at[].set`` is its own
    dispatch AND a full-pool copy — at serving scale that fixed host cost
    swamps the data actually written, burying exactly the saving
    suffix-only prefill exists to surface.  ``segs`` is the static
    segment structure ``((start, n_cols, piece_col0, row_js), ...)``;
    ``blks``/``offs`` are per-segment (rows, cols) index arrays."""
    def put_paged(pool, pc):
        for i, (st, nc, c0, js) in enumerate(segs):
            pool = pool.at[:, blks[i], offs[i]].set(
                pc[:, np.asarray(js), st - c0:nc - c0].astype(pool.dtype))
        return pool

    def put_rows(pool, pc):
        return pool.at[:, row_idx].set(
            pc[:, :row_idx.shape[0]].astype(pool.dtype))

    out = {}
    for pos_key, c in cache.items():
        if "attn" in c:
            out[pos_key] = {"attn": jax.tree.map(
                put_paged, c["attn"], piece[pos_key]["attn"])}
        else:
            out[pos_key] = {"ssm": jax.tree.map(
                put_rows, c["ssm"], piece[pos_key]["ssm"])}
    return out


class _RowPool:
    """Decode-row bookkeeping shared by both KV pools: a free list of
    rows and a per-row ``cache_pos`` — the machinery that decouples
    request lifetime from the compiled step's batch shape."""

    def __init__(self, cfg, num_slots: int, slot_len: int):
        assert num_slots >= 1 and slot_len >= 1, (num_slots, slot_len)
        self.cfg = cfg
        self.num_slots = num_slots
        self.slot_len = slot_len
        # attention rows hold min(window, slot_len) positions (ring cache)
        self.attn_len = model_lib.cache_len_for(cfg, slot_len)
        self.cache_pos = np.zeros((num_slots,), np.int32)
        self._free: List[int] = list(range(num_slots))

    @property
    def free_slots(self) -> List[int]:
        """Free slot ids, lowest first (deterministic allocation order)."""
        return sorted(self._free)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def allocate(self) -> int:
        """Claim the lowest-id free row and return it."""
        if not self._free:
            raise RuntimeError(f"{type(self).__name__}: no free rows")
        self._free.sort()
        return self._free.pop(0)

    def take(self, slot: int) -> None:
        """Claim a specific free slot (scheduler-chosen assignment)."""
        if slot not in self._free:
            raise ValueError(
                f"{type(self).__name__}.take({slot}): slot is not free "
                f"(free: {self.free_slots})")
        self._free.remove(slot)

    def _require_live(self, slots: Sequence[int]) -> None:
        """Guard for cache writes: every target row must be claimed.
        Writing into a free row would silently corrupt whatever request
        is admitted there next — raise instead."""
        dead = [s for s in slots if s in self._free]
        if dead:
            raise ValueError(
                f"{type(self).__name__}.write: slots {dead} are free "
                f"(allocate/take them first)")

    def release(self, slot: int) -> None:
        """Return a claimed row to the free list."""
        assert 0 <= slot < self.num_slots and slot not in self._free, slot
        self.cache_pos[slot] = 0
        self._free.append(slot)

    def positions(self) -> jnp.ndarray:
        """Per-slot decode positions as a device vector."""
        return jnp.asarray(self.cache_pos)

    def advance(self, slots: Sequence[int]) -> None:
        """One token decoded in each of ``slots``."""
        self.cache_pos[np.asarray(list(slots), np.int32)] += 1

    def truncate_to(self, slot: int, n_tokens: int) -> None:
        """Roll a live row back to ``n_tokens`` written positions — the
        speculative-decode rollback: positions ``>= n_tokens`` (a rejected
        draft suffix) become dead and the next decode write lands at
        ``n_tokens``.  Never grows a row.  Requires an unwrapped cache
        (a wrapped ring has aliased positions; rollback is ill-defined)."""
        if slot in self._free:
            raise ValueError(
                f"{type(self).__name__}.truncate_to({slot}): slot is free")
        held = int(self.cache_pos[slot])
        if self.cfg.attention_window > 0 and held > self.attn_len:
            raise ValueError(
                f"{type(self).__name__}.truncate_to({slot}): ring cache "
                f"has wrapped ({held} > {self.attn_len} positions); "
                f"rollback is ill-defined")
        if not 0 <= n_tokens <= held:
            raise ValueError(
                f"{type(self).__name__}.truncate_to({slot}, {n_tokens}): "
                f"row holds only {held} positions")
        self.cache_pos[slot] = n_tokens

    def slot_full(self, slot: int) -> bool:
        """No room left to write the next decode token (linear cache);
        ring (sliding-window) caches never fill."""
        if self.cfg.attention_window > 0:
            return False
        return int(self.cache_pos[slot]) >= self.attn_len

    def kv_bytes(self) -> int:
        """Device bytes held by the pool's cache tree."""
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache))

    def publish(self, reg) -> None:
        """Set pool gauges on ``reg`` (a repro.obs.MetricsRegistry).
        The engine registers this as a pull *source*, so the pool pays
        nothing between registry snapshots."""
        reg.gauge("serving.kv.num_slots").set(self.num_slots)
        reg.gauge("serving.kv.slots_free").set(self.num_free)
        reg.gauge("serving.kv.kv_bytes").set(self.kv_bytes())


class SlotPool(_RowPool):
    """Fixed-capacity slotted KV-cache pool with allocate/release."""

    def __init__(self, cfg, num_slots: int, slot_len: int):
        super().__init__(cfg, num_slots, slot_len)
        self.cache: PyTree = model_lib.init_cache(cfg, num_slots, slot_len)

    # ------------------------------------------------------------- cache I/O
    def write(self, slots: Sequence[int], piece: PyTree,
              lengths: Sequence[int],
              tokens: Optional[Sequence[np.ndarray]] = None,
              salt: bytes = b"") -> None:
        """Install a freshly prefilled cache into ``slots``.

        ``piece``: a cache tree with batch size ``>= len(slots)`` on axis 1
        (extra rows — prefill bucket padding — are ignored);
        ``lengths``: per-slot prompt length, i.e. the position the first
        decode step will write.  ``tokens`` (the per-slot prompt ids) and
        ``salt`` are accepted for signature parity with
        :meth:`BlockPool.write` and ignored — the slotted layout has no
        block sharing to key.
        """
        del tokens, salt
        self._require_live(slots)
        idx = np.asarray(list(slots), np.int32)
        nb = len(idx)

        def put(pool: jnp.ndarray, pc: jnp.ndarray) -> jnp.ndarray:
            return pool.at[:, idx].set(pc[:, :nb].astype(pool.dtype))

        self.cache = jax.tree.map(put, self.cache, piece)
        self.cache_pos[idx] = np.asarray(list(lengths), np.int32)


class BlockPool(_RowPool):
    """Block-paged KV-cache pool: global block pool + per-row block tables.

    ``num_slots`` decode rows (the compiled step's batch) share
    ``num_blocks`` usable KV blocks of ``block_size`` tokens each (device
    arrays hold one extra trash block at id 0).  Rows and blocks are
    decoupled: admission needs a free row AND the request's projected
    block count (``can_admit``); blocks are reserved at admit, allocated
    lazily (prompt blocks at :meth:`write`, decode blocks at
    :meth:`prepare_decode`), and returned at :meth:`release`.

    ``prefix_cache=True`` turns on content-addressed block sharing for
    prompts (refcounts + copy-on-write; see the module docstring).  It
    requires a linear cache (``cfg.attention_window == 0``): a wrapped
    ring overwrites logical positions in place, which would corrupt
    shared prefix blocks under other readers.

    Mamba SSM state is O(1)/request and stays per-row (never paged).
    """

    def __init__(self, cfg, num_slots: int, slot_len: int,
                 block_size: int = 16, num_blocks: int = None,
                 prefix_cache: bool = False):
        assert block_size >= 1, block_size
        super().__init__(cfg, num_slots, slot_len)
        self.block_size = block_size
        # attn_len doubles as the per-request logical capacity (the ring
        # modulus for sliding-window models)
        self.blocks_per_slot = -(-self.attn_len // block_size)
        if num_blocks is None:
            # full provisioning: every row can hold a max-length request,
            # so admission degenerates to slot availability (parity with
            # SlotPool); size it down to make blocks the scarce resource.
            num_blocks = num_slots * self.blocks_per_slot
        assert num_blocks >= self.blocks_per_slot, (
            f"num_blocks={num_blocks} cannot hold even one max-length "
            f"request ({self.blocks_per_slot} blocks)")
        self.num_blocks = num_blocks
        self.cache: PyTree = model_lib.init_paged_cache(
            cfg, num_slots, num_blocks, block_size)
        self.block_table = np.zeros((num_slots, self.blocks_per_slot),
                                    np.int32)
        self._free_blocks: List[int] = list(range(1, num_blocks + 1))
        self._reserved = np.zeros((num_slots,), np.int64)
        self._nalloc = np.zeros((num_slots,), np.int64)
        self.peak_blocks = 0

        if prefix_cache and cfg.attention_window > 0:
            raise ValueError(
                "prefix_cache requires a linear cache "
                "(cfg.attention_window == 0): a wrapped ring rewrites "
                "logical positions in place under shared readers")
        self.prefix_cache = bool(prefix_cache)
        # per-block reference count (index 0 = trash block, always 0) and
        # per-slot count of table entries attached via live sharing —
        # those are NOT "owned": the slot's reservation keeps covering a
        # private replacement so copy-on-write can never fail
        self._ref = np.zeros((num_blocks + 1,), np.int32)
        self._nshared = np.zeros((num_slots,), np.int64)
        self._shared_mark = np.zeros((num_slots, self.blocks_per_slot),
                                     bool)
        # content-addressed prefix index: chained digest <-> block id
        self._cache_map: Dict[bytes, int] = {}
        self._block_key: Dict[int, bytes] = {}
        # blocks registered in the index whose content is still queued for
        # a future :meth:`write` scatter: the engine attaches prefixes for
        # a whole admission pass BEFORE any group's prefill runs, so a
        # same-pass match on these must not read their pages in-graph —
        # :meth:`attach_prefix` reports the leading already-written span
        # (``ready``) separately from the matched span (``covered``)
        self._pending_blocks: set = set()
        self._pending_by_slot: Dict[int, List[int]] = {}
        # observability counters (prefix_stats / ServingReport)
        self.prefix_hit_blocks = 0
        self.prefix_hit_tokens = 0
        self.prefix_cow_copies = 0
        self.prefix_evictions = 0
        self.swap_outs = 0
        self.swap_ins = 0

    def tables(self) -> jnp.ndarray:
        """Per-row block tables as a device array for the decode step."""
        return jnp.asarray(self.block_table)

    # ----------------------------------------------------- block bookkeeping
    def blocks_needed(self, n_tokens: int) -> int:
        """Blocks covering ``n_tokens`` logical positions (ring-capped)."""
        return -(-min(max(int(n_tokens), 1), self.attn_len)
                 // self.block_size)

    @property
    def blocks_in_use(self) -> int:
        """Distinct pool blocks currently referenced by at least one row
        (a block shared by N rows counts once — the point of sharing)."""
        return self.num_blocks - len(self._free_blocks)

    @property
    def available_blocks(self) -> int:
        """Free blocks not spoken for by outstanding reservations.

        Debt counts *owned* allocations only: a shared-attached block
        leaves its slot's reservation booked for a private replacement,
        which is exactly what guarantees copy-on-write never runs the
        free list dry."""
        owned = self._nalloc - self._nshared
        debt = int((self._reserved - owned).sum())
        return len(self._free_blocks) - debt

    def can_admit(self, n_tokens: int) -> bool:
        """Whether a request projecting ``n_tokens`` positions fits."""
        return self.blocks_needed(n_tokens) <= self.available_blocks

    def reserved_for(self, slot: int) -> int:
        """Blocks currently reserved by ``slot``'s request."""
        return int(self._reserved[slot])

    def reserve(self, slot: int, n_tokens: int) -> None:
        """Book the request's lifetime block projection at admit time, so
        later on-demand allocation (prepare_decode) can never fail."""
        need = self.blocks_needed(n_tokens)
        assert self._reserved[slot] == 0 and self._nalloc[slot] == 0, slot
        assert need <= self.available_blocks, (
            f"reserve({slot}, {n_tokens}): need {need} > available "
            f"{self.available_blocks}")
        self._reserved[slot] = need

    def _evict_entry(self, bid: int) -> None:
        """Drop ``bid``'s prefix-cache entry (its content is about to be
        overwritten by a generic allocation or copy-on-write target)."""
        key = self._block_key.pop(bid, None)
        if key is not None:
            del self._cache_map[key]
            self.prefix_evictions += 1

    def _alloc_block(self, slot: int) -> None:
        assert self._nalloc[slot] < self._reserved[slot], (
            f"slot {slot}: allocation would exceed its reservation "
            f"({self._reserved[slot]} blocks)")
        # pop the list head (NOT lowest-id): deterministic, and it keeps a
        # test-injected permutation (permute_free) in force — physical
        # block order must be invisible to results
        bid = self._free_blocks.pop(0)
        self._evict_entry(bid)
        self._ref[bid] = 1
        self.block_table[slot, self._nalloc[slot]] = bid
        self._nalloc[slot] += 1
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)

    def _attach_block(self, slot: int, bid: int) -> None:
        """Append a cached block to ``slot``'s table instead of
        allocating a fresh one.  A refcount-zero block is *revived* out
        of the free list (a content-preserving allocation, charged as
        owned); a live block is attached as shared — its slot's
        reservation keeps covering a private copy-on-write replacement."""
        assert self._nalloc[slot] < self._reserved[slot], (
            f"slot {slot}: prefix attach would exceed its reservation")
        idx = int(self._nalloc[slot])
        if self._ref[bid] == 0:
            self._free_blocks.remove(bid)
        else:
            self._nshared[slot] += 1
            self._shared_mark[slot, idx] = True
        self._ref[bid] += 1
        self.block_table[slot, idx] = bid
        self._nalloc[slot] += 1
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)

    def _detach_block(self, slot: int, idx: int) -> None:
        """Drop table entry ``idx`` of ``slot``: decrement the block's
        refcount and free it when no row references it any more (its
        prefix-cache entry, if any, survives for revival)."""
        bid = int(self.block_table[slot, idx])
        self._ref[bid] -= 1
        assert self._ref[bid] >= 0, f"block {bid}: negative refcount"
        if self._ref[bid] == 0:
            self._free_blocks.append(bid)
        if self._shared_mark[slot, idx]:
            self._shared_mark[slot, idx] = False
            self._nshared[slot] -= 1
        self.block_table[slot, idx] = 0

    def alloc_prompt(self, slot: int, prompt_len: int) -> None:
        """Allocate the blocks the prompt's K/V will be installed into
        (on top of any prefix-cache attaches already in the table)."""
        while self._nalloc[slot] < self.blocks_needed(prompt_len):
            self._alloc_block(slot)

    def _copy_block(self, src: int, dst: int) -> None:
        """Device-side copy of one block's K/V across attention leaves."""
        def cp(leaf: jnp.ndarray) -> jnp.ndarray:
            return leaf.at[:, dst].set(leaf[:, src])

        new_cache: Dict[str, PyTree] = {}
        for pos_key, c in self.cache.items():
            if "attn" in c:
                new_cache[pos_key] = {"attn": jax.tree.map(cp, c["attn"])}
            else:
                new_cache[pos_key] = c
        self.cache = new_cache

    def _cow(self, slot: int, idx: int) -> None:
        """Copy-on-write: give ``slot`` a private copy of its shared
        table entry ``idx`` before it appends into that block.  The fresh
        block comes out of the slot's own reservation (the attach left it
        booked), so this can never fail."""
        assert self._shared_mark[slot, idx], (slot, idx)
        old = int(self.block_table[slot, idx])
        new = self._free_blocks.pop(0)
        self._evict_entry(new)
        self._copy_block(old, new)
        self._ref[new] = 1
        self._ref[old] -= 1
        assert self._ref[old] >= 1, f"block {old}: CoW from sole referent"
        self._shared_mark[slot, idx] = False
        self._nshared[slot] -= 1
        self.block_table[slot, idx] = new
        self.prefix_cow_copies += 1
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)

    def prepare_decode(self, slots: Sequence[int]) -> None:
        """Allocate, for each active row, the block its next decode write
        lands in (a no-op until the write crosses a block boundary).

        With prefix caching, a row about to append into a block it only
        *shares* first gets a private copy (copy-on-write) — or adopts
        the block in place when every other referent has since released
        it.  A block's original owner never copies: borrowers only ever
        read positions below the shared span, so the owner appending past
        it is invisible to them."""
        for s in slots:
            p = int(self.cache_pos[s])
            logical = p % self.attn_len if self.cfg.attention_window > 0 \
                else min(p, self.attn_len - 1)
            bi = logical // self.block_size
            while self._nalloc[s] <= bi:
                self._alloc_block(s)
            if self._shared_mark[s, bi]:
                if self._ref[int(self.block_table[s, bi])] > 1:
                    self._cow(s, bi)
                else:
                    # sole referent now: adopt in place; the booked
                    # replacement block returns to the headroom
                    self._shared_mark[s, bi] = False
                    self._nshared[s] -= 1

    def truncate_to(self, slot: int, n_tokens: int) -> None:
        """Speculative rollback: drop the row's positions ``>= n_tokens``
        and release the tail blocks past the kept span (refcount-aware —
        a shared tail block survives under its other readers).  The
        reservation stays booked — the request's lifetime projection is
        unchanged, so re-allocating the freed tail during later decode
        (prepare_decode) can never fail."""
        super().truncate_to(slot, n_tokens)            # guards + cache_pos
        keep = -(-min(n_tokens, self.attn_len) // self.block_size)
        n = int(self._nalloc[slot])
        for idx in range(keep, n):
            self._detach_block(slot, idx)
        if keep < n:
            self._nalloc[slot] = keep

    def release(self, slot: int) -> None:
        """Evict a finished request: drop every table entry (refcount-
        aware), clear the reservation, and free the row."""
        # a row released before its write scattered (shouldn't happen in
        # the engine's attach→write window, but stay safe) must drop its
        # pending index entries — the pages were never materialised
        for bid in self._pending_by_slot.pop(slot, []):
            if bid in self._pending_blocks:
                self._pending_blocks.discard(bid)
                self._evict_entry(bid)
        for idx in range(int(self._nalloc[slot])):
            self._detach_block(slot, idx)
        self.block_table[slot, :] = 0
        self._shared_mark[slot, :] = False
        self._reserved[slot] = 0
        self._nalloc[slot] = 0
        self._nshared[slot] = 0
        super().release(slot)                  # asserts against double free

    def permute_free(self, seed: int) -> None:
        """Shuffle free-block allocation order.  Physical block placement
        is invisible to results (tests/test_paged_kv.py proves it)."""
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(self._free_blocks))
        self._free_blocks = [self._free_blocks[i] for i in order]

    def check_invariants(self) -> None:
        """Free-list/refcount integrity: every block's refcount equals
        the number of table entries pointing at it, no block is both
        referenced and free, distinct used + free == total, shared-mark
        bookkeeping is consistent, and no row outruns its reservation."""
        counted: Dict[int, int] = {}
        for s in range(self.num_slots):
            for j in range(int(self._nalloc[s])):
                b = int(self.block_table[s, j])
                counted[b] = counted.get(b, 0) + 1
        used_ids = sorted(counted)
        free_ids = list(self._free_blocks)
        assert 0 not in used_ids, "trash block handed out"
        for b in range(1, self.num_blocks + 1):
            assert int(self._ref[b]) == counted.get(b, 0), \
                f"block {b}: refcount {int(self._ref[b])} != " \
                f"{counted.get(b, 0)} table references"
        assert len(set(free_ids)) == len(free_ids), "double-freed block"
        assert not set(used_ids) & set(free_ids), \
            "block simultaneously used and free"
        assert len(used_ids) + len(free_ids) == self.num_blocks, \
            f"leak: used {len(used_ids)} + free {len(free_ids)} != " \
            f"{self.num_blocks}"
        assert all(1 <= b <= self.num_blocks for b in used_ids + free_ids)
        for s in range(self.num_slots):
            n = int(self._nalloc[s])
            assert (self.block_table[s, n:] == 0).all(), \
                f"slot {s}: stale table entries past nalloc"
            assert not self._shared_mark[s, n:].any(), \
                f"slot {s}: stale shared marks past nalloc"
            assert int(self._shared_mark[s, :n].sum()) \
                == int(self._nshared[s]), f"slot {s}: nshared mismatch"
            assert self._nalloc[s] <= self._reserved[s], \
                f"slot {s}: allocated past its reservation"
        for key, bid in self._cache_map.items():
            assert self._block_key.get(bid) == key, \
                f"prefix index: block {bid} map/reverse-map mismatch"
            assert 1 <= bid <= self.num_blocks
        assert len(self._cache_map) == len(self._block_key)
        listed = {b for bl in self._pending_by_slot.values() for b in bl}
        for bid in self._pending_blocks:
            assert bid in self._block_key, \
                f"pending block {bid} lost its index entry"
            assert int(self._ref[bid]) >= 1, \
                f"pending block {bid} is not allocated"
            assert bid in listed, f"pending block {bid} owned by no slot"
        assert self.available_blocks >= 0

    # --------------------------------------------------------- prefix cache
    def _prefix_keys(self, toks: np.ndarray, salt: bytes = b""
                     ) -> Tuple[List[bytes], Optional[bytes]]:
        """Chained content digests for a prompt: one per FULL block (each
        digest covers the whole prefix up to that block), plus a distinct
        digest for the partial tail block when the prompt doesn't end on
        a block boundary.  Chaining makes a block's key identify its
        entire prefix, so matching is a simple walk.

        ``salt`` seeds the chain: a block's K/V is a function of the
        tokens AND of everything else that shaped the forward pass — for
        the adaptive-k engine, the slot's expert budget.  The engine
        salts with the tier, so equal prompts served at different ``k``
        never alias each other's (numerically different) pages."""
        toks = np.ascontiguousarray(np.asarray(toks, np.int32))
        bs = self.block_size
        keys: List[bytes] = []
        h = b"prefix:" + salt
        for i in range(len(toks) // bs):
            h = hashlib.sha1(h + toks[i * bs:(i + 1) * bs].tobytes()) \
                .digest()
            keys.append(h)
        tail = None
        if len(toks) % bs:
            tail = hashlib.sha1(
                h + b"partial:" + toks[(len(toks) // bs) * bs:].tobytes()
            ).digest()
        return keys, tail

    def _match_prefix(self, toks: np.ndarray, salt: bytes = b""
                      ) -> Tuple[List[int], int]:
        """Longest cached chain matching the prompt: the block ids to
        attach and the token count they cover.  The partial tail block is
        only shareable when the ENTIRE prompt matches a cached partial
        chain — a borrower must never scatter its own K/V into a block
        other rows read."""
        keys, tail = self._prefix_keys(toks, salt)
        bids: List[int] = []
        for key in keys:
            bid = self._cache_map.get(key)
            if bid is None:
                break
            bids.append(bid)
        covered = len(bids) * self.block_size
        if tail is not None and len(bids) == len(keys):
            bid = self._cache_map.get(tail)
            if bid is not None:
                bids.append(bid)
                covered = len(toks)
        return bids, covered

    def _register_prefix(self, slot: int, toks: np.ndarray,
                         salt: bytes = b"") -> List[int]:
        """Index the freshly written prompt blocks of ``slot`` so later
        requests can share them.  Blocks already carrying a key (the
        attached shared prefix itself) are left as they are.  Returns the
        block ids newly added to the index (== the slot's freshly
        allocated prompt blocks)."""
        keys, tail = self._prefix_keys(toks, salt)
        if tail is not None:
            keys = keys + [tail]
        fresh: List[int] = []
        for i, key in enumerate(keys):
            bid = int(self.block_table[slot, i])
            if key in self._cache_map or bid in self._block_key:
                continue
            self._cache_map[key] = bid
            self._block_key[bid] = key
            fresh.append(bid)
        return fresh

    def attach_prefix(self, slot: int,
                      toks: Optional[np.ndarray],
                      prompt_len: int,
                      salt: bytes = b"") -> Tuple[int, int]:
        """Match ``slot``'s prompt against the prefix index, attach the
        matched chain, allocate the remaining prompt blocks, and register
        the fresh ones — everything :meth:`write` used to do per slot
        except the K/V scatter itself.  Returns ``(covered, ready)``:

        * ``covered`` — tokens the attached chain holds (the scatter may
          start there; real matched tokens, never rounded up to blocks);
        * ``ready`` — the leading part of ``covered`` whose pages are
          already *written* (non-pending).  A suffix-only prefill may
          read attached pages strictly below ``ready`` in-graph; pages in
          ``[ready, covered)`` were registered by a not-yet-written slot
          in this same admission pass, so their content must be
          recomputed (but still not re-scattered).

        Pending blocks are always a *suffix* of any matched chain: a
        digest maps to exactly one block, so if chain position ``i`` is
        pending its key was new this pass — and then position ``i+1``'s
        chained digest cannot have existed before either.
        """
        self._require_live([slot])
        covered = ready = 0
        if self.prefix_cache and toks is not None:
            toks = np.asarray(toks, np.int32)[:prompt_len]
            bids, covered = self._match_prefix(toks, salt)
            n_ready = 0
            for bid in bids:
                if bid in self._pending_blocks:
                    break
                n_ready += 1
            ready = (covered if n_ready == len(bids)
                     else n_ready * self.block_size)
            for bid in bids:
                self._attach_block(slot, bid)
            self.prefix_hit_blocks += len(bids)
            self.prefix_hit_tokens += covered
        self.alloc_prompt(slot, prompt_len)
        if self.prefix_cache and toks is not None:
            fresh = self._register_prefix(slot, toks, salt)
            if fresh:
                self._pending_blocks.update(fresh)
                self._pending_by_slot.setdefault(slot, []).extend(fresh)
        return covered, ready

    def prefix_stats(self) -> Dict[str, int]:
        """Prefix-cache observability counters (cumulative)."""
        return {
            "hit_blocks": self.prefix_hit_blocks,
            "hit_tokens": self.prefix_hit_tokens,
            "cow_copies": self.prefix_cow_copies,
            "evictions": self.prefix_evictions,
            "cached_blocks": len(self._cache_map),
        }

    def publish(self, reg) -> None:
        """Paged-pool gauges: block occupancy, reservation headroom,
        swap and prefix-cache counters — sampled at snapshot time."""
        super().publish(reg)
        reg.gauge("serving.kv.num_blocks").set(self.num_blocks)
        reg.gauge("serving.kv.blocks_used").set(self.blocks_in_use)
        reg.gauge("serving.kv.blocks_free").set(
            self.num_blocks - self.blocks_in_use)
        reg.gauge("serving.kv.blocks_available").set(self.available_blocks)
        reg.gauge("serving.kv.blocks_peak").set(self.peak_blocks)
        reg.gauge("serving.kv.swap_outs").set(self.swap_outs)
        reg.gauge("serving.kv.swap_ins").set(self.swap_ins)
        if self.prefix_cache:
            for name, v in self.prefix_stats().items():
                reg.gauge(f"serving.kv.prefix.{name}").set(v)

    # ----------------------------------------------------------- preemption
    def swap_out(self, slot: int) -> Dict[str, Any]:
        """Preempt a live row: copy its allocated blocks' K/V (and its
        per-row SSM state) to host memory, then release the row — blocks,
        reservation and all.  Returns the opaque state :meth:`swap_in`
        restores.  Shared prefix blocks are copied too (the resumed row
        comes back fully private, but swap_in re-registers its prompt
        blocks wherever their keys are still unclaimed, so a round trip
        does not cost the row its shareability)."""
        if slot in self._free:
            raise ValueError(
                f"{type(self).__name__}.swap_out({slot}): slot is free")
        n = int(self._nalloc[slot])
        bids = np.asarray(self.block_table[slot, :n], np.int32)
        # each block's prefix key rides along so swap_in can re-register
        # the surviving prompt blocks — a round trip must not cost the
        # row its shareability
        keys = [self._block_key.get(int(b)) for b in bids]
        blocks: Dict[str, PyTree] = {}
        rows: Dict[str, PyTree] = {}
        for pos_key, c in self.cache.items():
            if "attn" in c:
                blocks[pos_key] = jax.tree.map(
                    lambda leaf: np.asarray(leaf[:, bids]), c["attn"])
            else:
                rows[pos_key] = jax.tree.map(
                    lambda leaf: np.asarray(leaf[:, slot]), c["ssm"])
        state = {"cache_pos": int(self.cache_pos[slot]), "n_blocks": n,
                 "attn": blocks, "ssm": rows, "keys": keys}
        self.swap_outs += 1
        self.release(slot)
        return state

    def swap_in(self, slot: int, state: Dict[str, Any]) -> None:
        """Resume a swapped-out request into a freshly taken AND reserved
        row: allocate as many blocks as it held, scatter the saved
        contents back, and restore its ``cache_pos``.  The caller's
        reservation covers the allocation (held blocks <= the lifetime
        projection the request was re-admitted with), so this cannot
        fail."""
        self._require_live([slot])
        assert self._nalloc[slot] == 0, \
            f"swap_in({slot}): target row already holds blocks"
        for _ in range(int(state["n_blocks"])):
            self._alloc_block(slot)
        bids = np.asarray(self.block_table[slot, :state["n_blocks"]],
                          np.int32)
        new_cache: Dict[str, PyTree] = {}
        for pos_key, c in self.cache.items():
            if "attn" in c:
                new_cache[pos_key] = {"attn": jax.tree.map(
                    lambda leaf, piece: leaf.at[:, bids].set(
                        jnp.asarray(piece).astype(leaf.dtype)),
                    c["attn"], state["attn"][pos_key])}
            else:
                new_cache[pos_key] = {"ssm": jax.tree.map(
                    lambda leaf, piece: leaf.at[:, slot].set(
                        jnp.asarray(piece).astype(leaf.dtype)),
                    c["ssm"], state["ssm"][pos_key])}
        self.cache = new_cache
        self.cache_pos[slot] = state["cache_pos"]
        # re-register the restored prompt blocks under their saved keys:
        # without this a preempted-and-resumed request's shared head
        # silently stops being shareable.  A key may have been re-created
        # by another LIVE row while this one was swapped out — that copy
        # wins.  But if the key only survives on a free-but-cached block
        # (typically this row's own pre-swap blocks), re-point it to the
        # live restored copy: a free block can be reclaimed any moment,
        # while this one is pinned for the request's remaining lifetime.
        if self.prefix_cache:
            for i, key in enumerate(state.get("keys") or []):
                bid = int(self.block_table[slot, i])
                if key is None or bid in self._block_key:
                    continue
                old = self._cache_map.get(key)
                if old is not None:
                    if self._ref[old] > 0:
                        continue
                    del self._block_key[old]
                self._cache_map[key] = bid
                self._block_key[bid] = key
        self.swap_ins += 1

    # ------------------------------------------------------------- cache I/O
    def write(self, slots: Sequence[int], piece: PyTree,
              lengths: Sequence[int],
              tokens: Optional[Sequence[np.ndarray]] = None,
              starts: Optional[Sequence[int]] = None,
              piece_col0: Optional[Sequence[int]] = None,
              salt: bytes = b"") -> None:
        """Install freshly prefilled caches into ``slots``.

        ``piece`` is a contiguous (slotted-layout) cache tree with batch
        ``>= len(slots)`` on axis 1 — exactly what ``model.prefill``
        returns — whose first ``min(len, attn_len)`` columns are scattered
        into each row's (freshly allocated) blocks; Mamba leaves install
        per row.  ``lengths``: per-slot prompt length, i.e. the position
        the first decode step will write.

        ``tokens`` (per-slot prompt ids, required for prefix caching):
        each prompt is first matched against the content-addressed block
        index — matched blocks are attached (refcounted) instead of
        written, only the un-cached suffix is scattered, and the freshly
        written blocks are indexed for the next request.  Same-prompt
        requests admitted in ONE batch share too: matching runs per slot
        in admission order.

        ``starts``/``piece_col0`` (the engine's suffix-prefill path):
        when given, the match/attach/alloc/register step already ran via
        :meth:`attach_prefix` — ``piece`` holds only the recomputed
        suffix, whose column 0 is prompt position ``piece_col0[j]``, and
        scattering begins at ``starts[j]`` (the matched span: the
        attached blocks already hold everything before it).
        """
        slots = [int(s) for s in slots]
        lengths = [int(n) for n in lengths]
        self._require_live(slots)
        if starts is None:
            starts = []
            for j, (s, L) in enumerate(zip(slots, lengths)):
                toks = None if tokens is None else tokens[j]
                covered, _ready = self.attach_prefix(s, toks, L, salt)
                starts.append(covered)
            piece_col0 = [0] * len(slots)
        else:
            starts = [int(v) for v in starts]
            piece_col0 = ([0] * len(slots) if piece_col0 is None
                          else [int(v) for v in piece_col0])

        bs = self.block_size
        n_cols = [min(L, self.attn_len) for L in lengths]
        row_idx = np.asarray(slots, np.int32)

        # one scatter per ((start, n_cols, piece-offset) group, leaf),
        # vectorised across slots and fused into a single donated device
        # program (_fused_scatter) — a per-slot .at[].set chain would
        # copy the whole pool array once per slot, and even per-segment
        # eager ops pay a fixed dispatch+copy cost that dwarfs small
        # suffix writes.  ``start`` skips the columns a shared prefix
        # already holds (start == n_cols: nothing to write).
        by_seg: Dict[Tuple[int, int, int], List[int]] = {}
        for j, (st, nc, c0) in enumerate(zip(starts, n_cols, piece_col0)):
            if st < nc:
                by_seg.setdefault((st, nc, c0), []).append(j)

        segs, blks_l, offs_l = [], [], []
        for (st, nc, c0), js in by_seg.items():
            cols = np.arange(st, nc)
            blks = np.stack([self.block_table[slots[j], cols // bs]
                             for j in js])                  # (nb, nc-st)
            offs = np.ascontiguousarray(
                np.broadcast_to(cols % bs, blks.shape))
            segs.append((st, nc, c0, tuple(js)))
            blks_l.append(jnp.asarray(blks))
            offs_l.append(jnp.asarray(offs))
        self.cache = _fused_scatter(
            self.cache, piece, tuple(blks_l), tuple(offs_l),
            jnp.asarray(row_idx), segs=tuple(segs))
        self.cache_pos[row_idx] = np.asarray(lengths, np.int32)
        # the scatter above materialises every registration these rows
        # left pending: their pages are now readable by later passes
        for s in slots:
            for bid in self._pending_by_slot.pop(s, []):
                self._pending_blocks.discard(bid)

    # ------------------------------------------------------------ reporting
    def block_bytes(self) -> int:
        """Device bytes of ONE block across all attention leaves."""
        total = 0
        for c in self.cache.values():
            if "attn" in c:
                for leaf in jax.tree.leaves(c["attn"]):
                    total += leaf.nbytes // leaf.shape[1]
        return total

    def peak_kv_bytes(self) -> int:
        """High-watermark of device KV bytes actually holding live pages
        (+ the per-row SSM state, which is always resident).  With prefix
        caching a block shared by N rows is counted once — the bytes the
        sharing actually saves."""
        row_bytes = sum(
            leaf.nbytes for c in self.cache.values() if "ssm" in c
            for leaf in jax.tree.leaves(c["ssm"]))
        return self.peak_blocks * self.block_bytes() + row_bytes
