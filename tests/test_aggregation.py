"""Aggregation-scheme tests — the paper's §5 edge-case analysis, verified.

  * t = 0       -> FLAME aggregation ≡ standard FedAvg (Eq. 3–4);
  * zero freq   -> that client contributes NOTHING to that expert;
  * full freq   -> dataset-size weighting (plain FedAvg weights);
  * HLoRA       -> rank components average only over clients that trained them;
  * FlexLoRA    -> ΔW-space FedAvg reproduced through the SVD refactor.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core import lora as L

E, NP, D, R = 4, 1, 8, 4        # experts, periods, dim, rank


def _client_lora(seed):
    key = jax.random.PRNGKey(seed)
    return {"blocks": {"pos0": {"moe": {"experts": {
        "w1": {"a": jax.random.normal(key, (NP, E, D, R)),
               "b": jax.random.normal(jax.random.fold_in(key, 1),
                                      (NP, E, R, D))},
    }}, "attn": {"wq": {"a": jax.random.normal(jax.random.fold_in(key, 2),
                                               (NP, D, R)),
                        "b": jnp.zeros((NP, R, D))}}}}}


def _freq(values):
    return {"pos0": jnp.broadcast_to(jnp.asarray(values, jnp.float32),
                                     (NP, E))}


def test_t0_equals_fedavg():
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [10.0, 30.0]
    freqs = [_freq([0.9, 0.1, 0.5, 0.0]), _freq([0.2, 0.8, 0.5, 1.0])]
    flame = agg.flame_aggregate(loras, freqs, sizes, temperature=0)
    fed = agg.fedavg(loras, sizes)
    for a, b in zip(jax.tree.leaves(flame), jax.tree.leaves(fed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_zero_activation_contributes_nothing():
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [10.0, 10.0]
    # client 0 never activated expert 2; client 1 always did
    freqs = [_freq([0.5, 0.5, 0.0, 0.5]), _freq([0.5, 0.5, 1.0, 0.5])]
    out = agg.flame_aggregate(loras, freqs, sizes, temperature=2)
    got = out["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"][:, 2]
    want = loras[1]["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"][:, 2]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_full_activation_reduces_to_dataset_weighting():
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [10.0, 30.0]
    freqs = [_freq([1.0] * E), _freq([1.0] * E)]
    out = agg.flame_aggregate(loras, freqs, sizes, temperature=4)
    fed = agg.fedavg(loras, sizes)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(fed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_non_expert_adapters_use_dataset_weights():
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [25.0, 75.0]
    freqs = [_freq([0.1] * E), _freq([0.9] * E)]
    out = agg.flame_aggregate(loras, freqs, sizes, temperature=4)
    got = out["blocks"]["pos0"]["attn"]["wq"]["a"]
    want = 0.25 * loras[0]["blocks"]["pos0"]["attn"]["wq"]["a"] + \
        0.75 * loras[1]["blocks"]["pos0"]["attn"]["wq"]["a"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_temperature_sharpens_weighting():
    """Higher t pushes the aggregate toward the high-activation client."""
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [10.0, 10.0]
    freqs = [_freq([0.9] * E), _freq([0.3] * E)]
    hi = loras[0]["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"]

    def dist_to_hi(t):
        out = agg.flame_aggregate(loras, freqs, sizes, temperature=t)
        got = out["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"]
        return float(jnp.abs(got - hi).mean())

    d = [dist_to_hi(t) for t in (0, 1, 2, 4, 8)]
    assert all(d[i] > d[i + 1] for i in range(len(d) - 1)), d


def test_hlora_components_average_over_trainers_only():
    """Client 0 trained rank 2, client 1 rank 4: components 2–3 must come
    from client 1 alone."""
    full = [_client_lora(0), _client_lora(1)]
    truncated = [L.truncate_rank(full[0], 2), full[1]]
    out = agg.hlora_aggregate(truncated, client_ranks=[2, 4],
                              dataset_sizes=[10.0, 10.0], r_full=4)
    got = out["blocks"]["pos0"]["attn"]["wq"]["a"]
    want_hi = full[1]["blocks"]["pos0"]["attn"]["wq"]["a"][..., 2:4]
    np.testing.assert_allclose(np.asarray(got[..., 2:4]),
                               np.asarray(want_hi), rtol=1e-5, atol=1e-6)
    want_lo = 0.5 * (full[0]["blocks"]["pos0"]["attn"]["wq"]["a"][..., :2]
                     + full[1]["blocks"]["pos0"]["attn"]["wq"]["a"][..., :2])
    np.testing.assert_allclose(np.asarray(got[..., :2]),
                               np.asarray(want_lo), rtol=1e-5, atol=1e-6)


def test_flexlora_aggregates_in_delta_space():
    loras = [_client_lora(0), _client_lora(1)]
    sizes = [20.0, 60.0]
    scale = 0.5
    out = agg.flexlora_aggregate(loras, sizes, r_full=R + 6, scale=scale)
    recon = L.merge_delta(out, scale)
    deltas = [L.merge_delta(c, scale) for c in loras]
    want = jax.tree.map(lambda a, b: 0.25 * a + 0.75 * b, *deltas)
    for a, b in zip(jax.tree.leaves(recon), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_activation_frequency_clipped_unit_range():
    f = agg.activation_frequency({"pos0": jnp.asarray([[5.0, 0.0, 12.0]])},
                                 total_tokens=10.0)
    assert float(f["pos0"].max()) <= 1.0 and float(f["pos0"].min()) >= 0.0
