"""Expert-load telemetry.

Serving side: the ragged dispatch plan is built on device from router
outputs, but the router already returns per-expert activation counts
(``MoEAux.activation_counts``) which ``model.decode_step`` can surface
host-side with ``return_counts=True`` — no kernel changes.
:class:`ExpertLoadTracker` turns those per-step ``{poskey: (n_periods,
E)}`` count arrays into occupancy histograms, cumulative totals, and
imbalance summaries (gini, normalized entropy, hottest expert).

Federated side: :class:`ActivationDriftTracker` consumes the per-round
activation *frequencies* FLAME's aggregation runs on (Eq. 6–7) and
reports per-period normalized entropy plus L1 drift against the
previous round — the "did routing move" signal per MoE position.
"""
from __future__ import annotations

import math
from typing import Dict, List, Mapping, Optional

import numpy as np

from repro.obs.metrics import Histogram, MetricsRegistry, exp_buckets


def gini(x) -> float:
    """Gini coefficient of a non-negative load vector (0 = perfectly
    even, →1 = one expert takes everything)."""
    x = np.sort(np.asarray(x, dtype=np.float64).ravel())
    n = x.size
    tot = float(x.sum())
    if n == 0 or tot <= 0:
        return 0.0
    cum = np.cumsum(x)
    return float((n + 1 - 2.0 * cum.sum() / tot) / n)


def entropy(x) -> float:
    """Shannon entropy of a load vector normalized to [0, 1] by
    ``log(len(x))`` (1 = uniform)."""
    x = np.asarray(x, dtype=np.float64).ravel()
    tot = float(x.sum())
    if x.size <= 1 or tot <= 0:
        return 0.0
    p = x / tot
    p = p[p > 0]
    return float(-(p * np.log(p)).sum() / math.log(x.size))


class ExpertLoadTracker:
    """Accumulates per-decode-step expert activation counts.

    ``observe_step`` takes ``{poskey: counts}`` with ``counts`` shaped
    ``(n_periods, E)`` — token→expert assignments routed in that step
    (inactive slots contribute zero; adaptive budgets shrink row sums).
    Keeps cumulative totals per position and a per-step occupancy
    histogram (assignments routed per step), so both "who is hot over
    the run" and "how loaded is a single step" are answerable.
    """

    def __init__(self, num_experts: int) -> None:
        self.num_experts = int(num_experts)
        self.reset()

    def reset(self) -> None:
        self.steps = 0
        self.totals: Dict[str, np.ndarray] = {}
        # assignments routed per decode step (all positions/periods)
        self.step_occupancy = Histogram(exp_buckets(1.0, 1e6, 1.6))

    def observe_step(self, counts: Mapping[str, np.ndarray]) -> None:
        self.steps += 1
        step_total = 0.0
        for pos, arr in counts.items():
            arr = np.asarray(arr, dtype=np.float64)
            arr = arr.reshape(-1, arr.shape[-1])  # (n_periods, E)
            tot = self.totals.get(pos)
            if tot is None:
                self.totals[pos] = arr.copy()
            else:
                tot += arr
            step_total += float(arr.sum())
        self.step_occupancy.observe(step_total)

    # -- summaries --------------------------------------------------------
    def _grand_total(self) -> np.ndarray:
        if not self.totals:
            return np.zeros(self.num_experts)
        return np.sum([t.sum(axis=0) for t in self.totals.values()], axis=0)

    def gini(self) -> float:
        return gini(self._grand_total())

    def entropy(self) -> float:
        return entropy(self._grand_total())

    def hot_expert(self) -> Optional[int]:
        g = self._grand_total()
        return int(np.argmax(g)) if g.sum() > 0 else None

    def snapshot(self) -> dict:
        return {
            "steps": self.steps,
            "num_experts": self.num_experts,
            "assignments_total": float(self._grand_total().sum()),
            "gini": self.gini(),
            "entropy": self.entropy(),
            "hot_expert": self.hot_expert(),
            "totals": {pos: [[float(v) for v in row] for row in t]
                       for pos, t in sorted(self.totals.items())},
            "step_occupancy": self.step_occupancy.snapshot(),
        }

    def publish(self, reg: MetricsRegistry,
                prefix: str = "serving.experts") -> None:
        reg.gauge(f"{prefix}.gini").set(self.gini())
        reg.gauge(f"{prefix}.entropy").set(self.entropy())
        reg.gauge(f"{prefix}.steps").set(self.steps)
        reg.gauge(f"{prefix}.assignments_total").set(
            float(self._grand_total().sum()))
        reg.register(f"{prefix}.step_occupancy", self.step_occupancy)


def _normalize_rows(arr: np.ndarray) -> np.ndarray:
    arr = np.asarray(arr, dtype=np.float64)
    arr = arr.reshape(-1, arr.shape[-1])
    denom = arr.sum(axis=1, keepdims=True)
    return np.divide(arr, denom, out=np.zeros_like(arr), where=denom > 0)


class ActivationDriftTracker:
    """Per-round activation-frequency entropy and L1 drift.

    ``update`` takes the round's mean activation frequencies per MoE
    position (``{poskey: (n_periods, E)}``, e.g. the participation-
    weighted client mean) and returns, per position::

        {"entropy": [per-period normalized entropy ...],
         "entropy_mean": float,
         "l1_drift": float | None}   # None on the first round

    L1 drift is the mean over periods of ``sum |p_t - p_{t-1}|`` after
    row-normalizing each period's distribution (range [0, 2]).
    """

    def __init__(self) -> None:
        self._prev: Dict[str, np.ndarray] = {}
        self.rounds = 0

    def update(self, freqs: Mapping[str, np.ndarray],
               ) -> Dict[str, Dict[str, object]]:
        out: Dict[str, Dict[str, object]] = {}
        nxt: Dict[str, np.ndarray] = {}
        for pos, arr in freqs.items():
            p = _normalize_rows(arr)
            ents = [entropy(row) for row in p]
            prev = self._prev.get(pos)
            drift = (float(np.abs(p - prev).sum(axis=1).mean())
                     if prev is not None and prev.shape == p.shape else None)
            out[pos] = {"entropy": ents,
                        "entropy_mean": float(np.mean(ents)) if ents else 0.0,
                        "l1_drift": drift}
            nxt[pos] = p
        self._prev = nxt
        self.rounds += 1
        return out

    def publish(self, reg: MetricsRegistry,
                per_pos: Mapping[str, Mapping[str, object]],
                prefix: str = "fed.activation") -> None:
        for pos, d in per_pos.items():
            reg.gauge(f"{prefix}.entropy.{pos}").set(
                float(d["entropy_mean"]))
            if d["l1_drift"] is not None:
                reg.gauge(f"{prefix}.l1_drift.{pos}").set(
                    float(d["l1_drift"]))
