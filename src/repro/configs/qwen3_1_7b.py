"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk_norm, GQA.  [hf:Qwen/Qwen3-8B family card]"""
from .base import LoRAConfig, ModelConfig

FULL = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    lora=LoRAConfig(rank=16),
    source="hf:Qwen/Qwen3-8B",
)

SMOKE = FULL.replace(
    name="qwen3-1.7b-smoke",
    num_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    lora=LoRAConfig(rank=4),
)

SWA_WINDOW = 8192
