"""GSPMD sharding rules for every parameter / state / input tree.

The rules implement DESIGN.md §8:

  * 2-D weight sharding for every large matrix: output-features (or the
    expert axis for MoE) on ``model`` (Megatron tensor / expert parallelism)
    and input-features on ``data`` (FSDP — GSPMD all-gathers the shard
    group just-in-time).  This is what makes llama3-405b + its LoRA/Adam
    state fit 256×16 GB chips with base weights frozen.
  * experts: expert axis → ``model`` (expert parallelism; the dispatch and
    combine einsums become all-to-alls on ``model``).
  * LoRA adapters inherit their base weight's sharding on the matching
    dims; the rank dim (tiny) is replicated.
  * decode KV cache: batch → ``data``, sequence → ``model`` (flash-decode
    style; GSPMD merges the partial softmax); mamba state: heads → ``model``.
  * batch dims → ``("pod", "data")`` when divisible, else replicated
    (long_500k has batch 1).

Specs are built by walking the *abstract* tree (jax.eval_shape — no
allocation) and pattern-matching (path, ndim), so the same rule function
covers all six architecture families.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return "/".join(out)


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % size == 0


def _maybe(spec_axes, dim: int, mesh: Mesh):
    """Drop a sharding axis when the dim isn't divisible (XLA pads uneven
    shardings, but padded all-gathers on tiny dims are pure waste)."""
    return spec_axes if spec_axes and _divisible(dim, mesh, spec_axes) else None


# --------------------------------------------------------------------------
# base parameters
# --------------------------------------------------------------------------

def _param_rule(path: str, shape: Tuple[int, ...], mesh: Mesh,
                fsdp: Optional[str], model: str) -> P:
    nd = len(shape)
    leaf = path.rsplit("/", 1)[-1]

    # ---- embedding / head ----
    if path.startswith("embed"):
        if nd == 3:   # (K, V, D) codebooks
            return P(None, _maybe(model, shape[1], mesh), None)
        return P(_maybe(model, shape[0], mesh), None)            # (V, D)
    if path.startswith("lm_head"):
        if nd == 3:   # (K, D, V)
            return P(None, None, _maybe(model, shape[2], mesh))
        return P(None, _maybe(model, shape[1], mesh))            # (D, V)
    if path.startswith("final_norm"):
        return P()

    # ---- blocks (leading axis = n_periods, always unsharded) ----
    if "experts" in path:
        # (np, E, d_in, d_out): expert-parallel on model, FSDP on d_in
        return P(None, _maybe(model, shape[1], mesh),
                 _maybe(fsdp, shape[2], mesh), None)
    if leaf == "router":
        return P(None, None, None)                                # small
    if leaf in ("wq", "wk", "wv"):
        return P(None, _maybe(fsdp, shape[1], mesh),
                 _maybe(model, shape[2], mesh))
    if leaf == "wo":
        return P(None, _maybe(model, shape[1], mesh),
                 _maybe(fsdp, shape[2], mesh))
    if leaf in ("w1", "w3"):                                      # dense/shared
        return P(None, _maybe(fsdp, shape[1], mesh),
                 _maybe(model, shape[2], mesh))
    if leaf == "w2":
        return P(None, _maybe(model, shape[1], mesh),
                 _maybe(fsdp, shape[2], mesh))
    if leaf == "in_proj":                                         # mamba
        return P(None, _maybe(fsdp, shape[1], mesh),
                 _maybe(model, shape[2], mesh))
    if leaf == "out_proj":
        return P(None, _maybe(model, shape[1], mesh),
                 _maybe(fsdp, shape[2], mesh))
    # norms, conv, dt_bias, A_log, D, rescalers, scalars -> replicated
    return P(*([None] * nd))


def param_specs(cfg, abstract_params: PyTree, mesh: Mesh) -> PyTree:
    """PartitionSpec tree mirroring ``init_params`` output."""
    fsdp = "data" if "data" in mesh.axis_names else None
    model = "model"

    def rule(path, leaf):
        return _param_rule(_path_str(path), leaf.shape, mesh, fsdp, model)

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


# --------------------------------------------------------------------------
# trainable tree (LoRA + rescaler)
# --------------------------------------------------------------------------

def _lora_rule(path: str, shape: Tuple[int, ...], mesh: Mesh,
               fsdp: Optional[str], model: str) -> P:
    nd = len(shape)
    leaf = path.rsplit("/", 1)[-1]
    if "rescaler" in path:
        return P(*([None] * nd))
    if "experts" in path:
        # a: (np, E, d_in, r) / b: (np, E, r, d_out) — follow expert sharding
        if leaf == "a":
            return P(None, _maybe(model, shape[1], mesh),
                     _maybe(fsdp, shape[2], mesh), None)
        return P(None, _maybe(model, shape[1], mesh), None, None)
    if leaf == "a":   # (np, d_in, r): shard d_in like the base weight's input
        return P(None, _maybe(fsdp, shape[1], mesh), None)
    if leaf == "b":   # (np, r, d_out): shard d_out on model
        return P(None, None, _maybe(model, shape[2], mesh))
    return P(*([None] * nd))


def trainable_specs(cfg, abstract_trainable: PyTree, mesh: Mesh) -> PyTree:
    fsdp = "data" if "data" in mesh.axis_names else None

    def rule(path, leaf):
        return _lora_rule(_path_str(path), leaf.shape, mesh, fsdp, "model")

    return jax.tree_util.tree_map_with_path(rule, abstract_trainable)


def opt_specs(trainable_spec: PyTree) -> PyTree:
    """Adam state mirrors the trainable tree (mu/nu same sharding)."""
    from ..optim.adam import AdamState
    return AdamState(step=P(), mu=trainable_spec,
                     nu=jax.tree.map(lambda s: s, trainable_spec))


# --------------------------------------------------------------------------
# inputs / batch
# --------------------------------------------------------------------------

def batch_spec(global_batch: int, mesh: Mesh, extra_dims: int = 1) -> P:
    """(B, S, ...) — shard B over ("pod","data") when divisible."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    lead = axes if axes and global_batch % size == 0 else None
    return P(lead, *([None] * extra_dims))


# --------------------------------------------------------------------------
# decode cache
# --------------------------------------------------------------------------

def cache_specs(cfg, abstract_cache: PyTree, mesh: Mesh,
                batch: int) -> PyTree:
    """KV cache (np, B, Sc, KV, hd): batch→data, seq→model.
    Mamba conv (np, B, C, W-1): C→model; ssm state (np, B, H, Pd, N): H→model."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    b_ax = baxes if baxes and batch % bsize == 0 else None

    def rule(path, leaf):
        p = _path_str(path)
        s = leaf.shape
        if "/attn/" in p or p.endswith("/k") or p.endswith("/v"):
            return P(None, b_ax, _maybe("model", s[2], mesh), None, None)
        if p.endswith("conv"):
            return P(None, b_ax, _maybe("model", s[2], mesh), None)
        if p.endswith("ssm"):
            return P(None, b_ax, _maybe("model", s[2], mesh), None, None)
        return P(*([None] * len(s)))

    return jax.tree_util.tree_map_with_path(rule, abstract_cache)


# --------------------------------------------------------------------------
# activation constraint helpers (used inside the step functions)
# --------------------------------------------------------------------------

def activation_spec(mesh: Mesh, mode: str, batch_ok: bool = True) -> P:
    """Sharding constraint for the (B, S, D) residual stream.

    mode: "batch" (B→data only), "dmodel" (also D→model — ZeRO-3-ish, slashes
    the saved-activation footprint for remat'd training of wide models),
    "seq" (S→model — sequence parallelism).
    """
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b = baxes if (baxes and batch_ok) else None
    if mode == "dmodel":
        return P(b, None, "model")
    if mode == "seq":
        return P(b, "model", None)
    return P(b, None, None)


def shardings(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
