# Developer entry points.  All targets assume the src/ layout and set
# PYTHONPATH accordingly; no installation step exists or is needed.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench bench-round docs-check

# tier-1 verification (see ROADMAP.md)
test:
	$(PYTHON) -m pytest -q

# all paper-table/figure benchmarks + kernel and round-engine timings
bench:
	$(PYTHON) -m benchmarks.run

# just the looped-vs-batched round engine comparison
bench-round:
	$(PYTHON) -m benchmarks.run round_engine

# README/docs must only reference modules & functions that exist
docs-check:
	$(PYTHON) tools/docs_check.py README.md docs/architecture.md docs/kernels.md
