# Developer entry points.  All targets assume the src/ layout and set
# PYTHONPATH accordingly; no installation step exists or is needed.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-slow coverage bench bench-round bench-serve bench-smoke docs-check changes-check ci

# tier-1 verification (see ROADMAP.md); pytest.ini excludes -m slow here;
# --durations surfaces the slowest tests so slow-test creep stays visible
test:
	$(PYTHON) -m pytest -q --durations=15

# tier-1 under coverage + the kernels/serving/obs/federated line-coverage
# floor (mirrors the CI coverage job; needs pytest-cov from
# requirements-ci.txt)
coverage:
	$(PYTHON) -m pytest -q --cov=repro --cov-report=xml --cov-report=term
	$(PYTHON) tools/coverage_gate.py coverage.xml --min 70 \
		repro/kernels repro/serving repro/obs \
		repro/serving/sampler.py repro/serving/speculative.py \
		repro/serving/kv_cache.py repro/serving/scheduler.py \
		repro/serving/engine.py \
		repro/obs/trace.py repro/obs/metrics.py \
		repro/obs/expert_load.py \
		repro/federated/server.py repro/core/aggregation.py

# the long-running randomized stress subset (CI runs it in the smoke job)
test-slow:
	$(PYTHON) -m pytest -q -m slow

# all paper-table/figure benchmarks + kernel and round-engine timings
bench:
	$(PYTHON) -m benchmarks.run

# just the looped-vs-batched round engine comparison
bench-round:
	$(PYTHON) -m benchmarks.run round_engine

# serving engine: continuous batching vs sequential + per-slot adaptive k
bench-serve:
	$(PYTHON) -m benchmarks.run serving

# the fast CI subset (kernel micro-bench + backend bench + serving smoke
# + the telemetry overhead guard), JSON results written to
# bench-smoke.json (the CI artifact)
bench-smoke:
	$(PYTHON) -m benchmarks.run --smoke --out bench-smoke.json

# README/docs must only reference modules & functions that exist; the
# serving ops guide's launcher flags are checked against the real parser
docs-check:
	$(PYTHON) tools/docs_check.py README.md docs/architecture.md \
		docs/kernels.md docs/serving.md docs/observability.md \
		--flags docs/serving.md=repro.launch.serve:build_parser \
		--flags docs/observability.md=repro.launch.serve:build_parser

# every PR must commit its CHANGES.md entry (CI runs --base origin/main)
changes-check:
	$(PYTHON) tools/changes_check.py

# local mirror of .github/workflows/ci.yml (keep the two in sync):
# tier-1 tests, slow subset, docs-check, benchmark smoke + artifact,
# CHANGES.md check.  The CI coverage job is mirrored separately by
# `make coverage` (needs pytest-cov, which requirements-ci.txt installs)
ci: changes-check
	$(PYTHON) -m pytest -x -q --durations=15
	$(MAKE) test-slow
	$(MAKE) docs-check
	$(MAKE) bench-smoke
