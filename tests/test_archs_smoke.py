"""Per-assigned-architecture smoke tests (deliverable f).

For every arch: instantiate the REDUCED same-family variant (≤2 effective
periods, d_model ≤ 512, ≤4 experts), run one forward and one LoRA train
step on CPU, assert output shapes and the absence of NaNs; plus a
prefill→decode consistency check.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ASSIGNED_ARCHS, get_config, list_archs
from repro.core import lora as lora_lib
from repro.models import model as M
from repro.optim import adam

ALL_ARCHS = list_archs()          # 10 assigned + the paper's own 2


def _tokens(cfg, key, B, S):
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_config(arch, "smoke")
    assert cfg.d_model <= 512 and cfg.num_layers <= 4
    if cfg.moe.enabled:
        assert cfg.moe.num_experts <= 8
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    toks = _tokens(cfg, key, 2, 32)
    logits, counts = M.forward(cfg, params, toks)
    want = ((2, 32, cfg.num_codebooks, cfg.vocab_size) if cfg.num_codebooks
            else (2, 32, cfg.vocab_size))
    assert logits.shape == want
    assert not bool(jnp.isnan(logits).any())
    if cfg.moe.enabled:
        # every MoE position reports per-expert activation counts
        assert counts, f"{arch}: no activation counts from MoE layers"
        total = sum(float(c.sum()) for c in counts.values())
        assert total > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, "smoke")
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    lora = lora_lib.init_lora(jax.random.fold_in(key, 1), cfg, params)
    resc = (lora_lib.init_rescalers(cfg, max(cfg.moe.top_k - 1, 1))
            if cfg.moe.enabled else None)
    trainable = lora_lib.make_trainable(lora, resc)
    opt = adam.init(trainable)
    toks = _tokens(cfg, key, 2, 32)
    labels = jnp.roll(toks, -1, axis=1)
    mask = jnp.ones((2, 32), jnp.float32)
    k = max(cfg.moe.top_k - 1, 1) if cfg.moe.enabled else None

    def loss_fn(tr):
        loss, counts = M.lm_loss(cfg, params, toks, labels, mask,
                                 trainable=tr, k=k)
        return loss, counts

    (loss0, counts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        trainable)
    assert np.isfinite(float(loss0))
    gnorm = adam.global_norm(grads)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    new_tr, _ = adam.update(grads, opt, trainable, lr=1e-3, grad_clip=1.0)
    loss1, _ = loss_fn(new_tr)[0], None
    assert np.isfinite(float(loss1[0] if isinstance(loss1, tuple) else loss1))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    """decode_step(t) after prefill(t-1 tokens) ≈ forward on full sequence."""
    cfg = get_config(arch, "smoke")
    if cfg.attention_window:
        pytest.skip("ring-cache indexing differs from linear forward")
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    S = 16
    toks = _tokens(cfg, key, 1, S)
    full_logits, _ = M.forward(cfg, params, toks)

    _, cache = M.prefill(cfg, params, toks[:, :S - 1], cache_len=S)
    last_tok = toks[:, S - 1:S]
    dec_logits, _ = M.decode_step(cfg, params, cache, last_tok, S - 1)
    a = np.asarray(full_logits[:, -1], np.float32)
    b = np.asarray(dec_logits[:, 0], np.float32)
    denom = max(np.abs(a).max(), 1e-3)
    assert np.max(np.abs(a - b)) / denom < 0.05, (
        f"{arch}: decode diverges from teacher-forced forward")
