"""Client-side local training (one federated participant).

A client owns a data shard, a resource budget (k_i experts for FLAME /
LoRA rank r_i for the compression baselines), and runs ``local_epochs`` of
Adam over its shard each round (paper A2.2: Adam, lr 1.5e-4, batch 16,
1 local epoch).

The jit'd train step returns per-expert activation counts; the client
accumulates them into the activation frequency a_i^j / S_i that the server's
activation-aware aggregation consumes (token-level frequency — see
core/aggregation.py docstring for the edge-case analysis).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, TrainConfig
from ..core import lora as lora_lib
from ..data.synthetic import Corpus, batches
from ..models import model as model_lib
from ..optim import adam

PyTree = Any


@dataclass
class ClientState:
    client_id: int
    shard: Corpus
    k: int                        # FLAME expert budget k_i
    rank: int                     # LoRA rank (baselines truncate this)
    rescaler: Optional[PyTree]    # client-local s_i (persists across rounds)
    rescaler_mode: str = "learnable"

    @property
    def dataset_size(self) -> int:
        return len(self.shard.tokens)


@partial(jax.jit, static_argnames=("cfg", "k", "tc", "rescaler_trainable"))
def _train_step(cfg: ModelConfig, params, trainable, opt_state, tokens,
                labels, mask, *, k: int, tc: TrainConfig,
                rescaler_trainable: bool):
    def loss_fn(tr):
        loss, counts = model_lib.lm_loss(cfg, params, tokens, labels, mask,
                                         trainable=tr, k=k)
        return loss, counts

    (loss, counts), grads = jax.value_and_grad(loss_fn, has_aux=True)(trainable)
    if not rescaler_trainable and "rescaler" in grads:
        grads = dict(grads)
        grads["rescaler"] = jax.tree.map(jnp.zeros_like, grads["rescaler"])
    new_trainable, new_opt = adam.update(
        grads, opt_state, trainable, lr=tc.learning_rate, beta1=tc.beta1,
        beta2=tc.beta2, eps=tc.eps, weight_decay=tc.weight_decay,
        grad_clip=tc.grad_clip)
    return new_trainable, new_opt, loss, counts


def local_train(cfg: ModelConfig, params: PyTree, global_lora: PyTree,
                client: ClientState, tc: TrainConfig, round_seed: int
                ) -> Tuple[PyTree, Dict[str, jnp.ndarray], float, Dict]:
    """Run the client's local epoch(s).

    Returns (trained_lora, activation_frequencies, total_tokens, info).
    ``global_lora`` arrives already shaped for this client (full for FLAME,
    rank-truncated for HLoRA/FlexLoRA).
    """
    trainable = {"lora": global_lora}
    if client.rescaler is not None:
        trainable["rescaler"] = client.rescaler
    opt_state = adam.init(trainable)
    rng = np.random.default_rng(round_seed * 10_007 + client.client_id)

    count_sums: Dict[str, jnp.ndarray] = {}
    total_tokens = 0.0
    losses = []
    # tiny shards (Dirichlet tail clients) still get >= 1 batch per epoch
    bs = max(1, min(tc.batch_size, len(client.shard.tokens)))
    for _ in range(tc.local_epochs):
        for tokens, labels, mask in batches(client.shard, bs, rng=rng):
            tokens = jnp.asarray(tokens)
            labels = jnp.asarray(labels)
            mask = jnp.asarray(mask)
            trainable, opt_state, loss, counts = _train_step(
                cfg, params, trainable, opt_state, tokens, labels, mask,
                k=client.k, tc=tc,
                rescaler_trainable=(client.rescaler_mode == "learnable"))
            losses.append(float(loss))
            # counts: {pos: (n_periods, E)} per step — accumulate
            for pos, c in counts.items():
                count_sums[pos] = count_sums.get(pos, 0.0) + c
            total_tokens += float(np.prod(tokens.shape[:2]))

    freqs = {pos: np.asarray(c) / max(total_tokens, 1.0)
             for pos, c in count_sums.items()}
    if "rescaler" in trainable:
        client.rescaler = trainable["rescaler"]   # persist s_i locally
    info = {"mean_loss": float(np.mean(losses)) if losses else float("nan"),
            "steps": len(losses)}
    return trainable["lora"], freqs, total_tokens, info


@partial(jax.jit, static_argnames=("cfg", "k"))
def _eval_step(cfg, params, tokens, labels, mask, trainable, k):
    loss, _ = model_lib.lm_loss(cfg, params, tokens, labels, mask,
                                trainable=trainable, k=k)
    return loss


def evaluate(cfg: ModelConfig, params: PyTree, trainable: Optional[PyTree],
             corpus: Corpus, *, k: int, batch_size: int = 16) -> float:
    """Mean masked CE loss over a corpus."""
    tot, n = 0.0, 0
    rng = np.random.default_rng(0)
    for tokens, labels, mask in batches(corpus, batch_size, rng=rng,
                                        drop_last=False):
        loss = _eval_step(cfg, params, jnp.asarray(tokens),
                          jnp.asarray(labels), jnp.asarray(mask),
                          trainable, k)
        tot += float(loss) * len(tokens)
        n += len(tokens)
    return tot / max(n, 1)
