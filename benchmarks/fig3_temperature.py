"""Figure 3/4 — temperature sweep of the activation-aware aggregation.

t = 0 is plain FedAvg; the paper finds t ∈ [2, 4] best, with the gain
largest at constrained budgets under high heterogeneity."""
from __future__ import annotations

from .common import emit, run_setting


def run(temps=(0, 1, 2, 4, 8), rounds=3) -> None:
    rows = []
    for t in temps:
        r = run_setting("flame", budget="b4", alpha=0.5, clients=4,
                        rounds=rounds, temperature=t)
        rows.append({"temperature": t, "score": r["score"],
                     "test_loss": r["test_loss"], "wall_s": r["wall_s"]})
    emit("fig3_temperature", rows,
         ["temperature", "score", "test_loss", "wall_s"])
    s = {r["temperature"]: r["score"] for r in rows}
    best_t = max(s, key=s.get)
    print(f"# best temperature: t={best_t} (score {s[best_t]:.2f}); "
          f"t>0 beats t=0: "
          f"{'CONFIRMS' if max(v for k, v in s.items() if k > 0) >= s[0] else 'REFUTES'}"
          f" (t=0 score {s[0]:.2f})")


if __name__ == "__main__":
    run()
