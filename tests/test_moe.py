"""SMoE layer tests: routing semantics, adaptive k, counts, groups, rescaler."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_moe
from repro.models import moe_layer as moe


def test_topk_mask_selects_k_per_token():
    logits = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
    for k in (1, 2, 4):
        w, m = moe.topk_routing(logits, k)
        np.testing.assert_allclose(np.asarray(m.sum(-1)), k)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
        # selected experts are the k largest-probability ones
        probs = jax.nn.softmax(logits, axis=-1)
        top = np.argsort(-np.asarray(probs), axis=-1)[:, :k]
        for t in range(64):
            assert set(np.where(np.asarray(m[t]) > 0)[0]) == set(top[t])


def test_counts_match_mask_and_total_tokens():
    cfg = tiny_moe()
    key = jax.random.PRNGKey(1)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    out, aux = moe.apply_moe(p, cfg, x, k=2)
    assert out.shape == x.shape
    assert float(aux.total_tokens) == 32.0
    # every token activates exactly k experts => counts sum to k·T
    np.testing.assert_allclose(float(aux.activation_counts.sum()), 2 * 32)


def test_adaptive_k_reduces_capacity_compute():
    """FLAME's FLOPs claim: the dispatch capacity scales with k_i."""
    assert moe._capacity(1024, 8, 4, 1.25) > moe._capacity(1024, 8, 1, 1.25)


def test_group_routing_equivalent_at_high_capacity():
    """G=1 vs G=4 agree when capacity never overflows."""
    cfg = tiny_moe()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(2)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model))
    o1, a1 = moe.apply_moe(p, cfg, x, k=2, num_groups=1)
    o4, a4 = moe.apply_moe(p, cfg, x, k=2, num_groups=4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o4),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a1.activation_counts),
                               np.asarray(a4.activation_counts))


def test_capacity_overflow_drops_to_residual():
    """With capacity factor ~0 every token overflows -> MoE output ≈ 0
    (token falls back to the residual stream), but counts still record
    the routing decisions (Eq. 6 counts activations, not completions)."""
    cfg = tiny_moe()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                               capacity_factor=1e-9))
    key = jax.random.PRNGKey(3)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model))
    out, aux = moe.apply_moe(p, cfg, x, k=2)
    # capacity floor is 8 slots/expert: most of the 128·2 assignments drop
    assert float(jnp.abs(out).mean()) < float(jnp.abs(x).mean())
    np.testing.assert_allclose(float(aux.activation_counts.sum()), 2 * 128)


def test_rescaler_scales_output():
    cfg = tiny_moe()
    key = jax.random.PRNGKey(4)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, cfg.d_model))
    o1, _ = moe.apply_moe(p, cfg, x, k=1, rescaler=None)
    o2, _ = moe.apply_moe(p, cfg, x, k=1, rescaler=jnp.asarray(2.0))
    np.testing.assert_allclose(np.asarray(o2), 2 * np.asarray(o1),
                               rtol=1e-5, atol=1e-6)


def test_shared_experts_always_active():
    cfg = tiny_moe()
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, num_shared_experts=1, d_shared_expert=32))
    key = jax.random.PRNGKey(5)
    p = moe.init_moe(key, cfg)
    assert "shared" in p
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, cfg.d_model))
    out, _ = moe.apply_moe(p, cfg, x, k=1)
    assert out.shape == x.shape
    assert not bool(jnp.isnan(out).any())


def test_fewer_experts_changes_output_not_shape():
    cfg = tiny_moe()
    key = jax.random.PRNGKey(6)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    o2, a2 = moe.apply_moe(p, cfg, x, k=2)
    o1, a1 = moe.apply_moe(p, cfg, x, k=1)
    assert o1.shape == o2.shape
    assert float(a1.activation_counts.sum()) == 0.5 * float(
        a2.activation_counts.sum())
    assert float(jnp.abs(o1 - o2).max()) > 1e-6  # genuinely different compute
