"""Production training launcher.

Three modes:

  * ``--federated`` — run the full federated simulation (build_experiment
    + FederatedServer.run) with the round driver selected by
    ``--round-driver`` (``device`` scans every round into one compiled
    program per checkpoint segment) and streamed resumable checkpoints
    via ``--checkpoint-to`` / ``--resume-from``.
  * ``--local``  — run real federated fine-tuning on this host's devices
    (CPU in this container) at a reduced scale; this is what the e2e
    example drives.
  * default      — build the production mesh (requires a real multi-host
    TPU slice, or the dry-run's forced host-device count), bind the
    sharded train step for ``--arch``, and run ``--steps`` steps on
    synthetic on-device batches.  In this offline container use
    ``repro.launch.dryrun`` instead, which stops after compile.

  PYTHONPATH=src python -m repro.launch.train --local --arch olmoe-1.3b-6.9b
  PYTHONPATH=src python -m repro.launch.train --federated --clients 64 \
      --rounds 4 --round-driver device --checkpoint-to /tmp/fed.ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import INPUT_SHAPES, ShapeConfig, TrainConfig
from ..configs.registry import get_config
from . import steps as steps_lib
from .mesh import make_local_mesh, make_production_mesh


def synthetic_batch(cfg, shape, key):
    tshape = ((shape.global_batch, shape.seq_len, cfg.num_codebooks)
              if cfg.num_codebooks else (shape.global_batch, shape.seq_len))
    tokens = jax.random.randint(key, tshape, 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((shape.global_batch, shape.seq_len), jnp.float32)
    return tokens, labels, mask


def run_federated(args) -> None:
    """--federated: assemble an Experiment and run every round through the
    selected round driver, with streamed checkpoints / resume."""
    from ..configs.base import FederatedConfig
    from ..data.synthetic import DataConfig
    from ..federated.simulation import build_experiment

    cfg = get_config(args.arch, args.variant or "smoke")
    fed = FederatedConfig(num_clients=args.clients, rounds=args.rounds,
                          participation=args.participation,
                          round_driver=args.round_driver,
                          checkpoint_every=args.checkpoint_every,
                          seed=args.seed)
    tc = TrainConfig(batch_size=8, local_epochs=1)
    data = DataConfig(vocab_size=cfg.vocab_size,
                      n_examples=max(args.clients * 8, 64),
                      seq_len=64, n_clusters=4)
    exp = build_experiment(cfg, fed=fed, tc=tc, data=data)
    t0 = time.time()
    results = exp.server.run(resume_from=args.resume_from,
                             checkpoint_to=args.checkpoint_to)
    dt = time.time() - t0
    for res in results:
        finite = [l for l in res.client_losses if np.isfinite(l)]
        mean = float(np.mean(finite)) if finite else float("nan")
        print(f"round {res.round_idx}: {len(res.participating)} clients, "
              f"mean loss {mean:.4f}")
    per_round = dt / max(len(results), 1)
    print(f"{len(results)} rounds via {fed.round_driver!r} driver in "
          f"{dt:.2f}s ({per_round:.2f}s/round)")
    print("done")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1.3b-6.9b")
    ap.add_argument("--variant", default=None,
                    help="full|smoke|swa (default: smoke for --local)")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--k", type=int, default=None,
                    help="FLAME client expert budget k_i")
    # federated-simulation mode
    ap.add_argument("--federated", action="store_true",
                    help="run the federated simulation end-to-end")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--round-driver", default="host",
                    choices=("host", "device"),
                    help="host = per-round Python loop (oracle); device = "
                         "one lax.scan program per checkpoint segment")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="device driver: rounds per checkpoint segment")
    ap.add_argument("--checkpoint-to", default=None)
    ap.add_argument("--resume-from", default=None)
    args = ap.parse_args()

    if args.federated:
        run_federated(args)
        return

    if args.local:
        mesh = make_local_mesh()
        cfg = get_config(args.arch, args.variant or "smoke")
        shape = ShapeConfig("local_train", seq_len=64, global_batch=8,
                            kind="train")
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch, args.variant or "full")
        shape = INPUT_SHAPES[args.shape]

    key = jax.random.PRNGKey(0)
    with mesh:
        bundle = steps_lib.build_train(cfg, shape, mesh, k=args.k,
                                       tc=TrainConfig())
        print(f"{cfg.name} × {shape.name} on {mesh.devices.shape}: "
              f"knobs={bundle.meta}")
        # materialise real state (local mode only — production state comes
        # from the checkpoint/restore path)
        from ..core import lora as lora_lib
        from ..models import model as model_lib
        from ..optim import adam
        params = model_lib.init_params(key, cfg)
        lora = lora_lib.init_lora(jax.random.fold_in(key, 1), cfg, params)
        resc = (lora_lib.init_rescalers(cfg, bundle.meta["k"] or 1)
                if cfg.moe.enabled else None)
        trainable = lora_lib.make_trainable(lora, resc)
        opt = adam.init(trainable)

        for step in range(args.steps):
            tokens, labels, mask = synthetic_batch(
                cfg, shape, jax.random.fold_in(key, 100 + step))
            t0 = time.time()
            trainable, opt, metrics = bundle.fn(params, trainable, opt,
                                                tokens, labels, mask)
            loss = float(metrics["loss"])
            print(f"step {step}: loss {loss:.4f} "
                  f"({time.time() - t0:.2f}s)")
            assert np.isfinite(loss)
    print("done")


if __name__ == "__main__":
    main()
