"""Paged KV cache: differential + property test harness.

The paged serving engine (``kv_layout="paged"``, kv_cache.BlockPool) is a
rewrite of the correctness-critical decode hot path, so it is proven
against two independent oracles:

* **differential**: token-for-token (and NLL-for-NLL) parity of the paged
  engine vs the PR 3 slotted engine vs the naive full-batch decode loop,
  on randomized mixed-length / mixed-tier traces, across both kernel
  backends and k in {1, 2, full} — including a sliding-window (ring)
  config and a block-starved pool that forces queued admission;
* **property**: arbitrary interleavings of allocate/extend/free on
  ``BlockPool`` (and the legacy ``SlotPool``) preserve free-list
  integrity — no double-allocation, no leaks across free/re-admit
  cycles, ``used + free == total`` after every operation — and physical
  block placement (block-table permutation) cannot change outputs.

The interleaving tests run under hypothesis when it is installed (CI) and
fall back to a seeded sweep of the same driver otherwise, so they never
silently skip.
"""
import jax
import numpy as np
import pytest

from conftest import tiny_moe
from repro.configs.base import KernelConfig
from repro.models import model as M
from repro.serving import (BlockPool, Request, ServingEngine, SlotPool,
                           WorkloadConfig, make_trace)

from test_serving import naive_decode

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

CFG = tiny_moe()
PARAMS = M.init_params(jax.random.PRNGKey(0), CFG)
RNG = np.random.default_rng(0)
TIERS = (1, 2, CFG.moe.num_experts)                    # constrained..full


# ==========================================================================
# trace + engine-pair helpers
# ==========================================================================

def _mixed_trace(n, *, seed, lens=(4, 8), new=(2, 5), tiers=TIERS,
                 forced_frac=0.5, rate=float("inf")):
    """Randomized mixed-length / mixed-tier trace; a ``forced_frac`` of
    requests run teacher-forced so the differential covers NLL too."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        if np.isfinite(rate) and i > 0:
            t += float(rng.exponential(1.0 / rate))
        L = int(rng.choice(lens))
        n_new = int(rng.choice(new))
        prompt = rng.integers(0, CFG.vocab_size, (L,)).astype(np.int32)
        forced = None
        if rng.random() < forced_frac:
            forced = rng.integers(0, CFG.vocab_size,
                                  (n_new,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=n_new,
                            k=int(rng.choice(tiers)), arrival=t,
                            forced=forced))
    return reqs


def _slot_k_for(tiers, num_slots):
    per = num_slots // len(tiers)
    out = []
    for k in tiers:
        out.extend([k] * per)
    out.extend([tiers[-1]] * (num_slots - len(out)))
    return tuple(out)


def _assert_same_results(rep_a, rep_b):
    toks_a, toks_b = rep_a.tokens_by_rid(), rep_b.tokens_by_rid()
    assert toks_a.keys() == toks_b.keys()
    for rid in toks_a:
        np.testing.assert_array_equal(toks_a[rid], toks_b[rid])
    nll_a = {c.rid: c.nll_sum for c in rep_a.completions}
    nll_b = {c.rid: c.nll_sum for c in rep_b.completions}
    for rid in nll_a:
        np.testing.assert_allclose(nll_a[rid], nll_b[rid], rtol=1e-5)


# ==========================================================================
# differential: paged engine == slotted engine == naive loop
# ==========================================================================

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_paged_differential_mixed_tiers_and_lengths(backend):
    """Randomized mixed trace through paged vs slotted vs naive, per
    kernel backend, tiers {1, 2, full}."""
    cfg = CFG.replace(kernels=KernelConfig(backend=backend))
    num_slots, slot_len = 6, 16
    slot_k = _slot_k_for(TIERS, num_slots)
    reqs = _mixed_trace(12, seed=7)
    paged = ServingEngine(cfg, PARAMS, num_slots=num_slots,
                          slot_len=slot_len, slot_k=slot_k,
                          kv_layout="paged", block_size=4)
    slotted = ServingEngine(cfg, PARAMS, num_slots=num_slots,
                            slot_len=slot_len, slot_k=slot_k,
                            kv_layout="slotted")
    rp, rs = paged.run(reqs), slotted.run(reqs)
    _assert_same_results(rp, rs)

    # greedy requests also check out against the naive full-batch loop,
    # grouped by (prompt_len, k) so each group is one reference run
    toks = rp.tokens_by_rid()
    groups = {}
    for r in reqs:
        if r.forced is None:
            groups.setdefault((r.prompt_len, r.k), []).append(r)
    for (L, k), members in groups.items():
        n_new = max(r.max_new_tokens for r in members)
        ref = naive_decode(cfg, PARAMS, np.stack([r.prompt
                                                  for r in members]),
                           n_new, k)
        for j, r in enumerate(members):
            np.testing.assert_array_equal(ref[j, :r.max_new_tokens],
                                          toks[r.rid])
    # nothing leaked: every block is back on the free list
    assert paged.pool.blocks_in_use == 0
    assert paged.pool.available_blocks == paged.pool.num_blocks
    paged.pool.check_invariants()


def test_paged_differential_sliding_window_ring():
    """Ring (sliding-window) caches page the same way: the block table is
    addressed mod the ring span.  Paged == slotted == naive."""
    cfg = tiny_moe(attention_window=6)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = RNG.integers(0, cfg.vocab_size, (4, 8)).astype(np.int32)
    new = 6
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=new, k=2)
            for i in range(4)]
    kw = dict(num_slots=4, slot_len=8 + new, slot_k=(2,) * 4)
    rp = ServingEngine(cfg, params, kv_layout="paged", block_size=4,
                       **kw).run(reqs)
    rs = ServingEngine(cfg, params, kv_layout="slotted", **kw).run(reqs)
    _assert_same_results(rp, rs)
    ref = naive_decode(cfg, params, prompts, new, 2)
    got = rp.tokens_by_rid()
    np.testing.assert_array_equal(ref, np.stack([got[i] for i in range(4)]))


def test_paged_block_starved_pool_queues_and_matches():
    """A pool with fewer blocks than the trace needs concurrently forces
    block-gated admission (requests wait for blocks, not slots) — results
    must still equal the unconstrained slotted engine, and the pool must
    come back empty."""
    reqs = _mixed_trace(8, seed=11, lens=(8,), new=(4,), tiers=(2,),
                        forced_frac=0.0)
    # 8-token prompt + 4 new => 11 positions => 3 blocks of 4; 7 usable
    # blocks admit at most 2 requests at a time onto the 4 rows
    paged = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                          slot_k=(2,) * 4, kv_layout="paged",
                          block_size=4, num_blocks=7)
    slotted = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                            slot_k=(2,) * 4, kv_layout="slotted")
    rp, rs = paged.run(reqs), slotted.run(reqs)
    _assert_same_results(rp, rs)
    assert paged.pool.blocks_in_use == 0
    assert paged.pool.peak_blocks <= 7
    paged.pool.check_invariants()


def test_paged_truncates_at_capacity_like_slotted():
    """Linear-cache capacity semantics survive paging: generation stops
    when the last block position is written."""
    req = Request(rid=0, prompt=RNG.integers(0, CFG.vocab_size, (8,))
                  .astype(np.int32), max_new_tokens=64)
    outs = []
    for layout in ("paged", "slotted"):
        eng = ServingEngine(CFG, PARAMS, num_slots=1, slot_len=10,
                            slot_k=(2,), kv_layout=layout, block_size=4)
        [comp] = eng.run([req]).completions
        assert comp.truncated and comp.n_generated == 3
        outs.append(comp.tokens)
    np.testing.assert_array_equal(outs[0], outs[1])


def test_block_table_permutation_and_history_independence():
    """Physical block placement is invisible: permuting the free-block
    order between runs, and recycling a pool dirtied by earlier traffic,
    both produce byte-identical results to a fresh engine."""
    reqs = _mixed_trace(6, seed=3, forced_frac=0.0, tiers=(2,))
    eng = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                        slot_k=(2,) * 4, kv_layout="paged", block_size=4)
    base = eng.run(reqs).tokens_by_rid()
    for seed in (1, 2):
        eng.pool.permute_free(seed)
        got = eng.run(reqs).tokens_by_rid()          # dirty pool + permuted
        assert base.keys() == got.keys()
        for rid in base:
            np.testing.assert_array_equal(base[rid], got[rid])
    fresh = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                          slot_k=(2,) * 4, kv_layout="paged", block_size=4)
    got = fresh.run(reqs).tokens_by_rid()
    for rid in base:
        np.testing.assert_array_equal(base[rid], got[rid])


@pytest.mark.slow
def test_paged_vs_slotted_long_poisson_stress():
    """Long deterministic Poisson trace (>= 200 requests, mixed lengths,
    mixed premium/economy tiers, teacher-forced subset) through the paged
    engine vs the slotted engine: identical tokens, identical NLL."""
    reqs = _mixed_trace(200, seed=42, lens=(4, 8), new=(2, 4, 6),
                        tiers=(1, 2), forced_frac=0.3, rate=400.0)
    kw = dict(num_slots=8, slot_len=16,
              slot_k=(2,) * 4 + (1,) * 4)
    paged = ServingEngine(CFG, PARAMS, kv_layout="paged", block_size=4,
                          num_blocks=20, **kw)
    slotted = ServingEngine(CFG, PARAMS, kv_layout="slotted", **kw)
    rp, rs = paged.run(reqs), slotted.run(reqs)
    assert len(rp.completions) == len(rs.completions) == 200
    _assert_same_results(rp, rs)
    assert paged.pool.blocks_in_use == 0
    paged.pool.check_invariants()


# ==========================================================================
# BlockPool unit mechanics
# ==========================================================================

def test_block_pool_admission_math():
    pool = BlockPool(CFG, num_slots=4, slot_len=16, block_size=4,
                     num_blocks=10)
    assert pool.blocks_per_slot == 4
    assert pool.blocks_needed(1) == 1
    assert pool.blocks_needed(4) == 1
    assert pool.blocks_needed(5) == 2
    assert pool.blocks_needed(999) == 4              # capped at the span
    assert pool.available_blocks == 10 and pool.can_admit(16)

    s = pool.allocate()
    pool.reserve(s, 11)                              # 3 blocks projected
    assert pool.available_blocks == 7                # debt counted up front
    pool.alloc_prompt(s, 8)                          # 2 blocks materialise
    assert pool.blocks_in_use == 2 and pool.available_blocks == 7
    pool.cache_pos[s] = 8
    pool.prepare_decode([s])                         # pos 8 -> 3rd block
    assert pool.blocks_in_use == 3
    pool.check_invariants()

    pool.release(s)
    assert pool.blocks_in_use == 0
    assert pool.available_blocks == 10
    assert (pool.block_table == 0).all()
    with pytest.raises(AssertionError):
        pool.release(s)                              # double free
    pool.check_invariants()


def test_block_pool_reservation_is_a_hard_ceiling():
    pool = BlockPool(CFG, num_slots=2, slot_len=16, block_size=4,
                     num_blocks=8)
    s = pool.allocate()
    pool.reserve(s, 4)                               # 1 block
    pool.alloc_prompt(s, 4)
    pool.cache_pos[s] = 4
    with pytest.raises(AssertionError):              # would need block 2
        pool.prepare_decode([s])
    pool.release(s)
    pool.check_invariants()


def test_block_pool_write_roundtrip():
    """Prefilled K/V scattered into blocks gathers back exactly, and the
    trash block (id 0) is never handed out."""
    import jax.numpy as jnp
    from repro.models.attention import paged_gather
    pool = BlockPool(CFG, num_slots=3, slot_len=8, block_size=4)
    L = 6
    prompts = RNG.integers(0, CFG.vocab_size, (2, L)).astype(np.int32)
    _, piece = M.prefill(CFG, PARAMS, jnp.asarray(prompts), k=2,
                         cache_len=8)
    s0, s1 = pool.allocate(), pool.allocate()
    pool.reserve(s0, 7), pool.reserve(s1, 7)
    pool.write([s0, s1], piece, [L, L])
    assert 0 not in pool.block_table[[s0, s1], :pool._nalloc[s0]]
    for leaf in ("k", "v"):
        pooled = pool.cache["pos0"]["attn"][leaf]
        want = np.asarray(piece["pos0"]["attn"][leaf])
        for p in range(pooled.shape[0]):             # periods
            got = np.asarray(paged_gather(pooled[p], pool.tables(),
                                          pool.attn_len))
            np.testing.assert_allclose(got[s0, :L], want[p, 0, :L])
            np.testing.assert_allclose(got[s1, :L], want[p, 1, :L])
    assert list(pool.cache_pos[[s0, s1]]) == [L, L]
    pool.check_invariants()


# ==========================================================================
# negative paths: pool MISUSE must raise instead of corrupting the free
# list (the interleaving drivers below only exercise legal sequences)
# ==========================================================================

def test_slot_pool_misuse_raises():
    import jax.numpy as jnp
    pool = SlotPool(CFG, num_slots=2, slot_len=8)
    pool.take(0)
    with pytest.raises(ValueError, match="not free"):
        pool.take(0)                                 # double take
    with pytest.raises(AssertionError):
        pool.release(1)                              # release a free slot
    prompt = RNG.integers(0, CFG.vocab_size, (1, 4)).astype(np.int32)
    _, piece = M.prefill(CFG, PARAMS, jnp.asarray(prompt), k=2,
                         cache_len=8)
    with pytest.raises(ValueError, match="free"):
        pool.write([1], piece, [4])                  # write to a free slot
    pool.take(1)
    with pytest.raises(RuntimeError, match="no free rows"):
        pool.allocate()                              # admit beyond the pool
    # the failed ops corrupted nothing: both rows still live, release works
    assert pool.num_free == 0
    pool.release(0), pool.release(1)
    assert pool.free_slots == [0, 1]


def test_block_pool_write_past_reservation_raises():
    """A write needing more blocks than the row's admission-time
    reservation must fail (the reservation is the hard ceiling that makes
    decode allocation infallible) — and fail WITHOUT corrupting the
    free-list bookkeeping."""
    import jax.numpy as jnp
    pool = BlockPool(CFG, num_slots=2, slot_len=16, block_size=4)
    s = pool.allocate()
    pool.reserve(s, 4)                               # 1 block booked
    prompt = RNG.integers(0, CFG.vocab_size, (1, 8)).astype(np.int32)
    _, piece = M.prefill(CFG, PARAMS, jnp.asarray(prompt), k=2,
                         cache_len=16)
    with pytest.raises(AssertionError, match="exceed its reservation"):
        pool.write([s], piece, [8])                  # needs 2 blocks
    pool.check_invariants()                          # nothing leaked
    pool.release(s)
    assert pool.available_blocks == pool.num_blocks


def test_block_pool_misuse_raises():
    pool = BlockPool(CFG, num_slots=2, slot_len=16, block_size=4,
                     num_blocks=4)
    # (a single reservation can never exceed the pool: blocks_needed caps
    # at the per-request span and the pool holds >= one span — the
    # overflow paths below are all CROSS-request)
    s = pool.allocate()
    pool.reserve(s, 16)                              # all 4 blocks
    with pytest.raises(AssertionError):
        pool.reserve(s, 4)                           # double reserve
    s2 = pool.allocate()
    with pytest.raises(AssertionError):
        pool.reserve(s2, 4)                          # no headroom left
    with pytest.raises(ValueError, match="not free"):
        pool.take(s2)                                # take a live row
    with pytest.raises(RuntimeError, match="no free rows"):
        pool.allocate()
    pool.release(s), pool.release(s2)
    with pytest.raises(AssertionError):
        pool.release(s)                              # double free
    pool.check_invariants()
    assert pool.available_blocks == pool.num_blocks


def test_block_pool_truncate_to_guards_and_frees_tail():
    """The speculative-rollback primitive's negative paths: rolling back
    a free slot or past a row's written length must raise, and a legal
    rollback must return exactly the dead tail blocks to the free list
    while keeping the admission-time reservation booked (regrowth over
    the freed span can never fail)."""
    pool = BlockPool(CFG, num_slots=2, slot_len=16, block_size=4)
    with pytest.raises(ValueError, match="slot is free"):
        pool.truncate_to(0, 0)                       # rollback a free row
    s = pool.allocate()
    pool.reserve(s, 16)                              # 4 blocks booked
    pool.alloc_prompt(s, 6)
    pool.cache_pos[s] = 6
    for _ in range(6):                               # decode up to 12 held
        pool.prepare_decode([s])
        pool.advance([s])
    assert int(pool._nalloc[s]) == 3
    with pytest.raises(ValueError, match="holds only"):
        pool.truncate_to(s, 13)                      # past cache_pos
    with pytest.raises(ValueError, match="holds only"):
        pool.truncate_to(s, -1)
    assert int(pool.cache_pos[s]) == 12              # failed ops: no change
    free_before = sorted(pool._free_blocks)
    tail = [int(b) for b in pool.block_table[s, 1:3]]
    pool.truncate_to(s, 2)                           # keep only block 0
    assert int(pool.cache_pos[s]) == 2
    assert int(pool._nalloc[s]) == 1
    assert sorted(pool._free_blocks) == sorted(free_before + tail)
    assert (pool.block_table[s, 1:] == 0).all()      # dead entries zeroed
    assert pool.reserved_for(s) == 4                 # reservation survives
    pool.check_invariants()
    for _ in range(10):                              # regrow over the span
        pool.prepare_decode([s])
        pool.advance([s])
    assert int(pool.cache_pos[s]) == 12
    pool.check_invariants()
    pool.release(s)
    assert pool.available_blocks == pool.num_blocks


# ==========================================================================
# property: arbitrary allocate/extend/free interleavings keep the
# free lists intact (hypothesis in CI, seeded sweep everywhere)
# ==========================================================================

def _drive_block_pool(seed: int) -> None:
    """Engine-shaped random walk over BlockPool ops, invariants checked
    after every operation."""
    rng = np.random.default_rng(seed)
    num_slots, slot_len, bs = 4, 16, 4
    num_blocks = int(rng.integers(4, 17))            # >= blocks_per_slot
    pool = BlockPool(CFG, num_slots, slot_len, block_size=bs,
                     num_blocks=num_blocks)
    active = {}                                      # slot -> decodes left
    for _ in range(80):
        op = int(rng.integers(0, 4))
        if op == 0 and pool.num_free:                # admit
            L = int(rng.integers(1, slot_len))
            max_new = int(rng.integers(1, 9))
            tokens = L + max_new - 1
            if pool.can_admit(tokens):
                slot = int(rng.choice(pool.free_slots))
                pool.take(slot)
                pool.reserve(slot, tokens)
                pool.alloc_prompt(slot, L)           # prompt blocks
                pool.cache_pos[slot] = L
                if max_new == 1 or pool.slot_full(slot):
                    pool.release(slot)               # done at admit time
                else:
                    active[slot] = max_new - 1
        elif op in (1, 2) and active:                # one decode step
            slot = int(rng.choice(list(active)))
            if not pool.slot_full(slot):
                pool.prepare_decode([slot])          # extend on demand
                pool.advance([slot])
                active[slot] -= 1
            if active[slot] <= 0 or pool.slot_full(slot):
                pool.release(slot)                   # finished
                del active[slot]
        elif op == 3 and active:                     # eviction / cancel
            slot = int(rng.choice(list(active)))
            pool.release(slot)
            del active[slot]
        pool.check_invariants()
    for slot in list(active):
        pool.release(slot)
    pool.check_invariants()
    assert pool.blocks_in_use == 0
    assert pool.available_blocks == pool.num_blocks
    assert (pool.block_table == 0).all() and (pool.cache_pos == 0).all()


def _drive_slot_pool(seed: int) -> None:
    """Same walk over the legacy SlotPool's free list."""
    rng = np.random.default_rng(seed)
    num_slots = 4
    pool = SlotPool(CFG, num_slots, slot_len=16)
    active = set()

    def check():
        free = pool.free_slots
        assert len(set(free)) == len(free), "duplicate free slot"
        assert not active & set(free), "slot both active and free"
        assert len(active) + len(free) == num_slots, "leaked slot"

    for _ in range(80):
        op = int(rng.integers(0, 3))
        if op == 0 and pool.num_free:
            slot = int(rng.choice(pool.free_slots))
            pool.take(slot)
            pool.cache_pos[slot] = int(rng.integers(1, 16))
            active.add(slot)
        elif op == 1 and active:
            pool.advance([int(rng.choice(list(active)))])
        elif op == 2 and active:
            slot = int(rng.choice(list(active)))
            pool.release(slot)
            assert pool.cache_pos[slot] == 0
            active.remove(slot)
        check()
    for slot in list(active):
        pool.release(slot)
        active.remove(slot)
    check()


# seeded sweep: always runs, hypothesis or not
@pytest.mark.parametrize("seed", range(15))
def test_block_pool_interleavings_seeded(seed):
    _drive_block_pool(seed)


@pytest.mark.parametrize("seed", range(15))
def test_slot_pool_interleavings_seeded(seed):
    _drive_slot_pool(seed)


if HAVE_HYPOTHESIS:
    # deterministic profile: derandomized, bounded examples, no deadline —
    # the tier-1 run stays fast and reproducible (see tests/test_properties)
    _SETTINGS = settings(max_examples=50, deadline=None, derandomize=True)

    @_SETTINGS
    @given(st.integers(0, 2 ** 32 - 1))
    def test_block_pool_interleavings_hypothesis(seed):
        _drive_block_pool(seed)

    @_SETTINGS
    @given(st.integers(0, 2 ** 32 - 1))
    def test_slot_pool_interleavings_hypothesis(seed):
        _drive_slot_pool(seed)
