"""Adam(W) optimizer, pure JAX pytrees (no optax in this container).

State is a pytree-of-dicts mirroring the trainable tree.  Supports
global-norm clipping, decoupled weight decay, and an optional boolean mask
tree (leaves with mask False are frozen — used for the "static rescaler"
ablation where s_i = k/k_i must not train).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


def init(params: PyTree) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)


def update(grads: PyTree, state: AdamState, params: PyTree, *,
           lr: float, beta1: float = 0.9, beta2: float = 0.999,
           eps: float = 1e-8, weight_decay: float = 0.0,
           grad_clip: float = 0.0,
           mask: Optional[PyTree] = None) -> Tuple[PyTree, AdamState]:
    """Returns (new_params, new_state)."""
    if grad_clip > 0:
        grads = clip_by_global_norm(grads, grad_clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t

    def upd(g, m, v, p, use=True):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        m_new = beta1 * m + (1 - beta1) * g
        v_new = beta2 * v + (1 - beta2) * g * g
        delta = lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        if weight_decay:
            delta = delta + lr * weight_decay * p32
        p_new = (p32 - delta).astype(p.dtype)
        if use is not True:  # masked leaf: freeze
            keep = jnp.asarray(use)
            p_new = jnp.where(keep, p_new, p)
            m_new = jnp.where(keep, m_new, m)
            v_new = jnp.where(keep, v_new, v)
        return p_new, m_new, v_new

    if mask is None:
        triples = jax.tree.map(upd, grads, state.mu, state.nu, params)
    else:
        triples = jax.tree.map(lambda g, m, v, p, k: upd(g, m, v, p, k),
                               grads, state.mu, state.nu, params, mask)

    new_params = jax.tree.map(lambda t3: t3[0], triples,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t3: t3[1], triples,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t3: t3[2], triples,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(step=step, mu=new_mu, nu=new_nu)


# --------------------------------------------------------------------------
# LR schedules
# --------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return fn


def constant_schedule(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)
