"""Table 3 — larger client populations (paper: 40 clients; bench: 16).

Validates that FLAME's advantage persists when the same data is split
across many more (hence smaller) shards."""
from __future__ import annotations

from .common import emit, run_setting

METHODS = ["flame", "trivial", "hlora", "flexlora"]


def run(clients=16, alphas=(5.0, 0.5), rounds=3) -> None:
    rows = []
    for alpha in alphas:
        for method in METHODS:
            r = run_setting(method, budget="b4", alpha=alpha,
                            clients=clients, rounds=rounds,
                            n_examples=384)
            rows.append({"clients": clients, "alpha": alpha,
                         "method": method, "score": r["score"],
                         "test_loss": r["test_loss"], "wall_s": r["wall_s"]})
    emit("table3_scale", rows,
         ["clients", "alpha", "method", "score", "test_loss", "wall_s"])
    for alpha in alphas:
        f = [r for r in rows if r["alpha"] == alpha
             and r["method"] == "flame"][0]
        base = max(r["score"] for r in rows if r["alpha"] == alpha
                   and r["method"] != "flame")
        print(f"# {clients} clients alpha={alpha} beta4: FLAME "
              f"{f['score']:.2f} vs best baseline {base:.2f} -> "
              f"{'CONFIRMS' if f['score'] >= base else 'REFUTES'} paper")


if __name__ == "__main__":
    run()
