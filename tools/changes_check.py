#!/usr/bin/env python
"""CHANGES.md discipline check (`make ci` / the changes-entry CI job).

Two modes:

* ``--base REF`` (pull-request CI): every PR must carry its CHANGES.md
  entry — fail unless CHANGES.md differs between ``REF`` and HEAD.
* no arguments (local ``make ci``): fail on *uncommitted* CHANGES.md
  drift — the entry must be part of the commit under test, not sitting
  dirty in the working tree where the pushed PR would silently miss it.
"""
from __future__ import annotations

import argparse
import subprocess
import sys


def _git(*args: str) -> str:
    return subprocess.run(["git", *args], check=True, capture_output=True,
                          text=True).stdout


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="changes_check")
    ap.add_argument("--base", metavar="REF",
                    help="require a CHANGES.md diff vs the merge-base "
                         "with REF (pull-request mode)")
    ns = ap.parse_args(argv)

    if ns.base:
        base = _git("merge-base", ns.base, "HEAD").strip()
        changed = _git("diff", "--name-only", base, "HEAD",
                       "--", "CHANGES.md").strip()
        if not changed:
            print(f"FAIL changes-check: no CHANGES.md entry in this PR "
                  f"(diff vs {ns.base} is empty) — append one line "
                  f"describing the change")
            return 1
        print("changes-check: OK (CHANGES.md updated in this PR)")
        return 0

    dirty = _git("status", "--porcelain", "--", "CHANGES.md").strip()
    if dirty:
        print("FAIL changes-check: CHANGES.md has uncommitted drift "
              f"({dirty!r}) — commit the entry with the change")
        return 1
    print("changes-check: OK (no uncommitted CHANGES.md drift)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
