"""Shared benchmark utilities: reduced-scale experiment runner + CSV output.

The paper's quality numbers are GPT-judge scores on AlpaGasus/Dolly with
OLMoE-1.3B/6.9B on 2×A100 — not reproducible in an offline CPU container.
Each table benchmark therefore runs the *same experimental design* (methods,
budgets, Dirichlet α, client counts, sampling rates) at reduced scale
(`olmoe-bench`: 2 layers, d_model 128, 8 experts) on the synthetic
cluster-mixture corpus, and reports the monotone proxy
``score = 100·exp(−test_loss)`` so the tables read like the paper's
(higher = better).  Directional claims are what we validate.
"""
from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig, TrainConfig
from repro.configs.registry import get_config
from repro.data.synthetic import DataConfig
from repro.federated.simulation import build_experiment, run_experiment

# reduced-scale evaluation defaults (CPU-tractable).  lr is LoRA-scale
# appropriate for the 2-layer bench model (the paper's 1.5e-4 applies to
# its real 6.9B model; at 1.5e-4×2 rounds the bench moves <0.001 nats and
# no method separates — measured 2026-07-11)
BENCH_TC = TrainConfig(batch_size=8, local_epochs=3, learning_rate=1e-2)


def bench_model(moe: bool = True) -> ModelConfig:
    if moe:
        from repro.configs.olmoe_1_3b_6_9b import BENCH
        return BENCH
    return get_config("olmo-1.3b", "smoke")


def bench_data(cfg: ModelConfig, n_examples: int = 192,
               seed: int = 0) -> DataConfig:
    return DataConfig(vocab_size=cfg.vocab_size, n_examples=n_examples,
                      seq_len=64, n_clusters=8, seed=seed,
                      num_codebooks=cfg.num_codebooks)


# FLAME budget grid on the bench model (top_k=4): k_i per β, mirroring the
# paper's {8,4,2,1} on OLMoE's top_k=8.
BENCH_FLAME_K = {"b1": 4, "b2": 2, "b3": 1, "b4": 1}


# --------------------------------------------------------------------------
# pretrained frozen base (the paper fine-tunes PRETRAINED LLMs — on a
# random-init base, rank compression loses nothing and no method separates;
# measured 2026-07-11: all methods within 1% of each other without this)
# --------------------------------------------------------------------------

_PRETRAIN_CACHE: Dict = {}


def pretrained_base(cfg: ModelConfig, data: DataConfig, *,
                    steps: int = 40, lr: float = 3e-3, batch: int = 32):
    """Briefly pretrain the FULL model so the federated phase starts from a
    competent frozen base — but only on HALF the task clusters (the paper's
    regime: a pretrained LLM fine-tuned on new instruction tasks).  The
    federated corpus mixes seen and unseen clusters, so LoRA has genuine
    headroom and the heterogeneity structure matters."""
    key = (cfg.name, data.seed)
    if key in _PRETRAIN_CACHE:
        return _PRETRAIN_CACHE[key]
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    from repro.data.synthetic import Corpus, make_corpus, split_corpus
    from repro.models import model as model_lib
    from repro.optim import adam

    params = model_lib.init_params(jax.random.PRNGKey(data.seed + 77), cfg)
    big = make_corpus(_dc.replace(data, n_examples=max(768,
                                                       data.n_examples)))
    keep = big.clusters < max(data.n_clusters // 2, 1)
    train = Corpus(big.tokens[keep], big.labels[keep], big.mask[keep],
                   big.clusters[keep])
    opt = adam.init(params)
    top_k = cfg.moe.top_k or 0
    # cycle k during pretraining: the real OLMoE's 64-expert redundancy
    # makes reduced-k inference viable out of the box; an 8-expert bench
    # model needs explicit activation-robust pretraining to play the same
    # role (otherwise serving at k=1 cripples the BASE, not the method)
    k_cycle = sorted({max(top_k // 4, 1), max(top_k // 2, 1), top_k}) \
        if top_k else [None]

    import functools

    @functools.partial(jax.jit, donate_argnums=(0, 1),
                       static_argnames=("k",))
    def step(params, opt, tokens, labels, mask, k):
        def loss_fn(p):
            loss, _ = model_lib.lm_loss(cfg, p, tokens, labels, mask, k=k)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam.update(grads, opt, params, lr=lr, grad_clip=1.0)
        return params, opt, loss

    rng = np.random.default_rng(data.seed)
    n = len(train.tokens)
    loss = float("nan")
    for i in range(steps):
        idx = rng.integers(0, n, batch)
        params, opt, loss = step(params, opt,
                                 jnp.asarray(train.tokens[idx]),
                                 jnp.asarray(train.labels[idx]),
                                 jnp.asarray(train.mask[idx]),
                                 k_cycle[i % len(k_cycle)])
    _PRETRAIN_CACHE[key] = params
    print(f"# pretrained base {cfg.name}: {steps} steps, "
          f"final loss {float(loss):.4f}")
    return params


def run_setting(method: str, *, budget: Optional[str] = None,
                alpha: float = 5.0, clients: int = 4, rounds: int = 2,
                participation: float = 1.0, temperature: int = 2,
                rescaler: str = "learnable", moe: bool = True,
                n_examples: int = 192, seed: int = 0,
                eval_k: Optional[int] = None) -> Dict[str, float]:
    cfg = bench_model(moe)
    fed = FederatedConfig(
        num_clients=clients, rounds=rounds, participation=participation,
        dirichlet_alpha=alpha, temperature=temperature, method=method,
        rescaler=rescaler if (moe and method == "flame") else "none",
        seed=seed)
    dc = bench_data(cfg, n_examples, seed)
    exp = build_experiment(cfg, fed=fed, tc=BENCH_TC, data=dc,
                           budget=budget,
                           base_params=pretrained_base(cfg, dc))
    if eval_k is None and method == "flame" and budget and moe:
        # FLAME's deployment-efficiency semantics (paper Table 2: the β
        # row's FLOPs column is the REDUCED-k inference cost): a model
        # fine-tuned at k_i is served at k_i
        eval_k = exp.server.clients[0].k
    t0 = time.time()
    out = run_experiment(exp, eval_k=eval_k)
    out["wall_s"] = time.time() - t0
    out["exp"] = exp
    return out


# Every emit() call also appends machine-readable rows here so the runner
# can dump one JSON artifact per invocation (CI uploads it) — see
# benchmarks.run --smoke --out.
RESULTS: List[Dict] = []

# benchmarks/telemetry_bench.py drops one entry per scenario here
# (decode-step p50, prefix hit rate, expert gini + the full registry
# snapshot); the runner writes it as the artifact's "telemetry" block so
# BENCH JSON files accumulate a perf trajectory across PRs.
TELEMETRY: Dict[str, Dict] = {}


def emit(name: str, rows: List[Dict], keys: List[str]) -> None:
    """CSV block: header + rows, prefixed with the benchmark name."""
    print(f"\n# {name}")
    print(",".join(["bench"] + keys))
    for r in rows:
        print(",".join([name] + [_fmt(r.get(k)) for k in keys]))
        RESULTS.append({"bench": name,
                        **{k: _jsonable(r.get(k)) for k in keys}})
    sys.stdout.flush()


def _jsonable(v):
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, np.floating):
        return float(v)
    if isinstance(v, np.integer):
        return int(v)
    return str(v)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)


def timeit(fn, *args, repeats: int = 3, **kw) -> float:
    fn(*args, **kw)                       # compile/warm
    t0 = time.time()
    for _ in range(repeats):
        r = fn(*args, **kw)
    try:
        import jax
        jax.block_until_ready(r)
    except Exception:
        pass
    return (time.time() - t0) / repeats * 1e6   # us/call
