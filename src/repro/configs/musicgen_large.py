"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192
vocab=2048, decoder-only over EnCodec tokens.  [arXiv:2306.05284]

The EnCodec neural codec is the stubbed modality frontend: the backbone
consumes its 4 parallel codebook token streams (delay pattern).  We model
this as 4 summed input embeddings and 4 parallel output heads; loss averages
over codebooks."""
from .base import LoRAConfig, ModelConfig

FULL = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=10_000.0,
    num_codebooks=4,
    lora=LoRAConfig(rank=16),
    source="arXiv:2306.05284",
)

SMOKE = FULL.replace(
    name="musicgen-smoke",
    num_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    vocab_size=128,
    num_codebooks=2,
    lora=LoRAConfig(rank=4),
)

SWA_WINDOW = 8192
