"""Quickstart: FLAME in ~60 lines.

Builds a small OLMoE-family SMoE model, runs TWO federated fine-tuning
rounds with four budget-heterogeneous clients (k_i ∈ {4,2,1,1}), and shows
the three FLAME mechanisms in action:

  1. clients fine-tune the FULL global LoRA with fewer activated experts;
  2. each client trains its own output rescaler s_i;
  3. the server aggregates with activation-aware weights (Eq. 6–7).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import FederatedConfig, TrainConfig
from repro.configs.registry import get_config
from repro.data.synthetic import DataConfig
from repro.federated.client import evaluate
from repro.federated.simulation import build_experiment, run_experiment


def main() -> None:
    cfg = get_config("olmoe-1.3b-6.9b", "smoke")   # 2L, 4 experts top-2
    fed = FederatedConfig(num_clients=4, rounds=2, method="flame",
                          dirichlet_alpha=0.5, temperature=2,
                          rescaler="learnable", seed=0)
    tc = TrainConfig(batch_size=8, local_epochs=1)
    data = DataConfig(vocab_size=cfg.vocab_size, n_examples=192,
                      seq_len=64, n_clusters=8)

    print(f"model: {cfg.name} ({cfg.num_layers}L, d={cfg.d_model}, "
          f"{cfg.moe.num_experts} experts top-{cfg.moe.top_k})")
    exp = build_experiment(cfg, fed=fed, tc=tc, data=data)
    for c, b in zip(exp.server.clients, exp.budgets):
        print(f"  client {c.client_id}: budget {b}, k_i={c.k}, "
              f"|D_i|={c.dataset_size}")

    init_loss = evaluate(cfg, exp.server.params, None, exp.val,
                         k=cfg.moe.top_k)
    print(f"\nval loss before fine-tuning: {init_loss:.4f}")

    res = run_experiment(exp)
    print(f"val loss after {res['rounds']} FLAME rounds: "
          f"{res['val_loss']:.4f}  (score {res['score']:.2f})")

    # the deployment-efficiency claim: serve with fewer activated experts
    res_k1 = run_experiment(exp, eval_k=1)   # re-evaluates, no extra training
    print(f"served with k=1 instead of k={cfg.moe.top_k}: "
          f"val loss {res_k1['val_loss']:.4f}")

    # inspect a trained rescaler and the round's activation imbalance
    s = exp.server.clients[2].rescaler
    if s is not None:
        print(f"\nclient 2 learned rescaler s_i (init k/k_i): "
              f"{np.asarray(list(s.values())[0]).round(3)}")
    freqs = exp.server.history[-1].client_freqs[0]
    f = np.concatenate([np.asarray(v).ravel() for v in freqs.values()])
    print(f"client 0 expert activation freqs: min {f.min():.3f} "
          f"max {f.max():.3f} (imbalance motivates Eq. 6)")


if __name__ == "__main__":
    main()
