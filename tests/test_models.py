"""Model-layer unit tests: attention math, rope, norms, mamba SSD, scan stack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_ssm
from repro.models import attention as A
from repro.models import mamba2 as S
from repro.models import model as M
from repro.models.layers import apply_rope, rms_norm, softcap


def naive_attention(q, k, v, causal=True, window=0):
    """(B,S,H,D) x (B,S,KV,D) reference with explicit score matrix."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    rep = H // KV
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * (D ** -0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sq)[None, :]
    valid = kpos <= qpos
    if window > 0:
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr)


@pytest.mark.parametrize("S_,H,KV,window", [
    (64, 4, 4, 0), (64, 4, 2, 0), (128, 8, 2, 0),
    (64, 4, 4, 16), (128, 4, 1, 32),
])
def test_flash_attention_jnp_matches_naive(S_, H, KV, window):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, S_, H, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, S_, KV, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, S_, KV, 16))
    out = A.flash_attention_jnp(q, k, v, window=window,
                                block_q=32, block_k=32)
    ref = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_last_row():
    key = jax.random.PRNGKey(3)
    B, S_, H, KV, D = 2, 32, 4, 2, 16
    q = jax.random.normal(key, (B, S_, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S_, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S_, KV, D))
    full = naive_attention(q, k, v)
    dec = A.decode_attention(q[:, -1:], k, v, jnp.asarray(S_ - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-5, atol=2e-5)


def test_rope_preserves_norm_and_relative_phase():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (1, 8, 2, 32))
    pos = jnp.arange(8)
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # dot products depend only on relative offset: shift positions by 5
    y2 = apply_rope(x, pos + 5, 10_000.0)
    d1 = jnp.einsum("bshd,bthd->bhst", y, y)
    d2 = jnp.einsum("bshd,bthd->bhst", y2, y2)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-4, atol=1e-4)


def test_rms_norm_unit_variance():
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 64)) * 7.0 + 3.0
    y = rms_norm(jnp.ones((64,)), x)
    ms = np.mean(np.asarray(y) ** 2, axis=-1)
    np.testing.assert_allclose(ms, 1.0, rtol=1e-3)


def test_softcap_bounds():
    x = jnp.linspace(-100, 100, 201)
    y = softcap(x, 30.0)
    assert float(jnp.abs(y).max()) <= 30.0
    np.testing.assert_allclose(np.asarray(softcap(x, 0.0)), np.asarray(x))


# ---------------------------------------------------------------- mamba SSD

def test_ssd_chunked_matches_sequential():
    """Chunked SSD == naive token-by-token recurrence."""
    key = jax.random.PRNGKey(6)
    B, S_, H, P, N = 1, 32, 2, 8, 4
    x = jax.random.normal(key, (B, S_, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S_, H)))
    Avec = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S_, H, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S_, H, N))

    y_chunk, state_chunk = S.ssd_chunked(x, dt, Avec, Bm, Cm, chunk=8)

    # sequential reference
    st = np.zeros((B, H, P, N), np.float32)
    ys = []
    for t in range(S_):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(Avec)[None])  # (B,H)
        dtx = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]
        st = st * dA[..., None, None] + np.einsum(
            "bhp,bhn->bhpn", dtx, np.asarray(Bm[:, t]))
        ys.append(np.einsum("bhn,bhpn->bhp", np.asarray(Cm[:, t]), st))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_ref,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_chunk), st,
                               rtol=2e-4, atol=2e-4)


def test_mamba_prefill_decode_consistency():
    cfg = tiny_ssm()
    key = jax.random.PRNGKey(7)
    p = S.init_mamba(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 17, cfg.d_model))
    full, _ = S.apply_mamba(p, cfg, x)
    # prefill on :16 then one recurrent step
    _, cache = S.apply_mamba(p, cfg, x[:, :16], return_cache=True)
    step, _ = S.apply_mamba(p, cfg, x[:, 16:17], cache=cache)
    np.testing.assert_allclose(np.asarray(step[:, 0]),
                               np.asarray(full[:, 16]), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------- stack

def test_remat_matches_no_remat():
    cfg = tiny_dense(num_layers=2)
    key = jax.random.PRNGKey(8)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    l1, _ = M.forward(cfg, params, toks, remat=False)
    l2, _ = M.forward(cfg, params, toks, remat=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


def test_chunked_ce_matches_dense_ce():
    cfg = tiny_dense()
    key = jax.random.PRNGKey(9)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=1)
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1),
                                0.7, (2, 32)).astype(jnp.float32)
    h, _ = M.forward_hidden(cfg, params, toks)
    loss = M.chunked_ce_loss(cfg, params, h, labels, mask, chunk=8)
    logits = M.lm_head(params, cfg, h).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    ref = (((lse - gold) * mask).sum() / mask.sum())
    np.testing.assert_allclose(float(loss), float(ref), rtol=1e-5)


def test_tied_embeddings_head():
    cfg = tiny_dense(tie_embeddings=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    assert "lm_head" not in params
    toks = jnp.zeros((1, 8), jnp.int32)
    logits, _ = M.forward(cfg, params, toks)
    assert logits.shape == (1, 8, cfg.vocab_size)
