"""Pytree checkpointing: flattened path->array .npz files (no orbax here).

Handles nested dicts/lists/tuples of jnp/np arrays plus scalar metadata.
Round-resumable federated state = (global LoRA, per-client rescalers,
round index).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "/"
_BF16 = "__bf16__"

try:
    import ml_dtypes
    _BF16_DTYPE = np.dtype(ml_dtypes.bfloat16)
except ImportError:                                   # pragma: no cover
    _BF16_DTYPE = None


def _flatten(tree: PyTree, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}#{i}" if prefix
                                else f"#{i}"))
    elif tree is None:
        out[prefix + f"{_SEP}__none__" if prefix else "__none__"] = \
            np.zeros((), np.int8)
    else:
        arr = np.asarray(tree)
        if _BF16_DTYPE is not None and arr.dtype == _BF16_DTYPE:
            # np.savez cannot serialise bfloat16 — store the raw uint16
            # view and tag the key so load() restores the dtype
            out[prefix + _BF16] = arr.view(np.uint16)
        else:
            out[prefix] = arr
    return out


def save(path: str, tree: PyTree, meta: Optional[dict] = None) -> None:
    """Atomic write: serialise to a sibling temp file, then ``os.replace``
    into place — a crash mid-save (the streamed-checkpoint cadence of the
    device round driver makes saves frequent) can never leave a truncated
    archive behind the canonical name."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(jax.tree.map(np.asarray, tree))
    final = path if path.endswith(".npz") else path + ".npz"
    tmp = final + ".tmp.npz"
    np.savez(tmp, **flat)
    os.replace(tmp, final)
    if meta is not None:
        with open(path + ".meta.json.tmp", "w") as f:
            json.dump(meta, f, indent=2)
        os.replace(path + ".meta.json.tmp", path + ".meta.json")


def load(path: str) -> Tuple[PyTree, Optional[dict]]:
    data = np.load(path if path.endswith(".npz") else path + ".npz",
                   allow_pickle=False)
    tree: dict = {}
    for key in data.files:
        parts = key.split(_SEP)
        if parts[-1] == "__none__":
            parts = parts[:-1]
            value = None
        elif parts[-1].endswith(_BF16):
            value = data[key].view(_BF16_DTYPE)
            parts[-1] = parts[-1][:-len(_BF16)]
        else:
            value = data[key]
        if not parts:
            return value, _load_meta(path)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    tree = _restore_sequences(tree)
    return tree, _load_meta(path)


def _load_meta(path: str) -> Optional[dict]:
    mp = (path if path.endswith(".npz") else path + ".npz") + ".meta.json"
    mp = mp.replace(".npz.meta.json", ".meta.json") \
        if not os.path.exists(mp) else mp
    for cand in (path + ".meta.json", mp):
        if os.path.exists(cand):
            with open(cand) as f:
                return json.load(f)
    return None


def _restore_sequences(node):
    if isinstance(node, dict):
        node = {k: _restore_sequences(v) for k, v in node.items()}
        if node and all(k.startswith("#") for k in node):
            return [node[f"#{i}"] for i in range(len(node))]
    return node


def to_device(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.asarray, tree)
