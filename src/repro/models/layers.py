"""Shared neural-net building blocks (pure JAX, no flax).

Parameters are plain nested dicts of jnp arrays.  Every ``apply``-style
function takes the param sub-tree as its first argument.  LoRA adapters are
threaded through as optional parallel sub-trees (``None`` = no adapter) so the
same forward code serves frozen-base fine-tuning and plain inference.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# initialisers
# --------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LLM init)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def gated_rms_norm(scale: jnp.ndarray, x: jnp.ndarray, z: jnp.ndarray,
                   eps: float = 1e-6):
    """Mamba-2 output norm: RMSNorm(x * silu(z))."""
    x = x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return rms_norm(scale, x, eps)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)          # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# LoRA-aware dense application
# --------------------------------------------------------------------------

def lora_dense(x: jnp.ndarray, w: jnp.ndarray,
               lp: Optional[dict], scale: float,
               kernels=None) -> jnp.ndarray:
    """y = x @ W (+ (x @ A) @ B * scale when a LoRA adapter is present).

    ``x``: (..., d_in); ``w``: (d_in, d_out); ``lp``: {"a": (d_in, r),
    "b": (r, d_out)} or None.  The LoRA bypass is computed in the weight
    dtype; correction is added unmerged (the federated protocol keeps
    A/B separate so the server can aggregate them).

    On the pallas path (``kernels``, a KernelConfig) an adapter-carrying
    projection runs the fused ``repro.kernels.lora_matmul`` kernel over the
    flattened token dim.
    """
    if lp is not None:
        from ..kernels import backend as kernel_backend
        if kernel_backend.use_pallas(kernels):
            xf = x.reshape(-1, x.shape[-1])
            y = kernel_backend.lora_matmul(kernels, xf, w, lp["a"],
                                           lp["b"], scale=scale)
            return y.reshape(x.shape[:-1] + (w.shape[-1],))
    y = x @ w
    if lp is not None:
        y = y + ((x @ lp["a"]) @ lp["b"]) * jnp.asarray(scale, y.dtype)
    return y


def lora_expert_einsum(x: jnp.ndarray, w: jnp.ndarray,
                       lp: Optional[dict], scale: float,
                       kernels=None) -> jnp.ndarray:
    """Per-expert matmul over stacked expert weights.

    ``x``: (E, C, d_in) or grouped (G, E, C, d_in) expert-major token slots;
    ``w``: (E, d_in, d_out);
    ``lp``: {"a": (E, d_in, r), "b": (E, r, d_out)} or None.

    ``kernels`` (a :class:`repro.configs.base.KernelConfig`) selects the
    implementation: on the pallas path an adapter-carrying matmul runs the
    fused ``repro.kernels.lora_matmul.lora_matmul_experts`` kernel (base +
    LoRA bypass in one VMEM pass).  The reference path and the no-adapter
    case use plain einsums — both accumulate in fp32 and cast once, the
    same numerics contract as the kernel.
    """
    from ..kernels import backend as kernel_backend
    from ..kernels import ref as kernel_ref

    if lp is not None and x.ndim == 3:
        if kernel_backend.use_pallas(kernels):
            return kernel_backend.lora_matmul_experts(
                kernels, x, w, lp["a"], lp["b"], scale=scale)
        return kernel_ref.lora_matmul_experts_ref(x, w, lp["a"], lp["b"],
                                                  scale)

    f32 = jnp.float32
    if x.ndim == 4:
        # grouped path: keep the G axis un-reshaped in the reference
        # einsums — G is the data-sharded routing-group dim and GSPMD must
        # see it intact (the pallas fold below is a per-device kernel view)
        if lp is not None and kernel_backend.use_pallas(kernels):
            G, E, C, K = x.shape
            xt = jnp.swapaxes(x, 0, 1).reshape(E, G * C, K)
            y = kernel_backend.lora_matmul_experts(
                kernels, xt, w, lp["a"], lp["b"], scale=scale)
            return jnp.swapaxes(y.reshape(E, G, C, -1), 0, 1)
        y = jnp.einsum("geci,eio->geco", x, w, preferred_element_type=f32)
        if lp is not None:
            xa = jnp.einsum("geci,eir->gecr", x, lp["a"],
                            preferred_element_type=f32)
            y = y + jnp.einsum("gecr,ero->geco", xa, lp["b"],
                               preferred_element_type=f32) * scale
        return y.astype(x.dtype)
    y = jnp.einsum("eci,eio->eco", x, w, preferred_element_type=f32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU FFN
# --------------------------------------------------------------------------

def init_ffn(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (d_model, d_ff), dtype),
        "w3": dense_init(k2, (d_model, d_ff), dtype),
        "w2": dense_init(k3, (d_ff, d_model), dtype),
    }


def apply_ffn(p: dict, x: jnp.ndarray, lora: Optional[dict] = None,
              lora_scale: float = 0.0, kernels=None) -> jnp.ndarray:
    lg = (lora or {})
    gate = lora_dense(x, p["w1"], lg.get("w1"), lora_scale, kernels=kernels)
    up = lora_dense(x, p["w3"], lg.get("w3"), lora_scale, kernels=kernels)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
    return lora_dense(h, p["w2"], lg.get("w2"), lora_scale, kernels=kernels)


# --------------------------------------------------------------------------
# misc
# --------------------------------------------------------------------------

def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap
