"""Mamba-2 block (State-Space Duality, arXiv:2405.21060), pure JAX.

Training/prefill uses the chunked SSD algorithm (quadratic *within* a chunk,
linear across chunks — ``lax.scan`` carries the inter-chunk state), decode is
the O(1) recurrent update.  Heads are fully independent, so the ``model``
mesh axis shards the head dimension and the scan stays shard-local.

State caches (the SSM analogue of a KV cache):
  ``conv``  (B, conv_dim, conv_width-1) — rolling depthwise-conv context
  ``ssm``   (B, H, P, N)                — recurrent state
Both are O(1) in sequence length — this is why the SSM/hybrid architectures
run the 500k-token decode shape natively.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, gated_rms_norm, lora_dense


# --------------------------------------------------------------------------
# dimensions
# --------------------------------------------------------------------------

def mamba_dims(cfg) -> dict:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return dict(d_inner=d_inner, n_heads=n_heads, conv_dim=conv_dim,
                d_state=s.d_state, head_dim=s.head_dim,
                n_groups=s.n_groups, conv_width=s.conv_width,
                in_dim=2 * d_inner + 2 * s.n_groups * s.d_state + n_heads)


def init_mamba(key, cfg) -> dict:
    dims = mamba_dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # dt bias init: softplus^-1 of dt ~ U[1e-3, 1e-1] (mamba default)
    dt = jnp.exp(jax.random.uniform(k3, (dims["n_heads"],), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    a_init = jax.random.uniform(k4, (dims["n_heads"],), jnp.float32, 1.0, 16.0)
    return {
        "in_proj": dense_init(k1, (cfg.d_model, dims["in_dim"]), dtype),
        "conv_w": (jax.random.normal(k2, (dims["conv_dim"],
                                          dims["conv_width"]), jnp.float32)
                   * (dims["conv_width"] ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((dims["conv_dim"],), dtype),
        "dt_bias": dt_bias,
        "A_log": jnp.log(a_init),
        "D": jnp.ones((dims["n_heads"],), jnp.float32),
        "norm": jnp.ones((dims["d_inner"],), dtype),
        "out_proj": dense_init(jax.random.fold_in(k1, 7),
                               (dims["d_inner"], cfg.d_model), dtype),
    }


# --------------------------------------------------------------------------
# chunked SSD scan
# --------------------------------------------------------------------------

def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., L) -> (..., L, L) with out[i, j] = sum_{k=j+1..i} x[k] for
    j <= i, -inf above the diagonal."""
    L = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    out = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                initial_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD forward.  Shapes:
      x  (B, S, H, P)   head inputs
      dt (B, S, H)      positive step sizes
      A  (H,)           negative decay rates
      Bm (B, S, H, N)   input gates  (already broadcast group->head)
      Cm (B, S, H, N)   output gates
    Returns (y (B,S,H,P), final_state (B,H,P,N)).  All math in fp32.
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    nc = S // chunk

    f32 = jnp.float32
    x, dt, Bm, Cm = (t.astype(f32) for t in (x, dt, Bm, Cm))
    A = A.astype(f32)

    def reshape_c(t):
        return t.reshape((Bsz, nc, chunk) + t.shape[2:])

    xc, dtc, Bc, Cc = map(reshape_c, (x, dt, Bm, Cm))   # (B,nc,L,...)
    dA = dtc * A[None, None, None, :]                   # (B,nc,L,H)
    dA = jnp.moveaxis(dA, -1, 2)                        # (B,nc,H,L)
    dA_cs = jnp.cumsum(dA, axis=-1)                     # (B,nc,H,L)
    dtx = xc * dtc[..., None]                           # (B,nc,L,H,P)

    # intra-chunk (diagonal blocks)
    Ldec = jnp.exp(_segsum(dA))                         # (B,nc,H,L,L)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp",
                        Cc, Bc, Ldec, dtx)

    # per-chunk end states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)     # (B,nc,H,L)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", Bc, decay_states, dtx)

    # inter-chunk recurrence: prev[c] = running state before chunk c
    chunk_decay = jnp.exp(dA_cs[..., -1])               # (B,nc,H)
    init = (jnp.zeros((Bsz, H, P, N), f32) if initial_state is None
            else initial_state.astype(f32))

    def step(carry, inp):
        st, dec = inp                                   # (B,H,P,N), (B,H)
        prev = carry
        new = st + dec[..., None, None] * prev
        return new, prev

    final_state, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (B,nc,H,P,N)

    # contribution of carried-in state to each position
    state_decay = jnp.exp(dA_cs)                        # (B,nc,H,L)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, final_state


# --------------------------------------------------------------------------
# depthwise causal conv
# --------------------------------------------------------------------------

def causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                state: Optional[jnp.ndarray] = None):
    """xbc: (B, S, C); w: (C, W).  Left-pads with ``state`` (B, C, W-1) or
    zeros.  Returns (out (B,S,C), new_state (B,C,W-1))."""
    Bsz, S, C = xbc.shape
    W = w.shape[-1]
    xt = jnp.moveaxis(xbc, 1, 2)                        # (B, C, S)
    pad = (jnp.zeros((Bsz, C, W - 1), xbc.dtype) if state is None
           else state.astype(xbc.dtype))
    xp = jnp.concatenate([pad, xt], axis=-1)            # (B, C, S+W-1)
    out = jnp.zeros((Bsz, C, S), jnp.float32)
    for i in range(W):
        out = out + (xp[:, :, i:i + S].astype(jnp.float32)
                     * w[:, i].astype(jnp.float32)[None, :, None])
    out = out + b.astype(jnp.float32)[None, :, None]
    new_state = xp[:, :, S:]                            # last W-1 inputs
    return jnp.moveaxis(out, 1, 2).astype(xbc.dtype), new_state


# --------------------------------------------------------------------------
# full block
# --------------------------------------------------------------------------

def _split_in_proj(z_xbc_dt, dims):
    d_inner, conv_dim, H = dims["d_inner"], dims["conv_dim"], dims["n_heads"]
    z = z_xbc_dt[..., :d_inner]
    xbc = z_xbc_dt[..., d_inner:d_inner + conv_dim]
    dt = z_xbc_dt[..., d_inner + conv_dim:]
    assert dt.shape[-1] == H
    return z, xbc, dt


def _split_xbc(xbc, dims):
    d_inner, G, N = dims["d_inner"], dims["n_groups"], dims["d_state"]
    x = xbc[..., :d_inner]
    Bm = xbc[..., d_inner:d_inner + G * N]
    Cm = xbc[..., d_inner + G * N:]
    return x, Bm, Cm


def _broadcast_groups(t, dims):
    """(B, S, G*N) -> (B, S, H, N) by repeating groups across heads."""
    Bsz, S = t.shape[:2]
    G, N, H = dims["n_groups"], dims["d_state"], dims["n_heads"]
    t = t.reshape(Bsz, S, G, N)
    return jnp.repeat(t, H // G, axis=2)


def apply_mamba(p: dict, cfg, x: jnp.ndarray,
                *, lora: Optional[dict] = None, lora_scale: float = 0.0,
                cache: Optional[dict] = None,
                return_cache: bool = False):
    """x: (B, S, D).  Prefill/train when cache is None (or being built),
    decode single-step when ``cache`` holds {conv, ssm} and S == 1."""
    dims = mamba_dims(cfg)
    Bsz, S, _ = x.shape
    lg = lora or {}
    H, P, N = dims["n_heads"], dims["head_dim"], dims["d_state"]

    zxbcdt = lora_dense(x, p["in_proj"], lg.get("in_proj"), lora_scale)
    z, xbc, dt = _split_in_proj(zxbcdt, dims)

    A = -jnp.exp(p["A_log"])                                     # (H,)
    new_cache = None

    if cache is not None and S == 1:
        # ---- recurrent decode ----
        xbc_full = jnp.concatenate(
            [cache["conv"], jnp.moveaxis(xbc, 1, 2)], axis=-1)   # (B,C,W)
        conv_out = (xbc_full.astype(jnp.float32)
                    * p["conv_w"].astype(jnp.float32)[None]).sum(-1)
        conv_out = conv_out + p["conv_b"].astype(jnp.float32)[None]
        xbc_t = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)  # (B,1,C)
        new_conv = xbc_full[:, :, 1:]

        xs, Bm, Cm = _split_xbc(xbc_t, dims)
        xs = xs.reshape(Bsz, H, P)
        Bm = _broadcast_groups(Bm, dims)[:, 0]                   # (B,H,N)
        Cm = _broadcast_groups(Cm, dims)[:, 0]
        dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                              + p["dt_bias"][None])              # (B,H)
        dA = jnp.exp(dtp * A[None])                              # (B,H)
        dtx = xs.astype(jnp.float32) * dtp[..., None]            # (B,H,P)
        new_ssm = (cache["ssm"].astype(jnp.float32) * dA[..., None, None]
                   + jnp.einsum("bhp,bhn->bhpn", dtx,
                                Bm.astype(jnp.float32)))
        y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(jnp.float32), new_ssm)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(Bsz, 1, dims["d_inner"]).astype(x.dtype)
        new_cache = {"conv": new_conv, "ssm": new_ssm}
    else:
        # ---- chunked SSD prefill/train ----
        conv_in_state = cache["conv"] if cache is not None else None
        xbc_c, conv_state = causal_conv(xbc, p["conv_w"], p["conv_b"],
                                        conv_in_state)
        xbc_c = jax.nn.silu(xbc_c.astype(jnp.float32)).astype(x.dtype)
        xs, Bm, Cm = _split_xbc(xbc_c, dims)
        xs = xs.reshape(Bsz, S, H, P)
        Bm = _broadcast_groups(Bm, dims)
        Cm = _broadcast_groups(Cm, dims)
        dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        chunk = min(cfg.ssm.chunk_size, S)
        while S % chunk:                       # largest divisor ≤ chunk_size
            chunk -= 1
        init_state = cache["ssm"] if cache is not None else None
        y, final_state = ssd_chunked(xs, dtp, A, Bm, Cm, chunk, init_state)
        y = y + (p["D"].astype(jnp.float32)[None, None, :, None]
                 * xs.astype(jnp.float32))
        y = y.reshape(Bsz, S, dims["d_inner"]).astype(x.dtype)
        if return_cache:
            new_cache = {"conv": conv_state, "ssm": final_state}

    y = gated_rms_norm(p["norm"], y, z, cfg.rms_eps)
    return lora_dense(y, p["out_proj"], lg.get("out_proj"), lora_scale), new_cache


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    dims = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, dims["conv_dim"], dims["conv_width"] - 1),
                          dtype),
        "ssm": jnp.zeros((batch, dims["n_heads"], dims["head_dim"],
                          dims["d_state"]), jnp.float32),
    }
