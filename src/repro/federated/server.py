"""Server-side federated orchestration: one round per method.

Implements the four compared methods end-to-end:

  * ``flame``    — distribute full-rank per-expert LoRA; clients train with
                   their k_i; aggregate with Eq. 6–7 (activation-aware).
  * ``trivial``  — every client uses the globally smallest rank; plain
                   FedAvg (the paper's "trivial" baseline: small uniform
                   LoRA for all experts).
  * ``hlora``    — distribute rank-truncated adapters per client budget;
                   sparsity-weighted aggregation over rank components.
  * ``flexlora`` — clients train truncated adapters; server aggregates full
                   ΔW = s·A·B and SVD-refactors back to the server rank.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..configs.base import FederatedConfig, ModelConfig, TrainConfig
from ..core import aggregation as agg
from ..core import lora as lora_lib
from . import client as client_lib

PyTree = Any

# the paper's budget grids (Appendix A1)
FLAME_BUDGET_K = {"b1": 8, "b2": 4, "b3": 2, "b4": 1}
MOE_BUDGET_RANKS = {"b1": 20, "b2": 12, "b3": 8, "b4": 6}
DENSE_BUDGET_RANKS = {"b1": 40, "b2": 24, "b3": 16, "b4": 12}


@dataclass
class RoundResult:
    round_idx: int
    client_losses: List[float]
    client_freqs: List[Dict[str, np.ndarray]]
    participating: List[int]


class FederatedServer:
    """Holds the global LoRA state and runs communication rounds."""

    def __init__(self, cfg: ModelConfig, params: PyTree, global_lora: PyTree,
                 clients: Sequence[client_lib.ClientState],
                 fed: FederatedConfig, tc: TrainConfig):
        self.cfg = cfg
        self.params = params
        self.global_lora = global_lora
        self.clients = list(clients)
        self.fed = fed
        self.tc = tc
        self.history: List[RoundResult] = []
        self._rng = np.random.default_rng(fed.seed + 999)

    # ----------------------------------------------------------- distribution
    def _distribute(self, c: client_lib.ClientState) -> PyTree:
        m = self.fed.method
        if m == "flame":
            return self.global_lora                      # full rank, always
        if m == "trivial":
            r_min = min(cl.rank for cl in self.clients)
            return lora_lib.truncate_rank(self.global_lora, r_min)
        if m in ("hlora", "flexlora"):
            return lora_lib.truncate_rank(self.global_lora, c.rank)
        raise ValueError(f"unknown method {m!r}")

    # ------------------------------------------------------------ aggregation
    def _aggregate(self, loras: List[PyTree],
                   freqs: List[Dict[str, np.ndarray]],
                   sizes: List[float], parts: List[int]) -> PyTree:
        m = self.fed.method
        r_full = max(cl.rank for cl in self.clients)
        if m == "flame":
            return agg.flame_aggregate(loras, freqs, sizes,
                                       self.fed.temperature)
        if m == "trivial":
            r_min = min(cl.rank for cl in self.clients)
            small = agg.fedavg(loras, sizes)
            # pad the uniformly-small global back to server rank storage
            return lora_lib.pad_rank(small, r_full)
        if m == "hlora":
            ranks = [self.clients[i].rank for i in parts]
            return agg.hlora_aggregate(loras, ranks, sizes, r_full)
        if m == "flexlora":
            return agg.flexlora_aggregate(loras, sizes, r_full,
                                          self.cfg.lora.scale)
        raise ValueError(m)

    # ----------------------------------------------------------------- rounds
    def run_round(self, round_idx: int) -> RoundResult:
        n = len(self.clients)
        n_part = max(1, int(round(self.fed.participation * n)))
        parts = sorted(self._rng.choice(n, size=n_part, replace=False)
                       .tolist())

        loras, freqs, sizes, losses = [], [], [], []
        for i in parts:
            c = self.clients[i]
            dist = self._distribute(c)
            trained, f, _, info = client_lib.local_train(
                self.cfg, self.params, dist, c, self.tc,
                round_seed=self.fed.seed * 1000 + round_idx)
            loras.append(trained)
            freqs.append(f)
            sizes.append(float(c.dataset_size))
            losses.append(info["mean_loss"])

        self.global_lora = self._aggregate(loras, freqs, sizes, parts)
        res = RoundResult(round_idx, losses, freqs, parts)
        self.history.append(res)
        return res

    def run(self) -> List[RoundResult]:
        return [self.run_round(r) for r in range(self.fed.rounds)]
