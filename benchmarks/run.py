"""Benchmark orchestrator — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table1 fig3

Output: CSV blocks (``name,...`` headers) + `#` summary lines asserting the
paper's directional claims.  Roofline numbers live in EXPERIMENTS.md
(§Roofline) — they come from the dry-run, not from CPU wall clock.
"""
from __future__ import annotations

import sys
import time

from . import (fig2_activation, fig3_temperature, kernel_bench,
               round_engine_bench, table1_flops, table2_budgets,
               table3_scale, table4_sampling, table5_rescaler)

ALL = {
    "table1": table1_flops.run,
    "table2": table2_budgets.run,
    "table3": table3_scale.run,
    "table4": table4_sampling.run,
    "table5": table5_rescaler.run,
    "fig2": fig2_activation.run,
    "fig3": fig3_temperature.run,
    "kernels": kernel_bench.run,
    "round_engine": round_engine_bench.run,
}


def main() -> None:
    picks = sys.argv[1:] or list(ALL)
    t0 = time.time()
    for name in picks:
        if name not in ALL:
            raise SystemExit(f"unknown benchmark {name!r}; "
                             f"choose from {list(ALL)}")
        t = time.time()
        ALL[name]()
        print(f"# [{name}] done in {time.time() - t:.1f}s", flush=True)
    print(f"\n# all benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
