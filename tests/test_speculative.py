"""Speculative decoding: exactness, distribution, and rollback proofs.

Three layers of evidence (ISSUE 6 headline suite):

* **greedy parity** — self-speculative greedy decode is token-for-token
  identical to the plain full-k decode oracle (naive_decode), across
  both kernel backends x paged/slotted KV layouts x mixed-tier traces.
  Every draft mismatch exercises the KV rollback path end to end.
* **statistical** — a seeded >= 10k-draw harness on a tiny vocab proving
  the rejection rule emits tokens with EXACTLY the target sampler's
  distribution (TV distance + chi-square against the analytic p), for
  temperature and top-p samplers, at every window position class
  (first token, mid-window conditional, all-accept bonus).  Marked
  ``slow`` (CI smoke job / ``make test-slow``).
* **rollback property** — arbitrary accept/reject prefixes leave the
  ``BlockPool`` (tables, allocation counts, free list) exactly as a
  straight decode of the accepted prefix would, bystander slots
  untouched; hypothesis-driven when available, seeded sweep otherwise.
"""
import jax
import numpy as np
import pytest

from conftest import tiny_dense, tiny_moe
from repro.configs.base import KernelConfig
from repro.models import model as M
from repro.serving import (BlockPool, Request, SamplerConfig, ServingEngine,
                           SpeculativeConfig)
from repro.serving.sampler import sample_from_probs, sampler_probs
from repro.serving.speculative import verify_window

from test_serving import naive_decode

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

CFG = tiny_moe()
PARAMS = M.init_params(jax.random.PRNGKey(0), CFG)
RNG = np.random.default_rng(0)
FULL_K = CFG.moe.num_experts


# ==========================================================================
# greedy parity: spec decode == plain full-k decode, token for token
# ==========================================================================

@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("layout", ["slotted", "paged"])
def test_greedy_spec_matches_plain_decode(backend, layout):
    """Mixed-tier trace: premium slots verify at k=4, constrained at k=2,
    both drafting at k=1.  The spec engine must reproduce the naive
    full-batch greedy loop of each tier exactly — the greedy rejection
    rule accepts iff draft argmax == target argmax, so every mismatch
    also exercises truncate_to/rollback on this layout."""
    cfg = CFG.replace(kernels=KernelConfig(backend=backend))
    new = 10
    prompts = RNG.integers(0, cfg.vocab_size, (8, 6)).astype(np.int32)
    ref = {4: naive_decode(cfg, PARAMS, prompts[:4], new, 4),
           2: naive_decode(cfg, PARAMS, prompts[4:], new, 2)}
    eng = ServingEngine(cfg, PARAMS, num_slots=4, slot_len=6 + new,
                        slot_k=(4, 4, 2, 2), kv_layout=layout,
                        speculative=SpeculativeConfig(window=3, draft_k=1))
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=new,
                    k=4 if i < 4 else 2) for i in range(8)]
    rep = eng.run(reqs)
    got = rep.tokens_by_rid()
    for i in range(8):
        tier, row = (4, i) if i < 4 else (2, i - 4)
        np.testing.assert_array_equal(
            got[i], ref[tier][row],
            err_msg=f"rid {i} (tier {tier}) diverged from plain decode")
    s = rep.summary()
    assert s["spec_rounds"] > 0 and s["spec_drafted"] > 0
    assert 0.0 <= s["acceptance_rate"] <= 1.0


def test_spec_sampled_reproducible_and_layout_independent():
    """Sampled speculative decode is a deterministic function of
    (seed, rid, draw order): re-running the same trace reproduces the
    same tokens, and the KV layout (paged vs slotted) cannot change
    them — the per-request event-counter keys make draws independent of
    engine internals."""
    sc = SamplerConfig(kind="temperature", temperature=1.2)
    prompts = RNG.integers(0, CFG.vocab_size, (6, 5)).astype(np.int32)
    outs = {}
    for layout in ("slotted", "paged"):
        for rep in range(2):
            eng = ServingEngine(
                CFG, PARAMS, num_slots=3, slot_len=5 + 8,
                kv_layout=layout, sampler=sc, seed=11,
                speculative=SpeculativeConfig(window=2, draft_k=1))
            reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=8)
                    for i in range(6)]
            outs[layout, rep] = eng.run(reqs).tokens_by_rid()
    for layout in ("slotted", "paged"):
        for i in range(6):
            np.testing.assert_array_equal(outs[layout, 0][i],
                                          outs[layout, 1][i])
    for i in range(6):
        np.testing.assert_array_equal(outs["slotted", 0][i],
                                      outs["paged", 0][i])


# ==========================================================================
# guards: configurations that would silently break exactness must raise
# ==========================================================================

def test_spec_guards():
    spec = SpeculativeConfig(window=2, draft_k=1)
    with pytest.raises(ValueError, match="window"):
        SpeculativeConfig(window=0)
    with pytest.raises(ValueError, match="draft_k"):
        SpeculativeConfig(draft_k=0)
    with pytest.raises(ValueError, match="draft_k"):
        ServingEngine(CFG, PARAMS, num_slots=2, slot_len=16,
                      speculative=SpeculativeConfig(draft_k=99))
    with pytest.raises(ValueError, match="no cheaper draft"):
        cfg_d = tiny_dense()
        ServingEngine(cfg_d, M.init_params(jax.random.PRNGKey(0), cfg_d),
                      num_slots=2, slot_len=16, speculative=spec)
    with pytest.raises(ValueError, match="loss-free"):
        ServingEngine(CFG, PARAMS, num_slots=2, slot_len=16,
                      dispatch="capacity", speculative=spec)
    with pytest.raises(ValueError, match="non-wrapping"):
        ServingEngine(CFG.replace(attention_window=4), PARAMS,
                      num_slots=2, slot_len=16, speculative=spec)
    # teacher-forced requests cannot run under speculation: fail fast
    eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=16,
                        speculative=spec)
    bad = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=4,
                  forced=np.zeros(4, np.int32))
    with pytest.raises(ValueError, match="teacher-forced"):
        eng.run([bad])


# ==========================================================================
# statistical harness: the rejection rule's output IS the target
# distribution (>= 10k draws, tiny vocab; CI smoke / make test-slow)
# ==========================================================================

def _tv(hist, p):
    return 0.5 * float(np.abs(hist - p).sum())


def _chi2(counts, p, n):
    sup = p > 1e-12
    return float((((counts - n * p) ** 2)[sup] / (n * p)[sup]).sum()), \
        int(sup.sum()) - 1


@pytest.mark.slow
@pytest.mark.parametrize("sc", [
    SamplerConfig(kind="temperature", temperature=1.3),
    SamplerConfig(kind="top_p", temperature=0.9, top_p=0.7),
], ids=["temperature", "top_p"])
def test_verify_window_emits_target_distribution(sc):
    """Fabricated context-free draft/target logits, 20k independent
    windows: at every emission class the output must match the analytic
    target distribution ``sampler_probs(p)`` —

    * the FIRST emitted token (accept-or-resample at position 0);
    * the token at position 1, among windows that reach it;
    * the BONUS token, among all-accept windows (drawn fresh from p_W).

    Seeds are fixed, so the chi-square / TV bounds are deterministic."""
    V, W, N = 8, 3, 20000
    rng = np.random.default_rng(5)
    p_logits = jax.numpy.asarray(rng.normal(size=(W + 1, V)) * 1.5)
    # draft close to target (acceptance high enough that all-accept
    # windows are plentiful) but not equal (rejections still exercised)
    q_logits = p_logits[:W] + jax.numpy.asarray(
        rng.normal(size=(W, V)) * 0.5)
    p = np.asarray(sampler_probs(p_logits, sc))            # (W+1, V)

    dkeys = jax.random.split(jax.random.PRNGKey(7), N * W).reshape(N, W, 2)
    drafts = jax.vmap(
        lambda ks: jax.vmap(sample_from_probs)(ks, sampler_probs(q_logits,
                                                                 sc))
    )(dkeys)
    keys = jax.random.split(jax.random.PRNGKey(42), N)
    out, n_emit, n_acc = jax.vmap(
        lambda k, d: verify_window(k, d, q_logits, p_logits, sc)
    )(keys, drafts)
    out, n_emit, n_acc = (np.asarray(out), np.asarray(n_emit),
                          np.asarray(n_acc))

    checks = [("first token", out[:, 0], p[0]),
              ("position 1", out[n_emit >= 2, 1], p[1]),
              ("bonus token", out[n_acc == W, W], p[W])]
    for name, toks, target in checks:
        n = len(toks)
        assert n >= 2000, f"{name}: only {n} samples (acceptance too low?)"
        counts = np.bincount(toks, minlength=V).astype(np.float64)
        # nothing outside the sampler's support, ever
        assert counts[target <= 1e-12].sum() == 0, \
            f"{name}: emitted a token outside the target support"
        hist = counts / n
        tv = _tv(hist, target)
        chi2, df = _chi2(counts, target, n)
        assert tv < 3.0 * np.sqrt(V / n), (name, tv, n)
        # H0 mean df, sd sqrt(2 df); ~6 sigma headroom, deterministic
        assert chi2 < df + 6.0 * np.sqrt(2.0 * df), (name, chi2, df)


@pytest.mark.slow
def test_engine_spec_sampling_matches_plain_distribution():
    """End-to-end two-sample check through the real engine: serve the
    same 2048-request trace (one shared prompt) with and without
    speculation under a temperature sampler; each request's draws are
    keyed by its rid, so requests are i.i.d. samples of the model's
    sampling process.  The marginal histogram of the first
    post-prefill token (the first speculatively-emitted position) must
    agree between the two engines."""
    cfg = tiny_moe(vocab_size=8)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    sc = SamplerConfig(kind="temperature", temperature=1.1)
    prompt = np.asarray([3, 1], np.int32)
    n = 2048
    reqs = lambda: [Request(rid=i, prompt=prompt, max_new_tokens=3)
                    for i in range(n)]

    def first_post_prefill(spec):
        eng = ServingEngine(cfg, params, num_slots=8, slot_len=2 + 3,
                            sampler=sc, seed=5, speculative=spec)
        rep = eng.run(reqs())
        toks = rep.tokens_by_rid()
        return np.asarray([toks[i][1] for i in range(n)])

    plain = first_post_prefill(None)
    spec = first_post_prefill(SpeculativeConfig(window=2, draft_k=1))
    hp = np.bincount(plain, minlength=8) / n
    hs = np.bincount(spec, minlength=8) / n
    assert _tv(hs, hp) < 2.0 * np.sqrt(2.0) * np.sqrt(8 / n)


# ==========================================================================
# rollback property: truncate_to leaves the pool exactly as a straight
# decode of the accepted prefix would
# ==========================================================================

def _pool_pair(block_size):
    mk = lambda: BlockPool(CFG, num_slots=3, slot_len=24,
                           block_size=block_size)
    return mk(), mk()


def _rollback_vs_straight(n_prefill, W, acc, block_size):
    """Pool A runs a speculative round (W draft advances + verify block +
    rollback); pool B straight-decodes the accepted prefix.  Their entire
    bookkeeping state must be indistinguishable, including an untouched
    bystander slot."""
    pool_a, pool_b = _pool_pair(block_size)
    states = []
    for pool, kind in ((pool_a, "spec"), (pool_b, "straight")):
        by = pool.allocate()                       # bystander
        pool.reserve(by, 8)
        pool.alloc_prompt(by, 5)
        pool.cache_pos[by] = 5
        s = pool.allocate()
        pool.reserve(s, n_prefill + W + 2)
        pool.alloc_prompt(s, n_prefill)
        pool.cache_pos[s] = n_prefill
        bystander_row = pool.block_table[by].copy()
        if kind == "spec":
            for _ in range(W):                     # draft window
                pool.prepare_decode([s])
                pool.advance([s])
            pool.prepare_decode([s])               # verify position
            if acc == W:
                pool.advance([s])
            else:
                pool.truncate_to(s, n_prefill + acc + 1)
        else:                                      # accepted prefix only
            for _ in range(acc + 1):
                pool.prepare_decode([s])
                pool.advance([s])
        pool.check_invariants()
        assert (pool.block_table[by] == bystander_row).all()
        states.append((pool, s))
    (pa, sa), (pb, sb) = states
    assert pa.cache_pos[sa] == pb.cache_pos[sb] == n_prefill + acc + 1
    assert pa._nalloc[sa] == pb._nalloc[sb]
    np.testing.assert_array_equal(pa.block_table[sa], pb.block_table[sb])
    assert sorted(pa._free_blocks) == sorted(pb._free_blocks)
    assert pa.blocks_in_use == pb.blocks_in_use
    # rollback is repeatable from here: both pools grow a fresh block.
    # WHICH free block the pool hands out is an implementation detail
    # (truncate_to appends freed blocks to the free list, so the ids can
    # differ) — the shared prefix and the allocation count must not.
    pa.prepare_decode([sa]), pb.prepare_decode([sb])
    assert pa._nalloc[sa] == pb._nalloc[sb]
    np.testing.assert_array_equal(pa.block_table[sa][:pa._nalloc[sa] - 1],
                                  pb.block_table[sb][:pb._nalloc[sb] - 1])
    pa.check_invariants(), pb.check_invariants()


_ROLLBACK_CASES = [(n, w, a, bs)
                   for n in (1, 3, 8) for w in (1, 2, 4)
                   for a in range(w + 1) for bs in (1, 4)]

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None, derandomize=True)
    @given(n_prefill=st.integers(1, 10), W=st.integers(1, 4),
           acc_frac=st.floats(0.0, 1.0), block_size=st.sampled_from([1, 2, 4]))
    def test_rollback_matches_straight_decode(n_prefill, W, acc_frac,
                                              block_size):
        _rollback_vs_straight(n_prefill, W, int(acc_frac * W), block_size)
else:                                              # pragma: no cover
    @pytest.mark.parametrize("n_prefill,W,acc,block_size", _ROLLBACK_CASES)
    def test_rollback_matches_straight_decode(n_prefill, W, acc,
                                              block_size):
        _rollback_vs_straight(n_prefill, W, acc, block_size)
