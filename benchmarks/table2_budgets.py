"""Table 2 — performance across resource budgets (4 clients, α ∈ {5, 0.5}).

Reduced-scale directional reproduction: FLAME vs trivial/HLoRA/FlexLoRA at
pinned budgets β1 (full) and β4 (most constrained), both heterogeneity
levels.  The paper's claim to validate: FLAME's margin is largest at β4."""
from __future__ import annotations

from .common import emit, run_setting

METHODS = ["flame", "trivial", "hlora", "flexlora"]


def run(budgets=("b1", "b4"), alphas=(5.0, 0.5), rounds=3) -> None:
    rows = []
    for alpha in alphas:
        for budget in budgets:
            for method in METHODS:
                r = run_setting(method, budget=budget, alpha=alpha,
                                clients=4, rounds=rounds)
                rows.append({"alpha": alpha, "budget": budget,
                             "method": method, "score": r["score"],
                             "test_loss": r["test_loss"],
                             "val_loss": r["val_loss"],
                             "wall_s": r["wall_s"]})
    emit("table2_budgets", rows,
         ["alpha", "budget", "method", "score", "test_loss", "val_loss",
          "wall_s"])

    # headline: FLAME >= best baseline at the constrained budget
    for alpha in alphas:
        f = [r for r in rows if r["alpha"] == alpha and r["budget"] == "b4"
             and r["method"] == "flame"][0]
        base = max(r["score"] for r in rows
                   if r["alpha"] == alpha and r["budget"] == "b4"
                   and r["method"] != "flame")
        print(f"# alpha={alpha} beta4: FLAME {f['score']:.2f} vs best "
              f"baseline {base:.2f} -> "
              f"{'CONFIRMS' if f['score'] >= base else 'REFUTES'} paper")


if __name__ == "__main__":
    run()
