"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed in this container; property tests "
           "are tier-2")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import aggregation as agg
from repro.core.flops import count_params, flops_paper_convention
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import Corpus, DataConfig, make_corpus
from repro.models.moe_layer import _capacity, topk_routing

# deterministic CI profile: derandomize fixes the example sequence (no
# flaky shrink-dependent failures, no run-to-run drift) and the bounded
# example count keeps the tier-1 run fast.  requirements-ci.txt installs
# hypothesis, so this suite RUNS in CI — the importorskip only fires in
# stripped local containers.  tests/test_paged_kv.py carries the same
# settings for its pool-invariant interleavings (with a seeded fallback
# sweep that runs even without hypothesis).
settings.register_profile("ci", max_examples=25, deadline=None,
                          derandomize=True)
settings.load_profile("ci")


@given(st.integers(2, 32), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_topk_invariants(E, k, seed):
    k = min(k, E)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (16, E))
    w, m = topk_routing(logits, k)
    m_ = np.asarray(m)
    w_ = np.asarray(w)
    np.testing.assert_allclose(m_.sum(-1), k)           # exactly k selected
    np.testing.assert_allclose(w_.sum(-1), 1.0, rtol=1e-4)
    assert ((m_ == 0) | (m_ == 1)).all()
    assert (w_ >= 0).all()
    assert (w_[m_ == 0] == 0).all()                     # weight only on selected


@given(st.integers(1, 4096), st.integers(1, 128), st.integers(1, 8),
       st.floats(0.1, 4.0))
def test_capacity_monotone_and_positive(T, E, k, cf):
    k = min(k, E)
    c = _capacity(T, E, k, cf)
    assert c >= 8 and c % 8 == 0
    assert _capacity(T, E, min(k + 1, E), cf) >= c      # monotone in k


@given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=6),
       st.integers(0, 2 ** 31 - 1))
def test_weighted_mean_stays_in_hull(sizes, seed):
    """FedAvg output is elementwise inside [min, max] of client values."""
    n = len(sizes)
    key = jax.random.PRNGKey(seed)
    trees = [{"w": jax.random.normal(jax.random.fold_in(key, i), (4, 4))}
             for i in range(n)]
    out = agg.fedavg(trees, sizes)
    stack = np.stack([np.asarray(t["w"]) for t in trees])
    assert (np.asarray(out["w"]) <= stack.max(0) + 1e-5).all()
    assert (np.asarray(out["w"]) >= stack.min(0) - 1e-5).all()


@given(st.integers(1, 8), st.floats(0.05, 10.0), st.integers(0, 10 ** 6))
def test_dirichlet_partition_conserves_examples(n_clients, alpha, seed):
    corpus = make_corpus(DataConfig(vocab_size=64, n_examples=128,
                                    seq_len=32, n_clusters=4, seed=seed))
    shards = dirichlet_partition(corpus, n_clients, alpha, seed=seed)
    assert sum(len(s.tokens) for s in shards) == 128
    assert all(len(s.tokens) >= 2 for s in shards)      # min shard guarantee


@given(st.integers(1, 8))
def test_flame_flops_monotone_in_k(k):
    """Paper Table 1: FLOPs strictly increase with activated experts."""
    from repro.configs.registry import get_config
    cfg = get_config("olmoe-1.3b-6.9b", "full")
    f1 = flops_paper_convention(cfg, 128, k=k, lora_rank=20)
    f2 = flops_paper_convention(cfg, 128, k=min(k + 1, 64), lora_rank=20)
    if k < 64:
        assert f2 > f1
    p = count_params(cfg, k=k)
    assert p["active"] <= p["total"]


@given(st.integers(0, 2 ** 31 - 1))
def test_corpus_deterministic_given_seed(seed):
    c1 = make_corpus(DataConfig(vocab_size=64, n_examples=16, seq_len=32,
                                seed=seed))
    c2 = make_corpus(DataConfig(vocab_size=64, n_examples=16, seq_len=32,
                                seed=seed))
    np.testing.assert_array_equal(c1.tokens, c2.tokens)
    np.testing.assert_array_equal(c1.mask, c2.mask)


@given(st.lists(st.floats(0.0, 1.0), min_size=4, max_size=4),
       st.integers(1, 8))
def test_flame_weights_interpolate_clients(freqs_a, t):
    """With two clients, each expert's aggregate lies on the segment
    between the two client values (convexity of Eq. 7)."""
    E_, NP_ = 4, 1
    key = jax.random.PRNGKey(0)
    mk = lambda s: {"blocks": {"pos0": {"moe": {"experts": {"w1": {
        "a": jax.random.normal(jax.random.fold_in(key, s), (NP_, E_, 4, 2)),
        "b": jnp.zeros((NP_, E_, 2, 4))}}}}}}
    loras = [mk(0), mk(1)]
    fa = {"pos0": jnp.asarray([freqs_a], jnp.float32)}
    fb = {"pos0": 1.0 - jnp.asarray([freqs_a], jnp.float32)}
    out = agg.flame_aggregate(loras, [fa, fb], [10.0, 10.0], temperature=t)
    a0 = np.asarray(loras[0]["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"])
    a1 = np.asarray(loras[1]["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"])
    got = np.asarray(out["blocks"]["pos0"]["moe"]["experts"]["w1"]["a"])
    lo, hi = np.minimum(a0, a1), np.maximum(a0, a1)
    assert (got <= hi + 1e-4).all() and (got >= lo - 1e-4).all()


# --------------------------------------------------------------------------
# serving samplers (serving/sampler.py): the distributions behind both
# plain sampling and the speculative rejection rule
# --------------------------------------------------------------------------

_logit_rows = st.lists(
    st.floats(-30.0, 30.0, allow_nan=False, allow_infinity=False,
              width=32),
    min_size=2, max_size=16)


@given(_logit_rows, st.floats(0.05, 3.0), st.floats(0.05, 0.999))
def test_top_p_never_samples_outside_nucleus(row, temp, top_p):
    """Nucleus support = the smallest prefix of probability-sorted tokens
    reaching ``top_p`` mass: every zero-probability token stays zero, the
    crossing token is included, and mass strictly before any kept token
    is < top_p."""
    from repro.serving.sampler import SamplerConfig, sampler_probs
    logits = jnp.asarray(row, jnp.float32)
    sc = SamplerConfig(kind="top_p", temperature=temp, top_p=top_p)
    probs = np.asarray(sampler_probs(logits, sc), np.float64)
    base = np.asarray(jax.nn.softmax(logits / temp), np.float64)
    order = np.argsort(-base, kind="stable")
    before = np.cumsum(base[order]) - base[order]
    keep = np.zeros(len(row), bool)
    keep[order[before < top_p]] = True
    assert keep.any()                               # argmax always kept
    assert (probs[~keep] == 0.0).all()              # outside nucleus: never
    assert (probs[keep] > 0.0).all()                # inside: always possible
    np.testing.assert_allclose(probs.sum(), 1.0, atol=1e-5)


@given(_logit_rows, st.sampled_from(["temperature", "top_p"]),
       st.floats(0.05, 3.0), st.floats(0.05, 1.0))
def test_sampler_probs_are_distributions(row, kind, temp, top_p):
    from repro.serving.sampler import SamplerConfig, sampler_probs
    sc = SamplerConfig(kind=kind, temperature=temp, top_p=top_p)
    probs = np.asarray(sampler_probs(jnp.asarray(row, jnp.float32), sc))
    assert (probs >= 0.0).all()
    np.testing.assert_allclose(probs.sum(), 1.0, atol=1e-5)
    assert np.isfinite(probs).all()


@given(_logit_rows)
def test_temperature_to_zero_converges_to_greedy(row):
    """As T -> 0 the temperature distribution concentrates on the
    near-argmax set; with a decisive gap it IS the greedy one-hot."""
    from repro.serving.sampler import SamplerConfig, sampler_probs
    logits = jnp.asarray(row, jnp.float32)
    row32 = np.asarray(row, np.float32)
    cold = np.asarray(sampler_probs(
        logits, SamplerConfig(kind="temperature", temperature=1e-5)),
        np.float64)
    near = row32 >= row32.max() - 1e-3
    assert cold[near].sum() > 1.0 - 1e-6
    if near.sum() == 1:                    # decisive max: exact one-hot
        greedy = np.asarray(sampler_probs(
            logits, SamplerConfig(kind="greedy")))
        np.testing.assert_allclose(cold, greedy, atol=1e-6)
