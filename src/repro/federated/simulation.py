"""End-to-end federated experiment assembly.

Builds (model, data shards, budgeted clients, server) for a given method ×
budget grid — the harness behind the Table 2–5 / Figure 2–4 benchmarks.
Budgets are assigned uniformly across the client population (paper §3.2).

Budget assignment doubles as *cohort structure* for the batched round
engine: clients sharing a β tier have identical expert budgets k_i and
adapter ranks, so each round's participants split into at most four
shape-homogeneous vmap groups (see federated/cohort.py, re-exported here
as :func:`build_cohorts`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import numpy as np

from ..configs.base import FederatedConfig, ModelConfig, TrainConfig
from ..core import lora as lora_lib
from ..data.partition import dirichlet_partition
from ..data.synthetic import Corpus, DataConfig, make_corpus, split_corpus
from ..models import model as model_lib
from . import client as client_lib
from .cohort import build_cohorts  # noqa: F401  (re-export: cohort builder)
from .server import (DENSE_BUDGET_RANKS, FLAME_BUDGET_K, MOE_BUDGET_RANKS,
                     FederatedServer)


@dataclass
class Experiment:
    cfg: ModelConfig
    server: FederatedServer
    val: Corpus
    test: Corpus
    budgets: List[str]


def budget_for_client(i: int, budget: Optional[str]) -> str:
    """Budget tier for client ``i``: round-robin β1..β4 when ``budget`` is
    None (the paper's uniform heterogeneous setting), else the fixed tier.

    The tier determines the client's expert budget k_i (FLAME) or LoRA rank
    r_i (baselines) — and therefore its *cohort*: the batched round engine
    vmaps local training over clients with identical tiers, so round-robin
    assignment yields at most four cohorts per round regardless of the
    client count."""
    return budget if budget else f"b{(i % 4) + 1}"


def build_experiment(cfg: ModelConfig, *, fed: FederatedConfig,
                     tc: TrainConfig, data: DataConfig,
                     budget: Optional[str] = None,
                     base_params=None) -> Experiment:
    """Assemble an :class:`Experiment`: init the base model + global LoRA,
    generate and Dirichlet-partition the corpus, and build one budgeted
    :class:`client_lib.ClientState` per client.

    ``budget=None`` assigns β1–β4 uniformly (the paper's main setting);
    ``budget="b4"`` pins every client to one row of the tables.
    ``base_params``: a pre-trained frozen base (the paper fine-tunes
    pretrained LLMs; passing this reproduces that regime at bench scale).

    Each client records its β tier (``ClientState.budget``); at round time
    the server groups participants into per-tier cohorts (same k_i, same
    distributed rank ⇒ shape-homogeneous) and runs each cohort's local
    training as one vmapped computation (``fed.round_engine="batched"``,
    the default) or falls back to the sequential reference loop
    (``"looped"``)."""
    key = jax.random.PRNGKey(fed.seed)
    params = (base_params if base_params is not None
              else model_lib.init_params(key, cfg))
    global_lora = lora_lib.init_lora(jax.random.fold_in(key, 1), cfg, params)

    corpus = make_corpus(data)
    train, val, test = split_corpus(corpus)
    shards = dirichlet_partition(train, fed.num_clients, fed.dirichlet_alpha,
                                 seed=fed.seed)

    is_moe = cfg.moe.enabled
    clients, budgets = [], []
    for i in range(fed.num_clients):
        b = budget_for_client(i, budget)
        budgets.append(b)
        if fed.method == "flame":
            # scale the paper's k grid {8,4,2,1} into this model's top_k
            k_i = (max(1, round(cfg.moe.top_k * FLAME_BUDGET_K[b]
                                / FLAME_BUDGET_K["b1"]))
                   if is_moe else 0)
            rank_i = cfg.lora.rank
        else:
            grid = MOE_BUDGET_RANKS if is_moe else DENSE_BUDGET_RANKS
            # scale the paper's rank grid into the model's configured rank
            rank_i = max(1, round(cfg.lora.rank * grid[b] / grid["b1"]))
            k_i = cfg.moe.top_k if is_moe else 0
        rescaler = None
        if fed.method == "flame" and is_moe and fed.rescaler != "none":
            rescaler = lora_lib.init_rescalers(cfg, k_i, fed.rescaler)
        clients.append(client_lib.ClientState(
            client_id=i, shard=shards[i], k=k_i or cfg.moe.top_k,
            rank=rank_i, rescaler=rescaler, rescaler_mode=fed.rescaler,
            budget=b))

    server = FederatedServer(cfg, params, global_lora, clients, fed, tc)
    return Experiment(cfg=cfg, server=server, val=val, test=test,
                      budgets=budgets)


def run_experiment(exp: Experiment, *, eval_k: Optional[int] = None
                   ) -> Dict[str, float]:
    """Run all rounds, return final metrics.

    ``eval_k``: #experts activated at evaluation (FLAME's deployment-
    efficiency claim: a model fine-tuned under reduced activation can be
    *served* with reduced activation).  Defaults to the server top_k.
    """
    exp.server.run()
    cfg = exp.cfg
    k = eval_k or (cfg.moe.top_k if cfg.moe.enabled else 0)
    trainable = {"lora": exp.server.global_lora}
    val_loss = client_lib.evaluate(cfg, exp.server.params, trainable,
                                   exp.val, k=k or 1)
    test_loss = client_lib.evaluate(cfg, exp.server.params, trainable,
                                    exp.test, k=k or 1)
    # monotone "higher is better" proxy so tables read like the paper's
    return {"val_loss": val_loss, "test_loss": test_loss,
            "score": 100.0 * float(np.exp(-test_loss)),
            "rounds": len(exp.server.history)}
