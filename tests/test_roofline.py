"""Roofline machinery tests: HLO trip-count parsing + analytic models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.launch import analytic, hlo_parse
from repro.launch.roofline import Roofline


def _scan_hlo(trips: int):
    def f(x, ws):
        def body(h, w):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, ws)
        return h
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((trips, 64, 64), jnp.float32))
    return lowered.compile().as_text()


def test_split_computations_finds_while_regions():
    hlo = _scan_hlo(37)
    comps = hlo_parse.split_computations(hlo)
    assert len(comps) >= 2
    assert any("while(" in t for t in comps.values())


def test_trip_count_extraction():
    hlo = _scan_hlo(37)
    comps, mult = hlo_parse.computation_multipliers(hlo)
    assert max(mult.values()) == 37.0


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(h, w):
            def inner(h2, _):
                return h2 @ w, None
            h2, _ = jax.lax.scan(inner, h, None, length=5)
            return h2, None
        h, _ = jax.lax.scan(outer, x, ws)
        return h
    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)).compile().as_text()
    _, mult = hlo_parse.computation_multipliers(hlo)
    assert max(mult.values()) == 35.0        # 7 × 5


def test_shape_bytes():
    assert hlo_parse.shape_bytes("f32[128,4]{1,0}") == 128 * 4 * 4
    assert hlo_parse.shape_bytes("bf16[2,3]") == 12
    assert hlo_parse.shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert hlo_parse.shape_bytes("pred[]") == 1


def test_collective_regex_matches_real_ops():
    line = ("  %all-gather.1 = f32[128,512]{0,1} all-gather(%fusion), "
            "channel_id=2, replica_groups=[4,4]<=[4,4]T(1,0)")
    m = hlo_parse._COLLECTIVE.search(line)
    assert m and m.group(2) == "all-gather"
    assert hlo_parse.shape_bytes(m.group(1)) == 128 * 512 * 4


# ---------------------------------------------------------------- analytic

CHIPS = 256


@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_analytic_terms_positive_and_ordered(shape_name):
    cfg = get_config("qwen3-moe-235b-a22b",
                     "swa" if shape_name == "long_500k" else "full")
    shape = INPUT_SHAPES[shape_name]
    knobs = {"k": 8, "n_micro": 8, "remat": True}
    f = analytic.device_flops(cfg, shape, CHIPS, knobs)
    b = analytic.device_bytes(cfg, shape, CHIPS, knobs)
    m = analytic.model_flops_global(cfg, shape, knobs)
    assert f > 0 and b > 0 and m > 0
    # compiled work must be >= useful work (remat/backward overhead)
    if shape.kind == "train":
        assert f * CHIPS >= m


def test_train_flops_scale_with_k():
    """FLAME economics at the roofline level: fewer experts, fewer FLOPs."""
    cfg = get_config("qwen3-moe-235b-a22b", "full")
    shape = INPUT_SHAPES["train_4k"]
    f8 = analytic.device_flops(cfg, shape, CHIPS, {"k": 8})
    f1 = analytic.device_flops(cfg, shape, CHIPS, {"k": 1})
    assert f1 < 0.7 * f8


def test_decode_memory_dominated_by_cache():
    cfg = get_config("llama3-405b", "full")
    shape = INPUT_SHAPES["decode_32k"]
    b = analytic.device_bytes(cfg, shape, CHIPS, {})
    cache = analytic._cache_bytes(cfg, shape.global_batch,
                                  shape.seq_len) / CHIPS
    assert cache / b > 0.5


def test_roofline_bottleneck_logic():
    r = Roofline(arch="x", shape="y", mesh="m", chips=4,
                 hlo_flops=197e12, hlo_bytes=1.0, collective_bytes=1.0,
                 model_flops=4 * 197e12, bytes_per_device=1.0,
                 collectives={}, meta={})
    assert r.bottleneck == "compute"
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.mfu - 1.0) < 1e-6
    r2 = Roofline(arch="x", shape="y", mesh="m", chips=4,
                  hlo_flops=1.0, hlo_bytes=819e9, collective_bytes=50e9 * 2,
                  model_flops=1.0, bytes_per_device=1.0,
                  collectives={}, meta={})
    assert r2.bottleneck == "collective"
    assert abs(r2.step_time - 2.0) < 1e-9
