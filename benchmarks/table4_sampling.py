"""Table 4 — client sampling (participation p ∈ {100%, 50%, 25%}).

Validates FLAME's graceful degradation under intermittent availability."""
from __future__ import annotations

from .common import emit, run_setting


def run(clients=8, rates=(1.0, 0.5, 0.25), rounds=3) -> None:
    rows = []
    for p in rates:
        for method in ("flame", "flexlora"):
            r = run_setting(method, budget="b4", alpha=0.5, clients=clients,
                            rounds=rounds, participation=p, n_examples=256)
            rows.append({"participation": p, "method": method,
                         "score": r["score"], "test_loss": r["test_loss"],
                         "wall_s": r["wall_s"]})
    emit("table4_sampling", rows,
         ["participation", "method", "score", "test_loss", "wall_s"])
    fl = {r["participation"]: r["score"] for r in rows
          if r["method"] == "flame"}
    print(f"# FLAME degradation 100%->25%: "
          f"{fl[1.0]:.2f} -> {fl[0.25]:.2f} "
          f"({100 * (fl[1.0] - fl[0.25]) / max(fl[1.0], 1e-9):.1f}% drop)")


if __name__ == "__main__":
    run()
