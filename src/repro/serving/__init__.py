"""Adaptive-k serving: continuous batching over a slotted KV cache.

The subsystem has four layers (docs/architecture.md §Serving):

* :mod:`repro.serving.kv_cache`  — ``SlotPool``: a fixed-capacity slotted
  (paged-lite) KV-cache pool with allocate/release and per-slot
  ``cache_pos``, so requests of different lengths share one compiled
  decode step;
* :mod:`repro.serving.scheduler` — ``Request``/``Scheduler``: FIFO queue
  with tier-aware admission into free slots;
* :mod:`repro.serving.engine`    — ``ServingEngine``: the continuous-
  batching loop; one jitted decode step over the whole slot batch with
  **per-slot expert budget k** (FLAME's adaptive-k at serving time) and
  the rescaler applied per slot;
* :mod:`repro.serving.workload`  — synthetic open-loop arrival traces
  (Poisson arrivals, length/tier mixes) and latency percentile helpers.
"""
from .engine import ServingEngine, ServingReport  # noqa: F401
from .kv_cache import SlotPool  # noqa: F401
from .scheduler import Completion, Request, Scheduler  # noqa: F401
from .workload import WorkloadConfig, make_trace, percentile  # noqa: F401
