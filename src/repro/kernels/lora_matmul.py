"""Pallas TPU kernel: fused LoRA matmul  y = x·W + (x·A)·B · scale.

The expert-FFN matmul is the compute hot spot of FLAME fine-tuning.  Naively
the LoRA bypass ``(x@A)@B`` is a separate pair of skinny matmuls whose
intermediates round-trip HBM.  This kernel fuses base + bypass in one pass:

  grid = (M/bm, N/bn, K/bk)   — k innermost (sequential on TPU), so the
  fp32 accumulator and the running ``x·A`` projection live in VMEM scratch
  across k iterations;

  * every k step: ``acc += x_blk @ w_blk`` (MXU, 128-aligned tiles) and
    ``xa += x_blk @ a_blk`` (A is sliced along K with the same index map
    as x, so the bypass never re-reads x from HBM);
  * last k step: ``acc += (xa @ B_blk) · scale`` — B is tiny ((r, bn));
    then the fp32 accumulator is cast once and written out.

VMEM working set per program: bm·bk + bk·bn + bm·bn + bm·r + r·bn floats —
with bm=bn=bk=256, r≤64 that is ~1 MB, far under the ~16 MB v5e VMEM budget.

Validated against ``ref.lora_matmul_ref`` with interpret=True shape/dtype
sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lora_matmul_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_scr, xa_scr,
                        *, scale: float, nk: int, k_axis: int = 2):
    ik = pl.program_id(k_axis)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        xa_scr[...] = jnp.zeros_like(xa_scr)

    x = x_ref[...].reshape(x_ref.shape[-2:]).astype(jnp.float32)  # (bm, bk)
    w = w_ref[...].reshape(w_ref.shape[-2:]).astype(jnp.float32)  # (bk, bn)
    a = a_ref[...].reshape(a_ref.shape[-2:]).astype(jnp.float32)  # (bk, r)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    xa_scr[...] += jax.lax.dot_general(
        x, a, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        b = b_ref[...].reshape(b_ref.shape[-2:]).astype(jnp.float32)  # (r, bn)
        bypass = jax.lax.dot_general(
            xa_scr[...], b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        out = (acc_scr[...] + bypass * scale).astype(o_ref.dtype)
        o_ref[...] = out.reshape(o_ref.shape)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_m", "block_n", "block_k", "interpret"))
def lora_matmul(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
                b: jnp.ndarray, *, scale: float = 1.0,
                block_m: int = 256, block_n: int = 256, block_k: int = 256,
                interpret: bool = True) -> jnp.ndarray:
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N) -> (M, N)."""
    M, K = x.shape
    Kw, N = w.shape
    r = a.shape[-1]
    assert Kw == K and a.shape == (K, r) and b.shape == (r, N)
    bm = min(block_m, M)
    bn = min(block_n, N)
    bk = min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk

    kernel = functools.partial(_lora_matmul_kernel, scale=scale, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda im, jn, ik: (im, ik)),
            pl.BlockSpec((bk, bn), lambda im, jn, ik: (ik, jn)),
            pl.BlockSpec((bk, r), lambda im, jn, ik: (ik, 0)),
            pl.BlockSpec((r, bn), lambda im, jn, ik: (0, jn)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda im, jn, ik: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),   # base accumulator
            pltpu.VMEM((bm, r), jnp.float32),    # running x·A
        ],
        interpret=interpret,
    )(x, w, a, b)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_m", "block_n", "block_k", "interpret"))
def lora_matmul_experts(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
                        b: jnp.ndarray, *, scale: float = 1.0,
                        block_m: int = 128, block_n: int = 256,
                        block_k: int = 256,
                        interpret: bool = True) -> jnp.ndarray:
    """Stacked per-expert variant: x (E, C, K); w (E, K, N); a (E, K, r);
    b (E, r, N) -> (E, C, N).  The expert axis becomes the outer grid dim so
    each expert's LoRA factors are fetched once and stay VMEM-resident."""
    E, C, K = x.shape
    N = w.shape[-1]
    r = a.shape[-1]
    bm = min(block_m, C)
    bn = min(block_n, N)
    bk = min(block_k, K)
    assert C % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk

    kernel = functools.partial(_lora_matmul_kernel, scale=scale, nk=nk,
                               k_axis=3)

    return pl.pallas_call(
        kernel,
        grid=(E, C // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, im, jn, ik: (e, im, ik)),
            pl.BlockSpec((1, bk, bn), lambda e, im, jn, ik: (e, ik, jn)),
            pl.BlockSpec((1, bk, r), lambda e, im, jn, ik: (e, ik, 0)),
            pl.BlockSpec((1, r, bn), lambda e, im, jn, ik: (e, 0, jn)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, im, jn, ik: (e, im, jn)),
        out_shape=jax.ShapeDtypeStruct((E, C, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b)
