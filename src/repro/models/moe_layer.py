"""Sparse Mixture-of-Experts FFN with adaptive top-k (the FLAME substrate).

TPU-native, static-shape dispatch (GShard/Switch style):

  1. router logits -> softmax probabilities, top-``k_i`` selection where
     ``k_i`` is the *client budget* (FLAME Eq. 5: clients activate fewer
     experts than the server default ``k``);
  2. capacity-based one-hot dispatch tensors (tokens that overflow an
     expert's capacity fall back to the residual stream — standard GShard
     semantics, required because XLA needs static shapes);
  3. expert computation as stacked einsums over an expert-sharded weight
     tensor (expert parallelism on the ``model`` mesh axis — GSPMD emits
     all-to-alls around the dispatch/combine einsums);
  4. per-expert **activation counts** are returned so the federated server
     can form the activation-aware aggregation weights (Eq. 6);
  5. a learnable **rescaler** multiplies the combined expert output to
     re-calibrate magnitude under partial activation (Eq. 5's ``s_i``).

Compute genuinely scales with ``k_i`` via the capacity
``C = ceil(k_i * S / E * capacity_factor)`` — this is the paper's central
FLOPs-adaptivity claim, preserved in static-shape form.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..kernels import backend as kernel_backend
from .layers import dense_init, lora_expert_einsum


class MoEAux(NamedTuple):
    """Auxiliary routing stats threaded out of the forward pass."""

    activation_counts: jnp.ndarray   # (E,) float — # tokens routed to expert j
    total_tokens: jnp.ndarray        # () float — tokens processed (= S_i unit)
    load_balance_loss: jnp.ndarray   # () float — Switch aux loss (optional use)


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, (d, m.num_experts), dtype, scale=0.1),
        "experts": {
            "w1": dense_init(k1, (m.num_experts, d, m.d_expert), dtype),
            "w3": dense_init(k2, (m.num_experts, d, m.d_expert), dtype),
            "w2": dense_init(k3, (m.num_experts, m.d_expert, d), dtype),
        },
    }
    if m.num_shared_experts > 0:
        dsh = m.d_shared_expert or m.d_expert * m.num_shared_experts
        ka, kb, kc = jax.random.split(ks, 3)
        p["shared"] = {
            "w1": dense_init(ka, (d, dsh), dtype),
            "w3": dense_init(kb, (d, dsh), dtype),
            "w2": dense_init(kc, (dsh, d), dtype),
        }
    return p


def _capacity_from_assignments(n_assign: float, num_experts: int,
                               factor: float) -> int:
    """Expert-queue capacity for ``n_assign`` total (token, expert)
    assignments: ceil-ish with slack ``factor``, rounded up to a multiple
    of 8 for lane-friendly layouts."""
    c = int(n_assign * factor / num_experts) + 1
    return max(8, ((c + 7) // 8) * 8)


def _capacity(tokens: int, num_experts: int, k: int, factor: float) -> int:
    return _capacity_from_assignments(tokens * k, num_experts, factor)


def dense_capacity(tokens: int) -> int:
    """Per-expert queue capacity of the dense no-drop mode: one expert can
    receive at most one copy of each token, so C = the group's token count
    (rounded up for lane layouts) guarantees zero overflow."""
    return max(8, ((tokens + 7) // 8) * 8)


DISPATCH_MODES = ("capacity", "dense", "ragged")


def resolve_dispatch(dispatch, no_drop: bool) -> str:
    """Normalise the dispatch-mode spelling: an explicit ``dispatch``
    string wins; otherwise the legacy ``no_drop`` flag selects between
    GShard-capacity (False) and dense no-drop (True)."""
    if dispatch is None:
        return "dense" if no_drop else "capacity"
    assert dispatch in DISPATCH_MODES, dispatch
    return dispatch


def topk_routing(router_logits: jnp.ndarray, k: int):
    """Reference routing: softmax over experts then iterative top-k.

    router_logits: (T, E).  Returns (weights (T,E), mask (T,E)) where mask is
    the 0/1 selection and weights are the softmax probs of the selected
    experts renormalised to sum to 1 per token.

    Delegates to ``repro.kernels.ref.topk_router_ref`` — the single source
    of truth for routing semantics (the Pallas router kernel is validated
    against the same oracle).
    """
    from ..kernels.ref import topk_router_ref
    weights, mask, _ = topk_router_ref(router_logits, k)
    return weights, mask


def _one_hot_expert_ffn(p: dict, cfg, xg: jnp.ndarray, weights, mask, *,
                        dispatch: str, k: Optional[int],
                        n_assign: Optional[int], lora: dict,
                        lora_scale: float, shard_fns: dict):
    """GShard one-hot dispatch + expert FFN + combine.

    ``xg``: (G, Tg, D) grouped tokens; ``weights``/``mask``: (G, Tg, E).
    ``dispatch="capacity"`` drops tokens past
    ``C = ceil(assignments·cf / E)``; ``dispatch="dense"`` is the
    loss-free variant with ``C = Tg`` (worst-case padding).  Capacity
    scales with the TOTAL expert assignments: on the adaptive path a
    mixed batch's ``n_assign`` follows sum(k_i), so constrained slots
    genuinely shrink the expert workload (FLAME's FLOPs-adaptivity, per
    slot instead of per client)."""
    m = cfg.moe
    G, Tg, D = xg.shape
    E = m.num_experts
    sf = shard_fns
    if dispatch == "dense":
        C = dense_capacity(Tg)
    elif n_assign is not None:
        C = _capacity_from_assignments(n_assign, E, m.capacity_factor)
    else:
        C = _capacity(Tg, E, k, m.capacity_factor)
    # position of each token within its expert's per-group queue
    pos_in_expert = (jnp.cumsum(mask, axis=1) - 1.0) * mask       # (G, Tg, E)
    keep = (pos_in_expert < C) & (mask > 0)
    pos_oh = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), C,
                            dtype=xg.dtype)                       # (G,Tg,E,C)
    dispatch_oh = pos_oh * keep[..., None].astype(xg.dtype)
    combine = dispatch_oh * weights[..., None].astype(xg.dtype)
    if "dispatch" in sf:
        # keep the dispatch one-hot group-sharded with the FULL expert dim —
        # the E→model restriction happens on the (much smaller) slot tensor,
        # where it is a local slice.  Without this GSPMD all-gathers the
        # (G,Tg,E,C) one-hot per layer (EXPERIMENTS.md §Perf H1).
        dispatch_oh = sf["dispatch"](dispatch_oh)
    if "combine" in sf:
        # the combine one-hot IS E→model-sharded so the combine einsum
        # contracts the local expert slice and all-reduces the (G,Tg,D)
        # token output — 3.7× less traffic than gathering expert outputs
        combine = sf["combine"](combine)

    # gather token slots: (G, E, C, D) — the expert all-to-all boundary
    slots = jnp.einsum("gtec,gtd->gecd", dispatch_oh, xg)
    if "slots" in sf:
        slots = sf["slots"](slots)

    # ----- expert FFN (SwiGLU) with per-expert LoRA -----
    # kernels=cfg.kernels: on the pallas backend each matmul is the fused
    # base+bypass lora_matmul_experts kernel (docs/kernels.md)
    gate = lora_expert_einsum(slots, p["experts"]["w1"], lora.get("w1"),
                              lora_scale, kernels=cfg.kernels)
    up = lora_expert_einsum(slots, p["experts"]["w3"], lora.get("w3"),
                            lora_scale, kernels=cfg.kernels)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
    eo = lora_expert_einsum(h, p["experts"]["w2"], lora.get("w2"),
                            lora_scale, kernels=cfg.kernels)

    eo = sf["slots"](eo) if "slots" in sf else eo
    out = jnp.einsum("gtec,gecd->gtd", combine, eo)               # (G, Tg, D)
    if "out" in sf:
        out = sf["out"](out)
    return out


def _ragged_expert_ffn(p: dict, cfg, x2d: jnp.ndarray, weights, mask, *,
                       budget: int, max_k: int, lora: dict,
                       lora_scale: float):
    """Sort-based ragged dispatch + expert FFN + combine (loss-free AND
    budget-proportional — kernels/ragged_dispatch.py).

    ``x2d``: (T, D) flat tokens; ``weights``/``mask``: (T, E);
    ``budget``: static worst-case assignment count; ``max_k``: static
    per-token selection cap.  Every op dispatches through the kernel
    backend (Pallas forward + reference backward on the pallas path)."""
    from ..kernels import ragged_dispatch as ragged_mod
    plan = ragged_mod.ragged_plan(mask, weights, budget=budget, max_k=max_k)
    xs = kernel_backend.ragged_gather(cfg.kernels, x2d, plan.src, plan.valid)

    def mm(inp, key):
        lp = lora.get(key)
        return kernel_backend.ragged_expert_matmul(
            cfg.kernels, inp, plan.block_expert, p["experts"][key],
            None if lp is None else lp["a"], None if lp is None else lp["b"],
            scale=lora_scale)

    gate = mm(xs, "w1")
    up = mm(xs, "w3")
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
    eo = mm(h, "w2")
    return kernel_backend.ragged_combine(cfg.kernels, eo, plan.rows,
                                         plan.wrank)


def apply_moe(p: dict, cfg, x: jnp.ndarray, *, k,
              rescaler: Optional[jnp.ndarray] = None,
              lora: Optional[dict] = None, lora_scale: float = 0.0,
              deterministic: bool = True,
              rng: Optional[jax.Array] = None,
              num_groups: int = 1,
              shard_fns: Optional[dict] = None,
              slot_mask: Optional[jnp.ndarray] = None,
              no_drop: bool = False,
              dispatch: Optional[str] = None):
    """x: (B, S, D) -> (out (B,S,D), MoEAux).

    ``k`` is static (client budget k_i): an ``int`` applied to every token,
    or a length-``B`` tuple of per-row budgets (the serving engine's
    per-slot adaptive k — each row of the batch decodes at its own budget,
    see serving/engine.py).  A uniform tuple collapses to the int path, so
    the two spellings are bit-identical.  ``rescaler`` is the FLAME
    learnable scalar s_i (None => 1.0): a scalar, or a length-``B`` vector
    applied per row (the engine's per-slot rescaler).

    ``slot_mask``: optional dynamic (B,) 0/1 vector — rows at 0 route to
    ZERO experts (their budget is masked, not just their output), so they
    cannot occupy expert-queue capacity that real rows need.  The serving
    engine masks its free slots this way; without it, garbage rows in a
    slotted decode batch could evict real tokens under GShard capacity.
    A (B, S) mask applies per token — the suffix-prefill path masks
    ragged suffix-length padding columns the same way.

    ``dispatch`` selects among three token-dispatch strategies (see
    docs/kernels.md §MoE dispatch modes for the trade-off table):

    * ``"capacity"`` (the default) — GShard one-hot dispatch with
      ``C = ceil(assignments·cf / E)``; tokens past an expert's capacity
      fall back to the residual stream.  The training mode.
    * ``"dense"`` — the same one-hot dispatch with ``C = T_g``: loss-free
      (no token can EVER drop, so co-batched rows cannot change a row's
      result) but every expert pays worst-case padding — compute no
      longer follows the activated budget.
    * ``"ragged"`` — sort-based dispatch (kernels/ragged_dispatch.py):
      loss-free like ``"dense"`` AND compute-proportional to the
      activated budget (``T·k``, or ``S·sum(slot_k)`` per-slot) like
      ``"capacity"``.  Routes globally (requires ``num_groups == 1``,
      no grouped-sharding path yet) — the serving engine's default.

    ``no_drop`` is the legacy alias: ``True`` means ``dispatch="dense"``
    (an explicit ``dispatch`` wins).

    ``num_groups``: GShard routing groups.  Capacity and the dispatch/
    combine one-hots are *per-group* ``(G, T_g, E, C_g)`` so when the token
    dim is batch-sharded over the ``data`` mesh axis (G = a multiple of the
    data parallelism) the dispatch tensor stays shard-local and only the
    slot tensor crosses the mesh (the expert all-to-all).  G=1 reproduces
    the global-routing reference semantics used by the CPU tests.
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E = m.num_experts
    G = num_groups
    assert T % G == 0, (T, G)
    Tg = T // G
    xg = x.reshape(G, Tg, D)
    dispatch = resolve_dispatch(dispatch, no_drop)

    if isinstance(k, (tuple, list)):
        assert len(k) == B, (len(k), B)
        if len(set(k)) == 1 and slot_mask is None:
            k = int(k[0])                 # uniform budgets: static-int path
    adaptive = isinstance(k, (tuple, list)) or slot_mask is not None
    if adaptive:
        # per-row budgets need global routing: grouped dispatch would need
        # per-group capacities (the serving decode path runs G == 1)
        assert G == 1, "per-slot k requires num_groups == 1"
        k_slots = (tuple(int(v) for v in k)
                   if isinstance(k, (tuple, list)) else (int(k),) * B)
        max_k = max(k_slots)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"])           # (G, Tg, E)
    if not deterministic and m.router_jitter > 0 and rng is not None:
        logits = logits + m.router_jitter * jax.random.normal(
            rng, logits.shape, logits.dtype)
    if adaptive:
        # per-token budgets have no fused kernel — route through the
        # reference adaptive router (the expert matmuls below still
        # dispatch per backend); row b's S tokens all use budget k[b],
        # zeroed where slot_mask marks the row inactive
        from ..kernels.ref import adaptive_topk_router_ref
        k_tok = jnp.repeat(jnp.asarray(k_slots, jnp.int32), S)
        if slot_mask is not None:
            if slot_mask.ndim == 2:        # per-token (B, S) validity
                k_tok = k_tok * slot_mask.reshape(T).astype(jnp.int32)
            else:
                k_tok = k_tok * jnp.repeat(slot_mask.astype(jnp.int32), S)
        weights, mask, counts = adaptive_topk_router_ref(
            logits.reshape(T, E), k_tok, max_k)                   # (T, E) fp32
    else:
        # backend-dispatched fused router (softmax + top-k + the FLAME Eq. 6
        # activation counts); reference path = ref.topk_router_ref, whose
        # routing semantics are identical to topk_routing below
        weights, mask, counts = kernel_backend.router(
            cfg.kernels, logits.reshape(T, E), k)                 # (T, E) fp32
    # Switch-style load-balance aux loss (kept for completeness; the paper
    # fine-tunes with the router frozen so this is usually unused).
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    lb = E * jnp.mean(probs.mean((0, 1))
                      * mask.reshape(G, Tg, E).mean((0, 1))) * E
    sf = shard_fns or {}
    le = (lora or {}).get("experts", {})

    if dispatch == "ragged":
        assert G == 1, "ragged dispatch routes globally (num_groups == 1)"
        assert not sf, "ragged dispatch has no grouped-sharding path yet"
        budget = S * sum(k_slots) if adaptive else T * k
        out = _ragged_expert_ffn(p, cfg, xg.reshape(T, D), weights, mask,
                                 budget=budget,
                                 max_k=(max_k if adaptive else k),
                                 lora=le, lora_scale=lora_scale)
        out = out.reshape(G, Tg, D)
    else:
        n_assign = S * sum(k_slots) if adaptive else None
        out = _one_hot_expert_ffn(p, cfg, xg, weights.reshape(G, Tg, E),
                                  mask.reshape(G, Tg, E), dispatch=dispatch,
                                  k=None if adaptive else k,
                                  n_assign=n_assign, lora=le,
                                  lora_scale=lora_scale, shard_fns=sf)

    if rescaler is not None:
        r = rescaler.astype(out.dtype)
        if r.ndim == 1 and r.shape[0] == B:
            # per-slot rescaler s_i (serving): row b's tokens scale by r[b]
            r = jnp.repeat(r, S).reshape(G, Tg, 1)
        out = out * r

    # ----- shared experts (always active; Qwen2-MoE style) -----
    if "shared" in p:
        from .layers import apply_ffn
        ls = (lora or {}).get("shared")
        out = out + apply_ffn(p["shared"], xg, ls, lora_scale,
                              kernels=cfg.kernels)

    aux = MoEAux(activation_counts=counts,
                 total_tokens=jnp.asarray(T, jnp.float32),
                 load_balance_loss=lb)
    return out.reshape(B, S, D), aux
