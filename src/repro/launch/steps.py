"""pjit-able step functions + sharding assembly for the production mesh.

One builder per input-shape kind:

  * ``build_train``   — LoRA fine-tune step (the paper's client step):
    microbatched gradient accumulation (``lax.scan``), remat'd layer scan,
    Adam on the trainable (LoRA + rescaler) tree only — base weights are
    frozen so they carry **no** optimizer state (this is what makes
    llama3-405b fine-tuning fit 256 chips).
  * ``build_prefill`` — forward + KV-cache build.
  * ``build_serve``   — ONE token against a ``seq_len``-deep cache (decode);
    cache donated so it updates in place.

Each returns a ``StepBundle``: the jitted fn (with in/out shardings bound),
the abstract example args, and metadata the dry-run records.

FLAME integration: every step takes the *static* expert budget ``k`` —
clients fine-tune with ``k_i ≤ k`` (Eq. 5) and serving uses the reduced
activation directly (the paper's deployment-efficiency claim).  The train
step also returns the summed per-expert activation counts the server's
activation-aware aggregation (Eq. 6) consumes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig, TrainConfig
from ..models import model as model_lib
from ..optim import adam
from . import sharding as shd
from . import specs as specs_lib

PyTree = Any

# per-device saved-activation budget used to auto-pick microbatching (bytes)
ACT_BUDGET = 4 * 2 ** 30


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Any                      # jitted step
    args: Tuple[PyTree, ...]     # abstract example args (ShapeDtypeStructs)
    meta: Dict[str, Any]


# --------------------------------------------------------------------------
# knob auto-selection (napkin math — see EXPERIMENTS.md §Perf for the
# measured validation of these choices)
# --------------------------------------------------------------------------

def _data_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.axis_names]))


def choose_train_knobs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                       ) -> Dict[str, Any]:
    """Pick (n_micro, remat_chunk, act_mode) so the saved-activation
    footprint fits the per-device budget.

    Strategy (validated in EXPERIMENTS.md §Perf): keep activations
    UNSHARDED (act_mode=batch — sharding them puts a collective on every
    matmul) and use two-level (√L) checkpointing, which shrinks the saved
    residuals from n_periods·|h| to (n_outer + chunk)·|h| per microbatch;
    minimise n_micro (every microbatch re-gathers the FSDP-sharded weights).
    Fall back to d_model-sharded activations only if even mb_local=1 with
    √L remat doesn't fit."""
    dp = _data_size(mesh)
    B, S = shape.global_batch, shape.seq_len
    n_periods = cfg.num_layers // cfg.pattern_period
    chunk = max(int(round(n_periods ** 0.5)), 1)
    while n_periods % chunk:
        chunk -= 1
    n_saved = (n_periods // chunk + chunk) if chunk > 1 else n_periods
    per_seq = n_saved * S * cfg.d_model * 2     # one (S,d) carry per boundary
    # mamba SSD transients (Ldec (nc,H,L,L) + einsum partials, fp32) scale
    # with the local batch and dwarf the carries for SSM/hybrid archs —
    # ignoring them regressed jamba train to 85 GB/device (§Perf)
    if any(cfg.layer_kind(l) == "ssm" for l in range(cfg.num_layers)):
        from ..models.mamba2 import mamba_dims
        dims = mamba_dims(cfg)
        L = min(cfg.ssm.chunk_size, S)
        per_seq += 3 * S * dims["n_heads"] * L * 4

    n_micro = 1
    while n_micro < B // max(dp, 1):
        mb_local = max(B // (n_micro * dp), 1)
        if mb_local * per_seq <= ACT_BUDGET:
            break
        n_micro *= 2
    mb_local = max(B // (n_micro * dp), 1)
    act_mode = "batch"
    if mb_local * per_seq > ACT_BUDGET:
        act_mode = "dmodel"      # last resort: shard the carry's d_model
    return {"n_micro": n_micro, "act_mode": act_mode,
            "remat_chunk": chunk if chunk > 1 else 0}


def choose_num_groups(cfg: ModelConfig, batch: int, seq: int, mesh: Mesh,
                      target_group: int = 2048) -> int:
    """GShard routing groups.  Two constraints:

    1. groups shard over ``data`` (G a multiple of the data parallelism)
       so the (G, T_g, E, C) dispatch one-hots stay shard-local;
    2. T_g stays near ``target_group`` — capacity C grows ∝ T_g·k/E, so a
       large group makes the dispatch tensor quadratic in T_g (the 166
       GB/device blow-up the first dry-run sweep caught; see EXPERIMENTS.md
       §Perf iteration 0).
    """
    if not cfg.moe.enabled:
        return 1
    T = batch * seq
    if T <= target_group:
        return 1
    g = 1
    while g * 2 <= T // target_group and T % (g * 2) == 0:
        g *= 2
    dp = _data_size(mesh)
    while g < dp and T % (g * 2) == 0:     # ≥ one group per data shard
        g *= 2
    return g


def _moe_shard_fns(mesh: Mesh):
    """Sharding constraints for the MoE internals (EXPERIMENTS.md §Perf H1):
    keep the (G,Tg,E,C) one-hots group-sharded with E FULL (restricting E on
    the one-hot makes GSPMD all-gather it — ~500 GB/step on qwen3-moe); the
    E→model restriction lands on the slot tensor where it's a local slice;
    the combined token output goes straight back to group sharding."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def c(spec):
        def f(t):
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(mesh, spec))
        return f

    # NOTE: an E→model constraint on the *combine* one-hot was tried and
    # REFUTED — its backward re-gathers the one-hot (EXPERIMENTS.md §Perf
    # H1 iteration 2: 110.6 s → 125.2 s).  Keep combine unconstrained.
    return {
        "dispatch": c(P(baxes, None, None, None)),
        "slots": c(P(baxes, "model", None, None)),
        "out": c(P(baxes, None, None)),
    }


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh: Mesh, *, k: Optional[int],
                    tc: TrainConfig, n_micro: int, act_mode: str,
                    num_groups: int, remat: bool = True, remat_chunk: int = 0,
                    rescaler_trainable: bool = True):
    """Returns step(params, trainable, opt_state, tokens, labels, mask)
    -> (trainable, opt_state, metrics)."""
    act_spec = shd.activation_spec(mesh, "seq" if act_mode == "sp"
                                   else act_mode)

    def act_fn(h):
        return jax.lax.with_sharding_constraint(h, NamedSharding(mesh, act_spec))

    inner_act_fn = None
    if act_mode == "sp":
        # Megatron-SP: gather the sequence dim for attention/FFN compute
        full_spec = shd.activation_spec(mesh, "batch")

        def inner_act_fn(h):
            return jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, full_spec))

    moe_shard_fns = None
    if cfg.moe.enabled and num_groups >= _data_size(mesh) > 1:
        moe_shard_fns = _moe_shard_fns(mesh)

    def step(params, trainable, opt_state, tokens, labels, mask):
        B = tokens.shape[0]
        S = tokens.shape[1]
        mb = B // n_micro
        baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        b_ok = mb % _data_size(mesh) == 0
        tok_extra = tokens.shape[2:]

        def resh(t, extra):
            t = t.reshape((n_micro, mb) + t.shape[1:])
            spec = P(None, baxes if b_ok else None,
                     *([None] * (1 + len(extra))))
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(mesh, spec))

        toks = resh(tokens, tok_extra)
        labs = resh(labels, tok_extra)
        msk = resh(mask, ())

        def loss_fn(tr, mtok, mlab, mmask):
            return model_lib.lm_loss(
                cfg, params, mtok, mlab, mmask, trainable=tr, k=k,
                remat=remat, remat_chunk=remat_chunk,
                num_groups=num_groups, act_fn=act_fn,
                inner_act_fn=inner_act_fn,
                moe_shard_fns=moe_shard_fns)

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def micro(carry, mbatch):
            g_acc, c_acc, l_acc = carry
            (loss, counts), grads = grad_fn(trainable, *mbatch)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                 g_acc, grads)
            c_acc = jax.tree.map(lambda a, c: a + c, c_acc, counts)
            return (g_acc, c_acc, l_acc + loss), None

        g0 = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32),
                          trainable)
        n_periods = cfg.num_layers // cfg.pattern_period
        c0 = {f"pos{pos}": jnp.zeros((n_periods, cfg.moe.num_experts),
                                     jnp.float32)
              for pos in range(cfg.pattern_period)
              if cfg.layer_is_moe(pos)}
        (grads, counts, loss_sum), _ = jax.lax.scan(
            micro, (g0, c0, jnp.zeros((), jnp.float32)), (toks, labs, msk))
        grads = jax.tree.map(lambda g: g / n_micro, grads)

        if not rescaler_trainable and "rescaler" in grads:
            grads = dict(grads)
            grads["rescaler"] = jax.tree.map(jnp.zeros_like,
                                             grads["rescaler"])

        new_trainable, new_opt = adam.update(
            grads, opt_state, trainable, lr=tc.learning_rate,
            beta1=tc.beta1, beta2=tc.beta2, eps=tc.eps,
            weight_decay=tc.weight_decay, grad_clip=tc.grad_clip)
        metrics = {"loss": loss_sum / n_micro, "counts": counts,
                   "tokens": jnp.asarray(np.prod(tokens.shape[:2]),
                                         jnp.float32)}
        return new_trainable, new_opt, metrics

    return step


def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                k: Optional[int] = None, tc: Optional[TrainConfig] = None,
                n_micro: Optional[int] = None, act_mode: Optional[str] = None,
                num_groups: Optional[int] = None, remat_chunk: Optional[int] = None,
                remat: bool = True) -> StepBundle:
    tc = tc or TrainConfig()
    knobs = choose_train_knobs(cfg, shape, mesh)
    n_micro = n_micro if n_micro is not None else knobs["n_micro"]
    act_mode = act_mode if act_mode is not None else knobs["act_mode"]
    remat_chunk = (remat_chunk if remat_chunk is not None
                   else knobs.get("remat_chunk", 0))
    num_groups = (num_groups if num_groups is not None else
                  choose_num_groups(cfg, shape.global_batch // n_micro,
                                    shape.seq_len, mesh))
    k = k if k is not None else (cfg.moe.top_k or None)

    a_params = specs_lib.abstract_params(cfg)
    a_train = specs_lib.abstract_trainable(cfg, k or 0)
    a_opt = specs_lib.abstract_opt_state(a_train)
    inputs = specs_lib.input_specs(cfg, shape)

    p_spec = shd.param_specs(cfg, a_params, mesh)
    t_spec = shd.trainable_specs(cfg, a_train, mesh)
    o_spec = adam.AdamState(step=P(), mu=t_spec,
                            nu=jax.tree.map(lambda s: s, t_spec))
    in_b = shd.batch_spec(shape.global_batch, mesh,
                          extra_dims=len(inputs["tokens"].shape) - 1)
    m_b = shd.batch_spec(shape.global_batch, mesh, extra_dims=1)

    step = make_train_step(cfg, mesh, k=k, tc=tc, n_micro=n_micro,
                           act_mode=act_mode, num_groups=num_groups,
                           remat=remat, remat_chunk=remat_chunk)
    jitted = jax.jit(
        step,
        in_shardings=(shd.shardings(mesh, p_spec),
                      shd.shardings(mesh, t_spec),
                      shd.shardings(mesh, o_spec),
                      NamedSharding(mesh, in_b), NamedSharding(mesh, in_b),
                      NamedSharding(mesh, m_b)),
        out_shardings=(shd.shardings(mesh, t_spec),
                       shd.shardings(mesh, o_spec),
                       None),
        donate_argnums=(1, 2),
    )
    args = (a_params, a_train, a_opt,
            inputs["tokens"], inputs["labels"], inputs["mask"])
    return StepBundle(
        name="train_step", fn=jitted, args=args,
        meta={"n_micro": n_micro, "act_mode": act_mode,
              "num_groups": num_groups, "k": k, "remat": remat,
              "remat_chunk": remat_chunk,
              "param_bytes": specs_lib.state_bytes(a_params),
              "trainable_bytes": specs_lib.state_bytes(a_train)})


# --------------------------------------------------------------------------
# prefill step
# --------------------------------------------------------------------------

def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                  k: Optional[int] = None,
                  num_groups: Optional[int] = None,
                  act_mode: str = "batch") -> StepBundle:
    k = k if k is not None else (cfg.moe.top_k or None)
    num_groups = (num_groups if num_groups is not None else
                  choose_num_groups(cfg, shape.global_batch, shape.seq_len,
                                    mesh))
    a_params = specs_lib.abstract_params(cfg)
    a_train = specs_lib.abstract_trainable(cfg, k or 0)
    inputs = specs_lib.input_specs(cfg, shape)
    a_cache = specs_lib.abstract_cache(cfg, shape.global_batch,
                                       shape.seq_len)

    p_spec = shd.param_specs(cfg, a_params, mesh)
    t_spec = shd.trainable_specs(cfg, a_train, mesh)
    c_spec = shd.cache_specs(cfg, a_cache, mesh, shape.global_batch)
    in_b = shd.batch_spec(shape.global_batch, mesh,
                          extra_dims=len(inputs["tokens"].shape) - 1)
    act_spec = shd.activation_spec(mesh, act_mode)

    def act_fn(h):
        return jax.lax.with_sharding_constraint(
            h, NamedSharding(mesh, act_spec))

    def step(params, trainable, tokens):
        return model_lib.prefill(cfg, params, tokens, trainable=trainable,
                                 k=k, num_groups=num_groups, act_fn=act_fn)

    jitted = jax.jit(
        step,
        in_shardings=(shd.shardings(mesh, p_spec),
                      shd.shardings(mesh, t_spec),
                      NamedSharding(mesh, in_b)),
        out_shardings=(None, shd.shardings(mesh, c_spec)),
    )
    return StepBundle(
        name="prefill_step", fn=jitted,
        args=(a_params, a_train, inputs["tokens"]),
        meta={"num_groups": num_groups, "k": k,
              "cache_bytes": specs_lib.state_bytes(a_cache),
              "param_bytes": specs_lib.state_bytes(a_params)})


# --------------------------------------------------------------------------
# serve (decode) step
# --------------------------------------------------------------------------

def build_serve(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                k: Optional[int] = None) -> StepBundle:
    """ONE new token with a ``seq_len``-deep KV/state cache."""
    k = k if k is not None else (cfg.moe.top_k or None)
    a_params = specs_lib.abstract_params(cfg)
    a_train = specs_lib.abstract_trainable(cfg, k or 0)
    inputs = specs_lib.input_specs(cfg, shape)
    a_cache = specs_lib.abstract_cache(cfg, shape.global_batch,
                                       shape.seq_len)

    p_spec = shd.param_specs(cfg, a_params, mesh)
    t_spec = shd.trainable_specs(cfg, a_train, mesh)
    c_spec = shd.cache_specs(cfg, a_cache, mesh, shape.global_batch)
    in_b = shd.batch_spec(shape.global_batch, mesh,
                          extra_dims=len(inputs["tokens"].shape) - 1)

    def step(params, trainable, cache, tokens, pos):
        return model_lib.decode_step(cfg, params, cache, tokens, pos,
                                     trainable=trainable, k=k)

    jitted = jax.jit(
        step,
        in_shardings=(shd.shardings(mesh, p_spec),
                      shd.shardings(mesh, t_spec),
                      shd.shardings(mesh, c_spec),
                      NamedSharding(mesh, in_b),
                      NamedSharding(mesh, P())),
        out_shardings=(None, shd.shardings(mesh, c_spec)),
        donate_argnums=(2,),            # cache updates in place
    )
    return StepBundle(
        name="serve_step", fn=jitted,
        args=(a_params, a_train, a_cache, inputs["tokens"], inputs["pos"]),
        meta={"k": k, "cache_bytes": specs_lib.state_bytes(a_cache),
              "param_bytes": specs_lib.state_bytes(a_params)})


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               **overrides) -> StepBundle:
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, **overrides)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, **overrides)
    return build_serve(cfg, shape, mesh, **overrides)
