"""LoRA adapter trees (the federated trainable surface).

The adapter tree mirrors the base parameter tree: every *targeted* 2-D weight
``W`` of shape ``(..., d_in, d_out)`` (leading axes = scan periods and/or
experts) gets a pair ``{"a": (..., d_in, r), "b": (..., r, d_out)}``.
``a`` is Gaussian-initialised, ``b`` zero-initialised (standard LoRA), so a
fresh adapter is an exact no-op.

FLAME specifics:
  * per-expert adapters ``A^j, B^j`` arise naturally because expert weights
    are stacked on an expert axis — the adapter inherits it;
  * the learnable rescaler ``s_i`` lives beside the adapters in the client's
    trainable tree (it is client-local: its value depends on the client's
    expert budget ``k_i`` and is NOT aggregated by the server);
  * ``truncate_rank`` / ``pad_rank`` implement the HLoRA baseline's
    rank-compressed distribution, ``merge_delta`` materialises ΔW = A·B for
    the FlexLoRA baseline's SVD redistribution.
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

# weight names eligible for adapters, per block sub-module
_TARGETS = {
    "attn": ("wq", "wk", "wv", "wo"),
    "ffn": ("w1", "w2", "w3"),
    "moe.experts": ("w1", "w2", "w3"),
    "moe.shared": ("w1", "w2", "w3"),
    "ssm": ("in_proj", "out_proj"),
}


def _module_enabled(cfg, module: str) -> bool:
    l = cfg.lora
    return {
        "attn": l.target_attn,
        "ffn": l.target_ffn,
        "moe.experts": l.target_expert,
        "moe.shared": l.target_ffn,
        "ssm": l.target_ssm,
    }[module]


def _init_pair(key, w: jnp.ndarray, rank: int) -> dict:
    """Adapter for a stacked weight (..., d_in, d_out)."""
    lead = w.shape[:-2]
    d_in, d_out = w.shape[-2:]
    a = (jax.random.normal(key, lead + (d_in, rank), jnp.float32)
         * (d_in ** -0.5)).astype(w.dtype)
    b = jnp.zeros(lead + (rank, d_out), w.dtype)
    return {"a": a, "b": b}


def init_lora(key, cfg, params: PyTree, rank: Optional[int] = None) -> PyTree:
    """Build the adapter tree for ``params`` (output of model.init_params)."""
    rank = rank if rank is not None else cfg.lora.rank
    blocks = {}
    for pos_name, block in params["blocks"].items():
        # zlib.crc32, not hash(): str hashes are salted per process, which
        # made adapter init — and borderline loss assertions — depend on
        # PYTHONHASHSEED
        kb = jax.random.fold_in(key, zlib.crc32(pos_name.encode()) % (2 ** 31))
        out: dict = {}
        for module, names in _TARGETS.items():
            if not _module_enabled(cfg, module):
                continue
            node = block
            okay = True
            for part in module.split("."):
                if not isinstance(node, dict) or part not in node:
                    okay = False
                    break
                node = node[part]
            if not okay:
                continue
            sub = {}
            for i, name in enumerate(names):
                if name in node:
                    sub[name] = _init_pair(jax.random.fold_in(kb, i),
                                           node[name], rank)
            if sub:
                cur = out
                parts = module.split(".")
                for part in parts[:-1]:
                    cur = cur.setdefault(part, {})
                cur[parts[-1]] = sub
        blocks[pos_name] = out
    return {"blocks": blocks}


def init_rescalers(cfg, k_client: int, mode: str = "learnable"
                   ) -> Optional[Dict[str, jnp.ndarray]]:
    """FLAME Eq. 5 rescaler s_i, one scalar per MoE layer.

    ``mode``: "learnable" (init at k/k_i, trained), "static" (k/k_i, frozen
    by exclusion from the gradient mask), "none".
    """
    if mode == "none" or not cfg.moe.enabled:
        return None
    P = cfg.pattern_period
    n_periods = cfg.num_layers // P
    init_val = cfg.moe.top_k / max(k_client, 1)
    out = {}
    for pos in range(P):
        if cfg.layer_is_moe(pos):
            out[f"pos{pos}"] = jnp.full((n_periods,), init_val, jnp.float32)
    return out or None


def make_trainable(lora: Optional[PyTree],
                   rescaler: Optional[PyTree]) -> PyTree:
    """Assemble the client's trainable tree in the form model.forward expects."""
    t: dict = {}
    if lora is not None:
        t["lora"] = lora
    if rescaler is not None:
        t["rescaler"] = rescaler
    return t


# --------------------------------------------------------------------------
# client-axis stacking (batched round engine substrate)
# --------------------------------------------------------------------------

def stack_adapters(trees: Sequence[PyTree]) -> PyTree:
    """Stack N structurally-identical adapter pytrees along a new leading
    *client* axis: every leaf ``(...)`` becomes ``(N, ...)``.

    This is the interchange format of the batched round engine: the server
    stacks the per-client distributed adapters, ``cohort_update`` vmaps the
    local-training program over axis 0, and ``flame_aggregate`` consumes the
    stacked result directly (no per-client host round-trips).  All trees must
    share structure and leaf shapes — the cohort builder guarantees this by
    grouping clients by budget (same rank ⇒ same adapter shapes)."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *trees)


def unstack_adapters(stacked: PyTree, n: Optional[int] = None
                     ) -> Tuple[PyTree, ...]:
    """Inverse of :func:`stack_adapters`: split leading axis 0 back into a
    tuple of ``n`` per-client pytrees (``n`` defaults to the leading dim of
    the first leaf)."""
    if n is None:
        n = jax.tree.leaves(stacked)[0].shape[0]
    return tuple(jax.tree.map(lambda l, i=i: l[i], stacked) for i in range(n))


# --------------------------------------------------------------------------
# rank surgery (HLoRA / FlexLoRA substrate)
# --------------------------------------------------------------------------

def _map_pairs(fn, lora: PyTree) -> PyTree:
    """Apply fn({"a","b"}) -> {"a","b"} to every adapter pair."""
    def rec(node):
        if isinstance(node, dict) and set(node) == {"a", "b"}:
            return fn(node)
        if isinstance(node, dict):
            return {k: rec(v) for k, v in node.items()}
        return node
    return rec(lora)


def truncate_rank(lora: PyTree, r_client: int) -> PyTree:
    """HLoRA distribution: keep the first ``r_client`` rank components."""
    def fn(pair):
        return {"a": pair["a"][..., :r_client],
                "b": pair["b"][..., :r_client, :]}
    return _map_pairs(fn, lora)


def pad_rank(lora: PyTree, r_full: int) -> PyTree:
    """Zero-pad a truncated adapter back to the server rank (for HLoRA's
    sparsity-weighted aggregation)."""
    def fn(pair):
        r = pair["a"].shape[-1]
        if r == r_full:
            return pair
        pa = jnp.zeros(pair["a"].shape[:-1] + (r_full - r,), pair["a"].dtype)
        pb = jnp.zeros(pair["b"].shape[:-2] + (r_full - r,) +
                       pair["b"].shape[-1:], pair["b"].dtype)
        return {"a": jnp.concatenate([pair["a"], pa], axis=-1),
                "b": jnp.concatenate([pair["b"], pb], axis=-2)}
    return _map_pairs(fn, lora)


def merge_delta(lora: PyTree, scale: float) -> PyTree:
    """ΔW = scale · A @ B per adapter (FlexLoRA aggregation operand)."""
    def fn(pair):
        delta = jnp.einsum("...ir,...ro->...io",
                           pair["a"].astype(jnp.float32),
                           pair["b"].astype(jnp.float32)) * scale
        return delta.astype(pair["a"].dtype)
    return _map_pairs(fn, lora)


def svd_refactor(delta: PyTree, rank: int, scale: float) -> PyTree:
    """FlexLoRA redistribution: ΔW --SVD--> (A, B) at ``rank``.

    ΔW = U S V^T;  A = U_r sqrt(S_r),  B = sqrt(S_r) V_r^T / scale so that
    scale·A·B reproduces the best rank-r approximation of ΔW.
    """
    def fn(dw):
        f32 = dw.astype(jnp.float32)
        u, s, vt = jnp.linalg.svd(f32, full_matrices=False)
        r = min(rank, s.shape[-1])
        sq = jnp.sqrt(s[..., :r])
        a = u[..., :, :r] * sq[..., None, :]
        b = sq[..., :, None] * vt[..., :r, :] / scale
        return {"a": a.astype(dw.dtype), "b": b.astype(dw.dtype)}

    def rec(node):
        if isinstance(node, dict):
            return {k: rec(v) for k, v in node.items()}
        return fn(node)
    return rec(delta)


# --------------------------------------------------------------------------
# merging adapters into base weights (deployment path)
# --------------------------------------------------------------------------

def merge_into_params(params: PyTree, lora: PyTree, scale: float) -> PyTree:
    """Return params with W := W + scale·A·B applied wherever adapters exist."""
    def rec(p_node, l_node):
        if not isinstance(l_node, dict):
            return p_node
        if set(l_node) == {"a", "b"}:
            delta = jnp.einsum("...ir,...ro->...io",
                               l_node["a"].astype(jnp.float32),
                               l_node["b"].astype(jnp.float32)) * scale
            return (p_node.astype(jnp.float32) + delta).astype(p_node.dtype)
        if isinstance(p_node, dict):
            return {k: rec(v, l_node[k]) if k in l_node else v
                    for k, v in p_node.items()}
        return p_node

    merged_blocks = {k: rec(params["blocks"][k], lora["blocks"].get(k, {}))
                     for k in params["blocks"]}
    out = dict(params)
    out["blocks"] = merged_blocks
    return out
