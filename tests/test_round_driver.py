"""Device round driver vs the host-loop oracle (ISSUE 10 acceptance).

``round_driver="device"`` folds multi-round federated training — per-round
participant subsampling, budget-cohort regrouping, streaming FLAME
aggregation, rescaler-bank scatter/gather — into one ``lax.scan`` program
per checkpoint segment.  The host loop (``round_driver="host"``) survives
as the reference oracle; this suite asserts the two produce the same
rounds: identical participant sets (shared RNG stream), per-client losses
and activation frequencies, the global adapter tree and every client's
local rescaler within tight fp32 tolerance — across cohort backends,
subsampling seeds and 1/2/4-cohort registry layouts, plus a 1024-client
randomized trace in the ``-m slow`` CI subset and a bit-exact streamed
checkpoint/resume roundtrip.
"""
import dataclasses
import itertools

import jax
import numpy as np
import pytest

from conftest import tiny_moe
from repro.configs.base import FederatedConfig, TrainConfig
from repro.data.synthetic import Corpus, DataConfig
from repro.federated.cohort import group_by_key
from repro.federated.simulation import build_experiment

CFG = tiny_moe()
TC = TrainConfig(batch_size=1, local_epochs=1)
DATA = DataConfig(vocab_size=CFG.vocab_size, n_examples=96, seq_len=32,
                  n_clusters=4)


def _experiment(driver, *, clients=4, rounds=3, participation=0.75,
                seed=0, backend="vmap", budget=None, tc=TC,
                checkpoint_every=1, shard_sizes=None):
    fed = FederatedConfig(num_clients=clients, rounds=rounds,
                          participation=participation, method="flame",
                          temperature=2, seed=seed, round_driver=driver,
                          cohort_backend=backend,
                          checkpoint_every=checkpoint_every)
    exp = build_experiment(CFG, fed=fed, tc=tc, data=DATA, budget=budget)
    if shard_sizes is not None:
        # pin shard sizes so plan batch sizes (and with them the cohort
        # count) are exactly what the test case wants
        for c, n in zip(exp.server.clients, itertools.cycle(shard_sizes)):
            s = c.shard
            assert len(s.tokens) >= n, (c.client_id, len(s.tokens), n)
            c.shard = Corpus(s.tokens[:n], s.labels[:n], s.mask[:n],
                             s.clusters[:n])
    return exp


def _assert_trees_close(a, b, rtol=2e-5, atol=1e-6):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


def _assert_same_rounds(host, device, rtol=2e-5, atol=1e-6):
    """Full oracle differential between two completed servers."""
    assert len(host.history) == len(device.history)
    for rh, rd in zip(host.history, device.history):
        assert rh.round_idx == rd.round_idx
        assert rh.participating == rd.participating
        np.testing.assert_allclose(rh.client_losses, rd.client_losses,
                                   rtol=1e-5, atol=1e-6, equal_nan=True)
        assert rd.activation_drift is not None
        for fh, fd in zip(rh.client_freqs, rd.client_freqs):
            assert set(fh) == set(fd)
            for pos in fh:
                np.testing.assert_allclose(fh[pos], fd[pos],
                                           rtol=1e-5, atol=1e-6)
    _assert_trees_close(host.global_lora, device.global_lora,
                        rtol=rtol, atol=atol)
    for ch, cd in zip(host.clients, device.clients):
        assert (ch.rescaler is None) == (cd.rescaler is None)
        if ch.rescaler is not None:
            _assert_trees_close(ch.rescaler, cd.rescaler,
                                rtol=rtol, atol=atol)


# --------------------------------------------------------------------------
# oracle differential: backends × seeds × cohort layouts
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend,seed,budget,shard_sizes,n_cohorts", [
    # one cohort: every client pinned to b1 (same k, same batch size)
    ("vmap", 0, "b1", None, 1),
    # two cohorts: round-robin β over tiny_moe's top_k=2 ⇒ k ∈ {2, 1}
    ("vmap", 0, None, None, 2),
    ("vmap", 3, None, None, 2),          # different subsampling stream
    ("map", 0, None, None, 2),           # lax.map cohort backend
])
def test_device_driver_matches_host_oracle(backend, seed, budget,
                                           shard_sizes, n_cohorts):
    kw = dict(seed=seed, backend=backend, budget=budget,
              shard_sizes=shard_sizes)
    host = _experiment("host", **kw)
    device = _experiment("device", **kw)
    order, _ = group_by_key(device.server.clients, TC,
                            rank_of=device.server._dist_rank)
    assert len(order) == n_cohorts
    host.server.run()
    device.server.run()
    _assert_same_rounds(host.server, device.server)


def test_device_driver_matches_host_four_cohorts():
    """Four shape-distinct cohorts: k ∈ {2, 1} crossed with pinned shard
    sizes that force step batch sizes {3, 1, 2} — the full static-key-set
    padding machinery (cohorts absent or short in a given round run
    exact-no-op slots)."""
    tc = dataclasses.replace(TC, batch_size=3)
    #        k:   2  1  1  1   (β1..β4 round-robin over 8 clients)
    sizes = [4, 4, 1, 2, 4, 4, 1, 2]
    kw = dict(clients=8, participation=0.6, tc=tc, shard_sizes=sizes)
    host = _experiment("host", **kw)
    device = _experiment("device", **kw)
    order, _ = group_by_key(device.server.clients, tc,
                            rank_of=device.server._dist_rank)
    assert len(order) == 4
    host.server.run()
    device.server.run()
    _assert_same_rounds(host.server, device.server)


def test_device_driver_multi_segment_checkpointing(tmp_path):
    """checkpoint_every=2 over 3 rounds ⇒ a 2-round program then a 1-round
    program, with a streamed checkpoint at each host sync point — still
    equal to the host oracle, and the final checkpoint records round 3."""
    from repro.checkpoint import io as ckpt_io
    path = str(tmp_path / "seg.npz")
    host = _experiment("host")
    device = _experiment("device", checkpoint_every=2)
    host.server.run()
    device.server.run(checkpoint_to=path)
    _assert_same_rounds(host.server, device.server)
    _, meta = ckpt_io.load(path)
    assert meta["round_idx"] == 3


# --------------------------------------------------------------------------
# streamed checkpoint -> resume: bit-exact continuation
# --------------------------------------------------------------------------

def test_device_resume_bit_matches_straight_run(tmp_path):
    """A device run checkpointed at round 2 and resumed (replayed
    subsampling RNG included) must reproduce rounds 2..3 of a straight
    device run BIT-exactly: with full participation both runs compile the
    same per-round program over the same operands, so there is no fp
    slack to hide behind."""
    path = str(tmp_path / "fed.npz")
    kw = dict(rounds=4, participation=1.0)

    straight = _experiment("device", **kw)
    straight.server.run(checkpoint_to=str(tmp_path / "s.npz"))

    first = _experiment("device", **kw)
    first.server.fed = dataclasses.replace(first.server.fed, rounds=2)
    first.server.run(checkpoint_to=path)

    resumed = _experiment("device", **kw)
    resumed.server.run(resume_from=path, checkpoint_to=path)
    assert [r.round_idx for r in resumed.server.history] == [2, 3]
    assert ([r.participating for r in resumed.server.history]
            == [r.participating for r in straight.server.history[2:]])
    for a, b in zip(jax.tree.leaves(straight.server.global_lora),
                    jax.tree.leaves(resumed.server.global_lora)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for ca, cb in zip(straight.server.clients, resumed.server.clients):
        for a, b in zip(jax.tree.leaves(ca.rescaler),
                        jax.tree.leaves(cb.rescaler)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for ra, rb in zip(straight.server.history[2:], resumed.server.history):
        np.testing.assert_array_equal(ra.client_losses, rb.client_losses)


def test_cross_driver_resume(tmp_path):
    """Checkpoints are driver-agnostic: a host-loop run checkpointed at
    round 2 resumes under the device driver and lands where a straight
    host run does (within fp tolerance)."""
    path = str(tmp_path / "fed.npz")
    kw = dict(rounds=4, participation=1.0)

    straight = _experiment("host", **kw)
    straight.server.run()

    first = _experiment("host", **kw)
    first.server.fed = dataclasses.replace(first.server.fed, rounds=2)
    first.server.run(checkpoint_to=path)

    resumed = _experiment("device", **kw)
    resumed.server.run(resume_from=path)
    assert ([r.participating for r in resumed.server.history]
            == [r.participating for r in straight.server.history[2:]])
    _assert_trees_close(straight.server.global_lora,
                        resumed.server.global_lora)


# --------------------------------------------------------------------------
# validation
# --------------------------------------------------------------------------

def test_device_driver_rejects_unsupported_configs():
    for kw, match in [
        (dict(method="hlora"), "flame"),
        (dict(round_engine="looped"), "batched"),
        (dict(checkpoint_every=0), "checkpoint_every"),
    ]:
        fed = FederatedConfig(num_clients=2, rounds=1, round_driver="device",
                              **kw)
        exp = build_experiment(CFG, fed=fed, tc=TC, data=DATA)
        with pytest.raises(ValueError, match=match):
            exp.server.run()


# --------------------------------------------------------------------------
# thousand-client randomized trace (CI slow subset)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_device_driver_1024_clients_randomized_trace():
    """1024 registered clients, 25% subsampling, two rounds: the scanned
    program must still match the host oracle — participant sets, losses,
    adapters — at a scale where the static key set, per-round padding
    slots and the rescaler bank all do real work."""
    data = DataConfig(vocab_size=CFG.vocab_size, n_examples=2048,
                      seq_len=32, n_clusters=4, seed=11)
    fed = FederatedConfig(num_clients=1024, rounds=2, participation=0.25,
                          method="flame", temperature=2, seed=11,
                          round_driver="host")
    host = build_experiment(CFG, fed=fed, tc=TC, data=data)
    device = build_experiment(
        CFG, fed=dataclasses.replace(fed, round_driver="device"),
        tc=TC, data=data)
    host.server.run()
    device.server.run()
    assert all(len(r.participating) == 256 for r in device.server.history)
    _assert_same_rounds(host.server, device.server, rtol=1e-4, atol=1e-5)
