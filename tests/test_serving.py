"""Serving subsystem tests: slotted decode parity with the naive loop,
per-slot adaptive k, pool/scheduler/workload mechanics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_moe
from repro.configs.base import KernelConfig
from repro.core import lora as lora_lib
from repro.kernels.ref import adaptive_topk_router_ref, topk_router_ref
from repro.models import model as M
from repro.serving import (Request, Scheduler, ServingEngine, SlotPool,
                           WorkloadConfig, make_trace, percentile)

CFG = tiny_moe()
PARAMS = M.init_params(jax.random.PRNGKey(0), CFG)
RNG = np.random.default_rng(0)
PROMPTS = RNG.integers(0, CFG.vocab_size, (4, 8)).astype(np.int32)


def naive_decode(cfg, params, prompts, new_tokens, k, *, trainable=None):
    """The examples/adaptive_serving.py-style full-batch greedy loop —
    the reference oracle the engine must reproduce token for token.
    Runs loss-free MoE dispatch (``no_drop``), the serving contract: a
    request's tokens must not depend on which rows share its batch."""
    L = prompts.shape[1]
    logits, cache = M.prefill(cfg, params, jnp.asarray(prompts), k=k,
                              trainable=trainable, cache_len=L + new_tokens,
                              no_drop=True)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(new_tokens - 1):
        logits, cache = M.decode_step(cfg, params, cache, tok, L + i, k=k,
                                      trainable=trainable, no_drop=True)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return np.asarray(jnp.concatenate(out, axis=1))


# ==========================================================================
# decode parity: slotted engine == naive full-batch loop
# ==========================================================================

@pytest.mark.parametrize("k", [1, 2])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_engine_matches_naive_decode(k, backend):
    cfg = CFG.replace(kernels=KernelConfig(backend=backend))
    new = 5
    ref = naive_decode(cfg, PARAMS, PROMPTS, new, k)
    eng = ServingEngine(cfg, PARAMS, num_slots=4, slot_len=8 + new,
                        slot_k=(k,) * 4)
    reqs = [Request(rid=i, prompt=PROMPTS[i], max_new_tokens=new, k=k)
            for i in range(4)]
    got = eng.run(reqs).tokens_by_rid()
    np.testing.assert_array_equal(ref, np.stack([got[i] for i in range(4)]))


def test_engine_mixed_slot_k_matches_per_request_naive():
    """Premium (k=2) and constrained (k=1) slots share one decode step;
    each request's tokens equal a solo naive run at its own budget."""
    new = 5
    eng = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=8 + new,
                        slot_k=(2, 2, 1, 1))
    reqs = [Request(rid=i, prompt=PROMPTS[i], max_new_tokens=new,
                    k=(2 if i < 2 else 1)) for i in range(4)]
    got = eng.run(reqs).tokens_by_rid()
    for i in range(4):
        kk = 2 if i < 2 else 1
        ref = naive_decode(CFG, PARAMS, PROMPTS[i:i + 1], new, kk)[0]
        np.testing.assert_array_equal(ref, got[i])


def test_engine_slot_reuse_and_queueing_parity():
    """4 requests of different lengths through 2 slots: admission waits,
    slots are recycled, and every request still decodes exactly as solo."""
    lens = (4, 8, 4, 6)
    prompts = [RNG.integers(0, CFG.vocab_size, (L,)).astype(np.int32)
               for L in lens]
    eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=16,
                        slot_k=(2, 2))
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    got = eng.run(reqs).tokens_by_rid()
    for i, p in enumerate(prompts):
        ref = naive_decode(CFG, PARAMS, p[None], 5, 2)[0]
        np.testing.assert_array_equal(ref, got[i])


def test_engine_per_slot_rescaler_matches_naive():
    """Tiered rescalers are stacked per slot; each slot's output matches a
    naive decode under that tier's scalar rescaler."""
    new = 4
    r_by_k = {k: lora_lib.init_rescalers(CFG, k) for k in (1, 2)}
    eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=8 + new,
                        slot_k=(2, 1), rescaler_by_k=r_by_k)
    reqs = [Request(rid=i, prompt=PROMPTS[i], max_new_tokens=new,
                    k=(2 if i == 0 else 1)) for i in range(2)]
    got = eng.run(reqs).tokens_by_rid()
    for i, kk in enumerate((2, 1)):
        ref = naive_decode(CFG, PARAMS, PROMPTS[i:i + 1], new, kk,
                           trainable={"rescaler": r_by_k[kk]})[0]
        np.testing.assert_array_equal(ref, got[i])


def test_engine_forced_mode_accumulates_nll():
    forced = RNG.integers(0, CFG.vocab_size, (4,)).astype(np.int32)
    eng = ServingEngine(CFG, PARAMS, num_slots=1, slot_len=16, slot_k=(2,))
    [comp] = eng.run([Request(rid=0, prompt=PROMPTS[0], max_new_tokens=4,
                              forced=forced)]).completions
    np.testing.assert_array_equal(comp.tokens, forced)
    assert comp.nll_sum > 0.0 and np.isfinite(comp.nll_sum)


def test_engine_forced_nll_deterministic_across_fresh_engines():
    """Teacher-forced NLL is an evaluation primitive: two freshly built
    engines fed the same seeded trace must agree bit for bit (no hidden
    state — pool history, compile order, RNG — may leak into the sum)."""
    rng = np.random.default_rng(1234)
    prompts = [rng.integers(0, CFG.vocab_size, (L,)).astype(np.int32)
               for L in (4, 8, 6)]
    forced = [rng.integers(0, CFG.vocab_size, (n,)).astype(np.int32)
              for n in (5, 3, 4)]

    def run_once():
        eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=16,
                            slot_k=(2, 2), seed=7)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=len(f), forced=f)
                for i, (p, f) in enumerate(zip(prompts, forced))]
        return {c.rid: c.nll_sum for c in eng.run(reqs).completions}

    a, b = run_once(), run_once()
    assert set(a) == {0, 1, 2}
    for rid in a:
        assert a[rid] == b[rid]                     # bit-identical
        assert np.isfinite(a[rid]) and a[rid] > 0.0


def test_moe_slot_mask_rows_cannot_steal_capacity():
    """Masked (free-slot / pad) rows must not occupy expert-queue
    positions: the unmasked rows' outputs equal running those rows alone."""
    from repro.models import moe_layer
    key = jax.random.PRNGKey(1)
    p = moe_layer.init_moe(key, CFG)
    x = jax.random.normal(jax.random.fold_in(key, 1), (24, 1, CFG.d_model),
                          jnp.float32)
    mask = jnp.asarray([0.0] * 8 + [1.0] * 16)
    out_m, aux_m = moe_layer.apply_moe(p, CFG, x, k=1, slot_mask=mask)
    out_solo, aux_solo = moe_layer.apply_moe(p, CFG, x[8:], k=1)
    # identical capacity (static C covers the full row count in both) and
    # identical relative token order => exact equality
    np.testing.assert_allclose(np.asarray(out_m[8:]), np.asarray(out_solo))
    np.testing.assert_allclose(np.asarray(out_m[:8]), 0.0)   # routed nowhere
    np.testing.assert_allclose(np.asarray(aux_m.activation_counts),
                               np.asarray(aux_solo.activation_counts))


def test_engine_results_independent_of_pool_history():
    """A slot pool that served earlier traffic (stale cache + last tokens
    in released slots) must produce byte-identical results to a fresh
    engine — free slots are masked out of routing, not just ignored."""
    new = 4
    first = [Request(rid=100 + i, prompt=PROMPTS[(i + 1) % 4],
                     max_new_tokens=new) for i in range(4)]
    reqs = [Request(rid=0, prompt=PROMPTS[0], max_new_tokens=new)]

    used = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16)
    used.run(first)                                 # dirty the pool
    got_used = used.run(reqs).tokens_by_rid()[0]

    fresh = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16)
    got_fresh = fresh.run(reqs).tokens_by_rid()[0]
    np.testing.assert_array_equal(got_used, got_fresh)


# ==========================================================================
# adaptive router reference
# ==========================================================================

def test_adaptive_router_uniform_equals_static():
    logits = jnp.asarray(RNG.normal(size=(12, 6)), jnp.float32)
    for k in (1, 2, 3):
        w0, m0, c0 = topk_router_ref(logits, k)
        w1, m1, c1 = adaptive_topk_router_ref(
            logits, jnp.full((12,), k, jnp.int32), max_k=3)
        np.testing.assert_allclose(np.asarray(w0), np.asarray(w1))
        np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
        np.testing.assert_allclose(np.asarray(c0), np.asarray(c1))


def test_adaptive_router_per_token_budgets():
    logits = jnp.asarray(RNG.normal(size=(6, 8)), jnp.float32)
    k_tok = jnp.asarray([1, 2, 3, 1, 2, 3], jnp.int32)
    w, m, counts = adaptive_topk_router_ref(logits, k_tok, max_k=3)
    # each token activates exactly its budget, weights renormalised
    np.testing.assert_array_equal(np.asarray(m.sum(-1)), np.asarray(k_tok))
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-6)
    assert float(counts.sum()) == float(k_tok.sum())
    # each token's row equals the static router at its own k
    for t in range(6):
        w_t, m_t, _ = topk_router_ref(logits[t:t + 1], int(k_tok[t]))
        np.testing.assert_allclose(np.asarray(w[t]), np.asarray(w_t[0]))


# ==========================================================================
# pool / scheduler / workload mechanics
# ==========================================================================

def test_slot_pool_allocate_release_write():
    pool = SlotPool(CFG, num_slots=3, slot_len=8)
    assert pool.free_slots == [0, 1, 2]
    s0 = pool.allocate()
    pool.take(2)
    assert pool.free_slots == [1]
    # install a 2-row prefilled cache into slots (0, 2)
    _, piece = M.prefill(CFG, PARAMS, jnp.asarray(PROMPTS[:2, :4]), k=2,
                         cache_len=8)
    pool.write([s0, 2], piece, [4, 4])
    got = np.asarray(pool.cache["pos0"]["attn"]["k"])
    want = np.asarray(piece["pos0"]["attn"]["k"])
    np.testing.assert_allclose(got[:, 0], want[:, 0])
    np.testing.assert_allclose(got[:, 2], want[:, 1])
    assert got[:, 1].max() == 0.0          # untouched slot stays zeroed
    assert list(pool.cache_pos) == [4, 0, 4]
    pool.advance([0])
    assert list(pool.cache_pos) == [5, 0, 4]
    pool.release(0)
    assert pool.cache_pos[0] == 0 and 0 in pool.free_slots
    with pytest.raises(AssertionError):
        pool.release(0)                    # double free


def test_scheduler_can_admit_blocks_own_tier_only():
    """A request rejected by the resource predicate (no blocks for its
    projected need) head-of-line-blocks ITS tier only: later same-tier
    requests cannot leapfrog it, other tiers admit normally."""
    sched = Scheduler()
    mk = lambda rid, k, big: Request(
        rid=rid, prompt=np.zeros(16 if big else 2, np.int32),
        max_new_tokens=1, k=k)
    # rid0: big premium (rejected); rid1 small premium (must NOT leapfrog);
    # rid2 small economy (different tier, must admit)
    for req in (mk(0, 2, True), mk(1, 2, False), mk(2, 1, False)):
        sched.add(req)
    out = sched.admit([0, 1, 2, 3], (2, 2, 1, 1),
                      can_admit=lambda r, s: r.prompt_len < 10)
    assert [(r.rid, s) for r, s in out] == [(2, 2)]
    assert [r.rid for r in sched.queue] == [0, 1]
    # blocks freed up: FIFO order resumes, big premium goes first
    out = sched.admit([0, 1, 3], (2, 2, 1, 1), can_admit=lambda r, s: True)
    assert [(r.rid, s) for r, s in out] == [(0, 0), (1, 1)]


def test_scheduler_wildcards_respect_blocked_tiers():
    """k=None (take-any-slot) requests must not punch through the
    head-of-line barrier: they cannot take a blocked tier's slots, and a
    blocked wildcard — which could have sat anywhere — ends the round."""
    mk = lambda rid, k, big=False: Request(
        rid=rid, prompt=np.zeros(16 if big else 2, np.int32),
        max_new_tokens=1, k=k)
    sched = Scheduler()
    # rid0: big premium, rejected -> tier 2 blocked; rid1: wildcard must
    # NOT grab the freed tier-2 slot (it would book rid0's blocks), but
    # may take a tier-1 slot
    for req in (mk(0, 2, big=True), mk(1, None)):
        sched.add(req)
    out = sched.admit([0, 1, 2], (2, 2, 1),
                      can_admit=lambda r, s: r.prompt_len < 10)
    assert [(r.rid, s) for r, s in out] == [(1, 2)]
    assert [r.rid for r in sched.queue] == [0]

    # a blocked wildcard ends the round: nothing may leapfrog a request
    # that could have occupied any slot
    sched = Scheduler()
    for req in (mk(0, None, big=True), mk(1, 1), mk(2, 2)):
        sched.add(req)
    out = sched.admit([0, 1], (2, 1), can_admit=lambda r, s: r.prompt_len < 10)
    assert out == []
    assert [r.rid for r in sched.queue] == [0, 1, 2]


def test_premium_flood_cannot_starve_economy_admission():
    """Adversarial trace: a flood of long premium requests saturates the
    block pool before short economy requests arrive.  Economy admission
    must proceed as soon as its tier slots + blocks allow — overlapping
    the flood, not serialised after it — and every request completes."""
    prem = [Request(rid=i, prompt=PROMPTS[i % 4], max_new_tokens=6, k=2)
            for i in range(8)]
    econ = [Request(rid=100 + i, prompt=PROMPTS[i % 4][:4],
                    max_new_tokens=2, k=1) for i in range(6)]
    eng = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                        slot_k=(2, 2, 1, 1), kv_layout="paged",
                        block_size=4, num_blocks=12)
    rep = eng.run(prem + econ)
    assert len(rep.completions) == 14
    last_prem_done = max(c.finished for c in rep.completions if c.rid < 100)
    econ_admitted = [c.admitted for c in rep.completions if c.rid >= 100]
    assert max(econ_admitted) < last_prem_done, \
        "economy requests were starved until the premium flood drained"
    # and the results match an unconstrained slotted run exactly
    ref = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                        slot_k=(2, 2, 1, 1),
                        kv_layout="slotted").run(prem + econ)
    want = ref.tokens_by_rid()
    for rid, toks in rep.tokens_by_rid().items():
        np.testing.assert_array_equal(toks, want[rid])


def test_big_request_not_starved_by_economy_stream():
    """The dual of the premium-flood test: a block-hungry premium request
    queued behind a stream of small economy requests must not wait until
    the whole stream drains — freed blocks are escrowed for the oldest
    waiter, so it admits ahead of younger economy arrivals."""
    econ = [Request(rid=i, prompt=PROMPTS[i % 4][:4], max_new_tokens=4,
                    k=1) for i in range(6)]
    big = Request(rid=50, prompt=np.concatenate([PROMPTS[0], PROMPTS[1]]),
                  max_new_tokens=8, k=2)        # 16 + 8 - 1 => 6 blocks
    reqs = econ[:2] + [big] + econ[2:]          # big is 3rd in FIFO order
    eng = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=24,
                        slot_k=(2, 1, 1, 1), kv_layout="paged",
                        block_size=4, num_blocks=8)
    rep = eng.run(reqs)
    assert len(rep.completions) == 7
    by_rid = {c.rid: c for c in rep.completions}
    # without escrow the economy stream re-books every freed block and
    # the big request admits dead last
    assert all(by_rid[50].admitted < by_rid[e.rid].admitted
               for e in econ[2:]), \
        "big premium request was starved behind younger economy requests"
    eng.pool.check_invariants()


def test_all_long_trace_drains_through_minimal_block_pool():
    """All-long-request trace through a pool holding ~one request's blocks
    at a time: requests serialise on block availability without deadlock
    or starvation, and tokens still match the unconstrained engine."""
    reqs = [Request(rid=i, prompt=PROMPTS[i % 4], max_new_tokens=6, k=2)
            for i in range(5)]
    # 8 + 6 - 1 = 13 positions => 4 blocks of 4; 5 usable blocks => one
    # request in flight (plus a head start on the next one's prompt)
    eng = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                        slot_k=(2,) * 4, kv_layout="paged",
                        block_size=4, num_blocks=5)
    rep = eng.run(reqs)
    assert len(rep.completions) == 5
    ref = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                        slot_k=(2,) * 4, kv_layout="slotted").run(reqs)
    want = ref.tokens_by_rid()
    for rid, toks in rep.tokens_by_rid().items():
        np.testing.assert_array_equal(toks, want[rid])
    assert eng.pool.blocks_in_use == 0
    eng.pool.check_invariants()


def test_scheduler_fifo_per_tier():
    sched = Scheduler()
    mk = lambda rid, k: Request(rid=rid, prompt=np.zeros(4, np.int32),
                                max_new_tokens=1, k=k)
    for rid, k in ((0, 1), (1, 2), (2, 1), (3, None)):
        sched.add(mk(rid, k))
    # slots: 0 -> k=2, 1 -> k=1.  FIFO per tier: rid0 takes the k=1 slot,
    # rid1 the k=2 slot; rid2 (k=1, no slot left) must NOT block rid3
    out = sched.admit([0, 1], (2, 1))
    assert [(r.rid, s) for r, s in out] == [(0, 1), (1, 0)]
    assert [r.rid for r in sched.queue] == [2, 3]
    out = sched.admit([0], (2, 1))
    assert [(r.rid, s) for r, s in out] == [(3, 0)]   # rid2 still waiting
    assert [r.rid for r in sched.queue] == [2]


def test_workload_trace_deterministic_and_mixed():
    wl = WorkloadConfig(n_requests=64, rate=100.0, prompt_lens=(4, 8),
                        new_tokens=(2, 4), tier_mix=((2, 0.5), (1, 0.5)),
                        vocab_size=CFG.vocab_size, seed=3)
    a, b = make_trace(wl), make_trace(wl)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[0] == 0.0 and arr[-1] > 0.0
    ks = {r.k for r in a}
    assert ks == {1, 2}
    assert all(r.prompt_len in (4, 8) for r in a)
    assert percentile([1.0, 2.0, 3.0], 50) == 2.0
    assert np.isnan(percentile([], 95))


def test_engine_rejects_oversized_prompt_upfront():
    """A prompt with no room for a generated token fails BEFORE any work
    starts — a malformed trace must not abort a run mid-flight."""
    eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=8)
    good = Request(rid=0, prompt=PROMPTS[0, :4], max_new_tokens=2)
    bad = Request(rid=1, prompt=PROMPTS[1], max_new_tokens=2)   # len 8
    with pytest.raises(ValueError, match=r"requests \[1\]"):
        eng.run([good, bad])
    assert eng.n_active == 0                    # nothing was admitted
    [comp] = eng.run([good]).completions        # engine still usable
    assert comp.rid == 0


def test_engine_rejects_unservable_tier():
    eng = ServingEngine(CFG, PARAMS, num_slots=1, slot_len=16, slot_k=(2,))
    with pytest.raises(RuntimeError, match="match no slot tier"):
        eng.run([Request(rid=0, prompt=PROMPTS[0], max_new_tokens=2, k=1)])


def test_engine_serves_zero_max_new_on_both_layouts():
    """max_new_tokens=0 still emits the prefill token; the paged block
    projection must floor at the prompt length (prefill installs all L
    positions) or reservation runs out mid-install."""
    for layout in ("paged", "slotted"):
        eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=16,
                            slot_k=(2, 2), kv_layout=layout, block_size=4)
        [comp] = eng.run([Request(rid=0, prompt=PROMPTS[0, :5],
                                  max_new_tokens=0)]).completions
        assert comp.n_generated == 1 and not comp.truncated


def test_engine_truncates_at_slot_capacity():
    eng = ServingEngine(CFG, PARAMS, num_slots=1, slot_len=10, slot_k=(2,))
    [comp] = eng.run([Request(rid=0, prompt=PROMPTS[0, :8],
                              max_new_tokens=64)]).completions
    # prefill token + one decode write per free cache position (8, 9)
    assert comp.truncated and comp.n_generated == 3


# ==========================================================================
# ragged-dispatch serving regression: the PR 4 pool-history / admission-
# schedule invariance traces, end to end on the paged layout, with the
# per-row prefill-group workaround REMOVED (ragged prefill routes one
# group per bucket — row isolation comes from the dispatch itself)
# ==========================================================================

def _ragged_trace(n=8):
    rng = np.random.default_rng(17)
    reqs = []
    for i in range(n):
        L = int(rng.choice((4, 8)))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, CFG.vocab_size, (L,))
            .astype(np.int32), max_new_tokens=int(rng.choice((3, 5))),
            k=int(rng.choice((1, 2)))))
    return reqs


def test_ragged_engine_admission_schedule_invariance():
    """The same trace through paged ragged engines with different pool
    sizes (different admission schedules, different prefill co-batching,
    different decode co-residents) and through the slotted layout: every
    configuration produces identical tokens, all equal to the solo naive
    oracle at each request's own budget."""
    reqs = _ragged_trace()
    assert all(e.dispatch == "ragged" for e in [
        ServingEngine(CFG, PARAMS, num_slots=1, slot_len=16, slot_k=(2,))])
    outs = {}
    for name, kw in (
            ("paged_small", dict(num_slots=2, slot_k=(2, 1),
                                 kv_layout="paged", block_size=4)),
            ("paged_large", dict(num_slots=6, slot_k=(2,) * 3 + (1,) * 3,
                                 kv_layout="paged", block_size=4)),
            ("slotted", dict(num_slots=4, slot_k=(2, 2, 1, 1),
                             kv_layout="slotted"))):
        eng = ServingEngine(CFG, PARAMS, slot_len=16, **kw)
        outs[name] = eng.run(reqs).tokens_by_rid()
    for name in ("paged_large", "slotted"):
        assert outs[name].keys() == outs["paged_small"].keys()
        for rid in outs[name]:
            np.testing.assert_array_equal(outs[name][rid],
                                          outs["paged_small"][rid])
    for r in reqs:
        ref = naive_decode(CFG, PARAMS, r.prompt[None],
                           r.max_new_tokens, r.k)[0]
        np.testing.assert_array_equal(ref, outs["paged_small"][r.rid])


def test_ragged_engine_pool_history_and_block_permutation_invariance():
    """Paged ragged engine: a pool dirtied by earlier traffic, with its
    free-block order permuted between runs, produces byte-identical
    results to a fresh engine — batching state cannot change results."""
    reqs = _ragged_trace(6)
    eng = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                        slot_k=(2, 2, 1, 1), kv_layout="paged",
                        block_size=4)
    base = eng.run(reqs).tokens_by_rid()
    for seed in (1, 2):
        eng.pool.permute_free(seed)
        got = eng.run(reqs).tokens_by_rid()      # dirty pool + permuted
        assert base.keys() == got.keys()
        for rid in base:
            np.testing.assert_array_equal(base[rid], got[rid])
    eng.pool.check_invariants()
    fresh = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                          slot_k=(2, 2, 1, 1), kv_layout="paged",
                          block_size=4)
    got = fresh.run(reqs).tokens_by_rid()
    for rid in base:
        np.testing.assert_array_equal(base[rid], got[rid])


def test_ragged_engine_teacher_forced_nll_matches_dense():
    """Teacher-forced NLL accounting is dispatch-invariant: the ragged
    engine's per-request NLL equals the dense no-drop engine's."""
    rng = np.random.default_rng(23)
    reqs = [Request(rid=i, prompt=PROMPTS[i], max_new_tokens=4,
                    forced=rng.integers(0, CFG.vocab_size, (4,))
                    .astype(np.int32)) for i in range(3)]
    kw = dict(num_slots=3, slot_len=16, slot_k=(2,) * 3)
    rag = ServingEngine(CFG, PARAMS, dispatch="ragged", **kw).run(reqs)
    den = ServingEngine(CFG, PARAMS, dispatch="dense", **kw).run(reqs)
    nll_r = {c.rid: c.nll_sum for c in rag.completions}
    nll_d = {c.rid: c.nll_sum for c in den.completions}
    for rid in nll_r:
        np.testing.assert_allclose(nll_r[rid], nll_d[rid], rtol=1e-5)


def test_engine_report_summary_keys():
    eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=16)
    reqs = [Request(rid=i, prompt=PROMPTS[i], max_new_tokens=3)
            for i in range(2)]
    s = eng.run(reqs).summary()
    assert s["n_requests"] == 2 and s["gen_tokens"] == 6
    assert s["requests_per_s"] > 0 and s["ttft_p95_ms"] >= 0
