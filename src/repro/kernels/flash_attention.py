"""Pallas TPU flash attention (causal / sliding-window, GQA-aware).

Online-softmax blockwise attention: grid = (batch·heads, q_blocks,
kv_blocks), kv innermost so the (m, l, acc) running statistics live in VMEM
scratch across kv iterations.  Block shapes are MXU-aligned (multiples of
128 on the matmul dims; fp32 accumulation).

GQA is handled in the BlockSpec index maps — K/V blocks are fetched from the
shared kv head ``h // rep`` so query-head replication never touches HBM.

This kernel is the TPU *target*; CPU correctness is validated with
``interpret=True`` against ``ref.flash_attention_ref`` over shape/dtype
sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # skip fully-masked blocks (strictly above the diagonal / out of window)
    if causal:
        run = k_start <= q_start + block_q - 1
        if window > 0:
            # block must intersect [qpos - window + 1, qpos] for some qpos
            run = run & (k_start + block_k - 1 > q_start - window)
    else:
        run = jnp.asarray(True)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        if causal:
            valid = kpos <= qpos
            if window > 0:
                valid &= kpos > qpos - window
            s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, S, D); k, v: (B, KV, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    rep = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq = S // block_q
    nk = S // block_k
    scale = D ** -0.5

    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * KV, S, D)
    vf = v.reshape(B * KV, S, D)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        b = bh // H
        h = bh % H
        return (b * KV + h // rep, ik, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),   # l (running sum)
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
