"""Figure 2 — expert-activation imbalance across clients.

Reproduces the heatmap *statistics*: per-(client, expert) activation
frequencies after one round, under both heterogeneity levels.  The paper's
claim: activation is highly imbalanced, and lower α (more skew) increases
the cross-client variance — the phenomenon motivating Eq. 6."""
from __future__ import annotations

import numpy as np

from repro.core.aggregation import stack_client_frequencies

from .common import emit, run_setting


def run() -> None:
    rows = []
    cvars = {}
    for alpha in (5.0, 0.5):
        r = run_setting("flame", alpha=alpha, clients=4, rounds=1)
        freqs = r["exp"].server.history[0].client_freqs
        stacked = stack_client_frequencies(freqs)       # {pos: (n, P, E)}
        f = np.concatenate([np.asarray(v).reshape(len(freqs), -1)
                            for v in stacked.values()], axis=1)  # (n, L·E)
        cvars[alpha] = float(np.var(f, axis=0).mean())
        rows.append({
            "alpha": alpha,
            "mean_freq": float(f.mean()),
            "min_freq": float(f.min()),
            "max_freq": float(f.max()),
            "cross_client_var": cvars[alpha],
            "frac_cold_experts": float((f < 0.01).mean()),
        })
    emit("fig2_activation", rows,
         ["alpha", "mean_freq", "min_freq", "max_freq",
          "cross_client_var", "frac_cold_experts"])
    print(f"# higher heterogeneity (alpha 0.5) raises cross-client "
          f"activation variance: {cvars[5.0]:.5f} -> {cvars[0.5]:.5f} "
          f"({'CONFIRMS' if cvars[0.5] > cvars[5.0] else 'REFUTES'} Fig. 2)")


if __name__ == "__main__":
    run()
