"""Client-side local training (one federated participant).

A client owns a data shard, a resource budget (k_i experts for FLAME /
LoRA rank r_i for the compression baselines), and runs ``local_epochs`` of
Adam over its shard each round (paper A2.2: Adam, lr 1.5e-4, batch 16,
1 local epoch).

The local-training program is factored so the server can run it two ways:

* **looped** (reference oracle): ``local_train`` — one jitted
  ``local_update`` per client, exactly the paper's sequential simulation;
* **batched** (round engine): ``cohort_update`` — the *same* pure
  ``local_update`` program vmapped (or ``lax.map``-ed) over a leading
  client axis, so a whole shape-homogeneous cohort trains in one compiled
  computation.  Per-client activation counts accumulate inside the scan
  carry, so the stacked counts feed ``flame_aggregate`` without host
  round-trips.

Both paths consume the same deterministic :class:`BatchPlan` (seeded by
``(round_seed, client_id)``), which is what makes them numerically
equivalent — verified in tests/test_round_engine.py.

The jit'd train step returns per-expert activation counts; accumulated
counts become the activation frequency a_i^j / S_i that the server's
activation-aware aggregation consumes (token-level frequency — see
core/aggregation.py docstring for the edge-case analysis).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, TrainConfig
from ..core import lora as lora_lib
from ..data.synthetic import Corpus, batches
from ..models import model as model_lib
from ..optim import adam

PyTree = Any


@dataclass
class ClientState:
    client_id: int
    shard: Corpus
    k: int                        # FLAME expert budget k_i
    rank: int                     # LoRA rank (baselines truncate this)
    rescaler: Optional[PyTree]    # client-local s_i (persists across rounds)
    rescaler_mode: str = "learnable"
    # β-tier label ("b1".."b4") — informational; cohort grouping keys on
    # the derived shape tuple (k, rank, ...), see federated/cohort.py
    budget: str = ""

    @property
    def dataset_size(self) -> int:
        return len(self.shard.tokens)


# ==========================================================================
# batch plans: the deterministic per-round data schedule
# ==========================================================================

@dataclass
class BatchPlan:
    """Materialised minibatch schedule for one client × one round.

    ``tokens``/``labels``: (n_steps, B, S[, K]); ``mask``: (n_steps, B, S);
    ``valid``: (n_steps,) — 1.0 for real steps, 0.0 for padding steps added
    by :func:`stack_plans` so a cohort shares one static step count.  A
    padding step re-runs step 0's batch but its update, counts and loss are
    all discarded inside ``local_update`` (exact no-op, including the Adam
    step counter)."""
    tokens: np.ndarray
    labels: np.ndarray
    mask: np.ndarray
    valid: np.ndarray

    @property
    def n_steps(self) -> int:
        return self.tokens.shape[0]

    @property
    def batch_size(self) -> int:
        return self.tokens.shape[1]


def plan_batch_size(client: ClientState, tc: TrainConfig) -> int:
    """Per-client step batch size: ``tc.batch_size``, shrunk so tiny shards
    (Dirichlet tail clients) still get >= 1 batch per epoch."""
    return max(1, min(tc.batch_size, len(client.shard.tokens)))


def make_batch_plan(client: ClientState, tc: TrainConfig,
                    round_seed: int) -> BatchPlan:
    """Draw the client's round schedule: ``local_epochs`` shuffled epochs
    over its shard, seeded by (round_seed, client_id) so looped and batched
    execution consume byte-identical data."""
    rng = np.random.default_rng(round_seed * 10_007 + client.client_id)
    bs = plan_batch_size(client, tc)
    toks, labs, msks = [], [], []
    for _ in range(tc.local_epochs):
        for tokens, labels, mask in batches(client.shard, bs, rng=rng):
            toks.append(tokens)
            labs.append(labels)
            msks.append(mask)
    if not toks:
        # zero-step client (empty shard / local_epochs=0): one all-invalid
        # dummy step so the scan has static length >= 1; local_update
        # discards it entirely — zero counts, zero tokens, nan mean loss
        # (the aggregation-side zero-activation edge case)
        shape = (1, bs) + client.shard.tokens.shape[1:]
        return BatchPlan(tokens=np.zeros(shape, np.int32),
                         labels=np.zeros(shape, np.int32),
                         mask=np.zeros((1, bs) + client.shard.mask.shape[1:],
                                       np.float32),
                         valid=np.zeros((1,), np.float32))
    return BatchPlan(tokens=np.stack(toks), labels=np.stack(labs),
                     mask=np.stack(msks),
                     valid=np.ones((len(toks),), np.float32))


def pad_plan(plan: BatchPlan, n_steps: int) -> BatchPlan:
    """Pad a plan to ``n_steps`` with repeats of step 0 flagged invalid."""
    extra = n_steps - plan.n_steps
    if extra <= 0:
        return plan

    def rep(x):
        return np.concatenate([x, np.repeat(x[:1], extra, axis=0)])

    return BatchPlan(tokens=rep(plan.tokens), labels=rep(plan.labels),
                     mask=rep(plan.mask),
                     valid=np.concatenate([plan.valid,
                                           np.zeros((extra,), np.float32)]))


def empty_plan(like: BatchPlan) -> BatchPlan:
    """All-invalid plan with ``like``'s shapes — a padding *slot* in a
    device-driver cohort (a cohort position with no real participant this
    round).  Every step is invalid, so ``local_update`` returns its inputs
    untouched with zero counts/tokens, and the slot's aggregation weight
    (dataset size 0) excludes it from the global average entirely."""
    return BatchPlan(tokens=np.zeros_like(like.tokens),
                     labels=np.zeros_like(like.labels),
                     mask=np.zeros_like(like.mask),
                     valid=np.zeros_like(like.valid))


def stack_plans(plans: Sequence[BatchPlan]) -> BatchPlan:
    """Stack per-client plans to (C, n_steps, B, S...) for ``cohort_update``,
    padding shorter plans (smaller shards) with invalid no-op steps.  All
    plans must share a batch size — the cohort builder groups by it."""
    sizes = {p.batch_size for p in plans}
    if len(sizes) > 1:
        raise ValueError(f"cannot stack plans with mixed batch sizes {sizes}"
                         " — cohort grouping should have split these")
    n = max(p.n_steps for p in plans)
    padded = [pad_plan(p, n) for p in plans]
    return BatchPlan(tokens=np.stack([p.tokens for p in padded]),
                     labels=np.stack([p.labels for p in padded]),
                     mask=np.stack([p.mask for p in padded]),
                     valid=np.stack([p.valid for p in padded]))


# ==========================================================================
# the pure local-training program (one client)
# ==========================================================================

@partial(jax.jit, static_argnames=("cfg", "k", "tc", "rescaler_trainable"))
def _train_step(cfg: ModelConfig, params, trainable, opt_state, tokens,
                labels, mask, *, k: int, tc: TrainConfig,
                rescaler_trainable: bool):
    def loss_fn(tr):
        loss, counts = model_lib.lm_loss(cfg, params, tokens, labels, mask,
                                         trainable=tr, k=k)
        return loss, counts

    (loss, counts), grads = jax.value_and_grad(loss_fn, has_aux=True)(trainable)
    if not rescaler_trainable and "rescaler" in grads:
        grads = dict(grads)
        grads["rescaler"] = jax.tree.map(jnp.zeros_like, grads["rescaler"])
    new_trainable, new_opt = adam.update(
        grads, opt_state, trainable, lr=tc.learning_rate, beta1=tc.beta1,
        beta2=tc.beta2, eps=tc.eps, weight_decay=tc.weight_decay,
        grad_clip=tc.grad_clip)
    return new_trainable, new_opt, loss, counts


def _count_zeros(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Zeroed activation-count accumulator {pos: (n_periods, E)} matching
    the counts pytree that model.lm_loss returns for MoE positions."""
    P = cfg.pattern_period
    n_periods = cfg.num_layers // P
    return {f"pos{p}": jnp.zeros((n_periods, cfg.moe.num_experts),
                                 jnp.float32)
            for p in range(P) if cfg.layer_is_moe(p)}


def local_update(cfg: ModelConfig, params: PyTree, trainable: PyTree,
                 tokens, labels, mask, valid, *, k: int, tc: TrainConfig,
                 rescaler_trainable: bool):
    """One client's full local round as a pure scan — the vmappable unit.

    ``tokens``/``labels``: (n_steps, B, S[, K]); ``mask``: (n_steps, B, S);
    ``valid``: (n_steps,).  Invalid (padding) steps compute but discard
    their update — trainable, Adam state (step counter included), counts
    and loss all keep their prior value, so padded execution is bit-wise
    equivalent to running fewer steps.

    Returns ``(trainable, count_sums, token_count, loss_sum, n_valid)``
    where ``count_sums`` is {pos: (n_periods, E)} summed over valid steps
    and ``token_count`` is the number of tokens processed (the S_i unit of
    the activation frequency a_i^j / S_i).
    """
    opt_state = adam.init(trainable)
    tokens_per_step = float(np.prod(tokens.shape[1:3]))      # B * S
    zero = jnp.zeros((), jnp.float32)
    carry0 = (trainable, opt_state, _count_zeros(cfg), zero, zero, zero)

    def body(carry, xs):
        tr, opt, counts_acc, tok_acc, loss_acc, n_acc = carry
        tok, lab, msk, val = xs
        new_tr, new_opt, loss, counts = _train_step(
            cfg, params, tr, opt, tok, lab, msk, k=k, tc=tc,
            rescaler_trainable=rescaler_trainable)
        keep = val > 0

        def sel(new, old):
            return jax.tree.map(lambda a, b: jnp.where(keep, a, b), new, old)

        carry = (sel(new_tr, tr), sel(new_opt, opt),
                 jax.tree.map(lambda a, c: a + jnp.where(keep, c, 0.0),
                              counts_acc, counts),
                 tok_acc + jnp.where(keep, tokens_per_step, 0.0),
                 loss_acc + jnp.where(keep, loss, 0.0),
                 n_acc + jnp.where(keep, 1.0, 0.0))
        return carry, None

    (tr, _, count_sums, tok, loss_sum, n_valid), _ = jax.lax.scan(
        body, carry0, (tokens, labels, mask, valid))
    return tr, count_sums, tok, loss_sum, n_valid


_local_update_jit = jax.jit(
    local_update, static_argnames=("cfg", "k", "tc", "rescaler_trainable"))


# ==========================================================================
# batched cohort execution (the round engine's compute core)
# ==========================================================================

@partial(jax.jit,
         static_argnames=("cfg", "k", "tc", "rescaler_trainable", "backend"))
def cohort_update(cfg: ModelConfig, params: PyTree, stacked_trainable: PyTree,
                  tokens, labels, mask, valid, *, k: int, tc: TrainConfig,
                  rescaler_trainable: bool, backend: str = "vmap"):
    """Run :func:`local_update` for a whole cohort in one compiled call.

    ``stacked_trainable``: pytree with leading client axis C (from
    ``lora.stack_adapters``); ``tokens``…``valid``: stacked
    :class:`BatchPlan` arrays (C, n_steps, ...).  The cohort must be
    shape-homogeneous (same k, adapter rank, batch size) — the cohort
    builder guarantees this.

    ``backend="vmap"`` batches all clients into one program (fastest;
    memory scales with C); ``backend="map"`` lowers to ``lax.map``, which
    compiles the same single-client program once and runs clients
    sequentially *inside* one computation — the fallback for memory-tight
    configs.

    The kernel implementations inside the compiled step follow
    ``cfg.kernels`` (``repro.kernels.backend``): with
    ``KernelConfig(backend="pallas")`` the whole cohort trains on the
    fused Pallas hot-path kernels; reference-vs-pallas parity of this
    exact entry point is CI-enforced in tests/test_backend.py.

    Returns stacked ``(trainable, count_sums {pos: (C, n_periods, E)},
    token_counts (C,), loss_sums (C,), n_valid (C,))``.
    """
    def one(tr, tok, lab, msk, val):
        return local_update(cfg, params, tr, tok, lab, msk, val, k=k, tc=tc,
                            rescaler_trainable=rescaler_trainable)

    if backend == "vmap":
        return jax.vmap(one)(stacked_trainable, tokens, labels, mask, valid)
    if backend == "map":
        return jax.lax.map(lambda a: one(*a),
                           (stacked_trainable, tokens, labels, mask, valid))
    raise ValueError(f"unknown cohort backend {backend!r}")


# ==========================================================================
# looped reference path (the sequential oracle)
# ==========================================================================

def local_train(cfg: ModelConfig, params: PyTree, global_lora: PyTree,
                client: ClientState, tc: TrainConfig, round_seed: int
                ) -> Tuple[PyTree, Dict[str, jnp.ndarray], float, Dict]:
    """Run the client's local epoch(s) — sequential reference path.

    Returns (trained_lora, activation_frequencies, total_tokens, info).
    ``global_lora`` arrives already shaped for this client (full for FLAME,
    rank-truncated for HLoRA/FlexLoRA).  Consumes the same
    :class:`BatchPlan` as the batched engine, so ``cohort_update`` output
    is allclose to running this per client.
    """
    trainable = lora_lib.make_trainable(global_lora, client.rescaler)
    plan = make_batch_plan(client, tc, round_seed)
    trained, count_sums, tok, loss_sum, n_valid = _local_update_jit(
        cfg, params, trainable, jnp.asarray(plan.tokens),
        jnp.asarray(plan.labels), jnp.asarray(plan.mask),
        jnp.asarray(plan.valid), k=client.k, tc=tc,
        rescaler_trainable=(client.rescaler_mode == "learnable"))

    total_tokens = float(tok)
    freqs = {pos: np.asarray(c) / max(total_tokens, 1.0)
             for pos, c in count_sums.items()}
    if "rescaler" in trained:
        client.rescaler = trained["rescaler"]     # persist s_i locally
    steps = int(n_valid)
    info = {"mean_loss": (float(loss_sum) / steps if steps
                          else float("nan")),
            "steps": steps}
    return trained["lora"], freqs, total_tokens, info


# ==========================================================================
# evaluation
# ==========================================================================

@partial(jax.jit, static_argnames=("cfg", "k"))
def _eval_step(cfg, params, tokens, labels, mask, trainable, k):
    loss, _ = model_lib.lm_loss(cfg, params, tokens, labels, mask,
                                trainable=trainable, k=k)
    return loss


def evaluate(cfg: ModelConfig, params: PyTree, trainable: Optional[PyTree],
             corpus: Corpus, *, k: int, batch_size: int = 16) -> float:
    """Mean masked CE loss over a corpus."""
    tot, n = 0.0, 0
    rng = np.random.default_rng(0)
    for tokens, labels, mask in batches(corpus, batch_size, rng=rng,
                                        drop_last=False):
        loss = _eval_step(cfg, params, jnp.asarray(tokens),
                          jnp.asarray(labels), jnp.asarray(mask),
                          trainable, k)
        tot += float(loss) * len(tokens)
        n += len(tokens)
    return tot / max(n, 1)
