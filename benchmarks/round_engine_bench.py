"""Round-engine benchmark: looped vs batched per-round wall-clock.

Times ONE communication round of the FLAME method over a mixed b1–b4
client population, executed three ways:

  * ``looped``        — sequential per-client ``local_train`` (reference);
  * ``batched/vmap``  — one vmapped ``cohort_update`` per budget cohort;
  * ``batched/map``   — same engine lowered through ``lax.map`` (the
                        memory-tight fallback).

The first timed round per engine is compile-inclusive and discarded; the
reported figure is steady-state (the per-round cost a multi-round sweep
actually pays).  Emits the usual CSV block plus a ``BENCH JSON`` line for
machine consumption.
"""
from __future__ import annotations

import json
import time

from repro.configs.base import FederatedConfig

from .common import BENCH_TC, bench_data, bench_model, emit


def _time_rounds(engine: str, backend: str, *, clients: int,
                 rounds: int = 2):
    """Build a fresh experiment and time rounds; returns (compile_s,
    steady_s) — round 0 includes jit compilation, later rounds don't."""
    from repro.federated.simulation import build_experiment

    cfg = bench_model(moe=True)
    fed = FederatedConfig(num_clients=clients, rounds=rounds,
                          method="flame", temperature=2,
                          round_engine=engine, cohort_backend=backend)
    exp = build_experiment(cfg, fed=fed, tc=BENCH_TC,
                           data=bench_data(cfg))
    times = []
    for r in range(rounds):
        t0 = time.perf_counter()
        exp.server.run_round(r)
        times.append(time.perf_counter() - t0)
    steady = min(times[1:]) if len(times) > 1 else times[0]
    return times[0], steady


def run(clients: int = 16) -> None:
    # 16 clients ⇒ 4 per budget cohort: the regime where batching pays even
    # on CPU (at 8 clients/2-wide cohorts the vmap dispatch overhead wins);
    # on accelerators the gap widens with cohort width.
    rows = []
    results = {}
    for engine, backend in (("looped", "vmap"), ("batched", "vmap"),
                            ("batched", "map")):
        label = engine if engine == "looped" else f"{engine}/{backend}"
        compile_s, steady_s = _time_rounds(engine, backend, clients=clients)
        results[label] = steady_s
        rows.append({"engine": label, "clients": clients,
                     "compile_round_s": compile_s,
                     "steady_round_s": steady_s})
    emit("round_engine", rows,
         ["engine", "clients", "compile_round_s", "steady_round_s"])

    speedup = results["looped"] / max(results["batched/vmap"], 1e-9)
    print(f"# CLAIM round-engine: batched/vmap {speedup:.2f}x vs looped "
          f"({clients} clients, steady-state round)")
    print("# BENCH JSON: " + json.dumps(
        {"bench": "round_engine", "clients": clients,
         "steady_round_s": results,
         "speedup_batched_vmap_vs_looped": speedup}))


if __name__ == "__main__":
    run()
