"""Pallas TPU kernel: fused SMoE router — softmax + top-k + activation counts.

FLAME's adaptive routing needs, per token block: (1) routing probabilities,
(2) the top-``k_i`` selection mask, (3) renormalised combine weights, and
(4) the **per-expert activation counts** that feed the activation-aware
aggregation (Eq. 6).  On GPU the counts would be a scatter-add; on TPU we
fuse everything into one VMEM-resident pass over token blocks:

  grid = (T / bt,)  — one program per token block;
  * softmax over the expert axis in fp32 (E ≤ a few hundred, fits a lane);
  * iterative top-k: k repeats of (argmax → one-hot → mask out), which is
    exactly the oracle semantics and MXU/VPU friendly (no sort);
  * weights renormalised over the selected experts;
  * counts: ``mask.sum(0)`` accumulated into a single (1, E) output block
    that every grid step maps to — TPU grid iterations are sequential, so
    the revisited block acts as an accumulator (init at step 0).

Validated against ``ref.topk_router_ref`` in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(logits_ref, w_ref, m_ref, c_ref, *, k: int):
    it = pl.program_id(0)

    @pl.when(it == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    logits = logits_ref[...].astype(jnp.float32)          # (bt, E)
    z = logits - logits.max(axis=-1, keepdims=True)
    ez = jnp.exp(z)
    probs = ez / ez.sum(axis=-1, keepdims=True)

    masked = probs
    mask = jnp.zeros_like(probs)
    for _ in range(k):                                    # k is static
        idx = jnp.argmax(masked, axis=-1)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
                  == idx[:, None]).astype(jnp.float32)
        mask = mask + onehot
        masked = masked * (1.0 - onehot)

    weights = probs * mask
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    w_ref[...] = weights.astype(w_ref.dtype)
    m_ref[...] = mask.astype(m_ref.dtype)
    c_ref[...] += mask.sum(axis=0, keepdims=True).astype(c_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "block_t", "interpret"))
def topk_router(logits: jnp.ndarray, k: int, *, block_t: int = 1024,
                interpret: bool = True):
    """logits: (T, E) -> (weights (T, E) f32, mask (T, E) f32, counts (E,)).

    ``k`` static (the client budget k_i).  Semantics identical to
    ``moe_layer.topk_routing`` plus the fused count reduction.
    """
    T, E = logits.shape
    bt = min(block_t, T)
    while T % bt:
        bt //= 2
    nt = T // bt

    kernel = functools.partial(_router_kernel, k=k)
    weights, mask, counts = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((bt, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, E), lambda i: (i, 0)),
            pl.BlockSpec((bt, E), lambda i: (i, 0)),
            pl.BlockSpec((1, E), lambda i: (0, 0)),   # accumulator block
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, E), jnp.float32),
            jax.ShapeDtypeStruct((T, E), jnp.float32),
            jax.ShapeDtypeStruct((1, E), jnp.float32),
        ],
        interpret=interpret,
    )(logits)
    return weights, mask, counts[0]
