"""Self-speculative decoding: the adaptive-k model drafts for itself.

FLAME's global SMoE weights serve any activation budget, so the model is
its own draft model: the engine drafts a window of W tokens per slot at
``draft_k`` (default 1, the cheapest budget on the ragged dispatch path),
then verifies the whole window in ONE full-k multi-token decode step
(models.decode_step with S = W+1, teacher-forcing the drafts against the
cache), and accepts a prefix via the standard speculative sampling
rejection rule:

  accept draft ``d_i`` with probability ``min(1, p_i[d_i] / q_i[d_i])``
  (``q`` = draft distribution, ``p`` = target distribution, both under
  the engine's sampler transform); on the first rejection resample from
  the corrected residual ``norm(max(p_i - q_i, 0))``; if all W drafts
  survive, emit a bonus token from ``p_W``.

This yields output *distributionally identical* to plain full-k decoding
(Leviathan et al.) — and for the greedy sampler the rule degenerates to
exact-match acceptance with an argmax resample, i.e. token-for-token
identity with plain greedy decode (tests/test_speculative.py).

KV correctness: the draft steps never write the cache at all — their
K/V live in a small per-round window buffer (models.draft_window), the
verify step attends the cache pre-write and deposits full-k K/V at the
window's positions (attention.verify_attention), and the engine then
rolls each row back to its first rejected position
(``pool.truncate_to``), so the cache after a round is exactly what a
straight decode of the accepted prefix would have produced.

Launch economics: a round is THREE device launches regardless of W —
the draft window is a single jitted ``lax.scan`` over W steps (sampling
in-graph, so no per-step host sync), verify is one multi-token step, and
the rejection rule is one vmapped call over all slots.  A plain decode
pass over the same W+1 tokens costs W+1 launches + host syncs.  Just as
important, each in-scan draft step skips the cache read-modify-write
that dominates a small-batch decode step: the prefix is gathered once
(paged) or read in place (slotted) and stays read-only, so a draft step
costs a fraction of a real decode step even before launch savings.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib
from .sampler import SamplerConfig, sample_from_probs, sampler_probs


@dataclass(frozen=True)
class SpeculativeConfig:
    """``window``: drafts per round (W); ``draft_k``: the draft budget."""
    window: int = 4
    draft_k: int = 1

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"speculative window must be >= 1, "
                             f"got {self.window}")
        if self.draft_k < 1:
            raise ValueError(f"draft_k must be >= 1, got {self.draft_k}")


def _verify_window(key: jax.Array, draft_tokens: jnp.ndarray,
                   draft_logits: jnp.ndarray, target_logits: jnp.ndarray,
                   sc: SamplerConfig):
    """The rejection rule for one slot's drafted window (pure jnp).

    ``draft_tokens``: (W,) int; ``draft_logits``: (W, V) — the draft
    model's logits each token was sampled from; ``target_logits``:
    (W+1, V) — the full-k logits at every window position (the last row
    conditions on all W drafts and feeds the bonus token).

    Returns ``(tokens (W+1,), n_emitted, n_accepted)``: the first
    ``n_emitted = n_accepted + 1`` entries of ``tokens`` are the round's
    output (accepted drafts + the resampled/bonus token).  Vmappable;
    with ``sc.kind == "greedy"`` the distributions are one-hot and the
    outcome is key-independent.
    """
    W = draft_tokens.shape[0]
    q = sampler_probs(draft_logits, sc)                   # (W, V)
    p = sampler_probs(target_logits, sc)                  # (W+1, V)
    iw = jnp.arange(W)
    p_d = p[iw, draft_tokens]
    q_d = q[iw, draft_tokens]
    key_u, key_last = jax.random.split(key)
    u = jax.random.uniform(key_u, (W,))
    # u < min(1, p/q)  <=>  u * q < p  (divide-free; q == 0 accepts iff
    # p > 0, the natural limit — a greedy draft mismatch has p_d == 0)
    accept = u * q_d < p_d
    n_acc = jnp.cumprod(accept.astype(jnp.int32)).sum()   # accepted prefix
    # corrected residual at the first rejected position (unused when all
    # accepted); the p-fallback guards the p <= q everywhere edge, which
    # is unreachable for a real rejection but keeps the math total
    ridx = jnp.minimum(n_acc, W - 1)
    resid = jnp.clip(p[ridx] - q[ridx], 0.0)
    rs = resid.sum()
    resid = jnp.where(rs > 0.0, resid / rs, p[ridx])
    last_probs = jnp.where(n_acc == W, p[W], resid)
    last = sample_from_probs(key_last, last_probs)
    out = jnp.concatenate(
        [draft_tokens, jnp.zeros((1,), draft_tokens.dtype)])
    out = out.at[n_acc].set(last.astype(out.dtype))
    return out, n_acc + 1, n_acc


verify_window = jax.jit(_verify_window, static_argnames=("sc",))


@partial(jax.jit, static_argnames=("W",))
def _fold_event_keys(base_keys: jnp.ndarray, events: jnp.ndarray,
                     W: int) -> jnp.ndarray:
    """keys[j, b] = fold_in(base_keys[b], events[b] + j) for j < W —
    the per-slot draw keys for a draft window, built in one launch."""
    def row(j):
        return jax.vmap(jax.random.fold_in)(base_keys, events + j)
    return jnp.stack([row(j) for j in range(W)])


class SpeculativeDecoder:
    """Draft/verify driver bound to one :class:`~.engine.ServingEngine`.

    Owns the extra compiled steps: the fused draft window (the engine's
    decode step recompiled with every slot at ``draft_k`` and scanned W
    times in-graph, sampling included), the verify step (full tier k,
    S = W+1 tokens), and the vmapped rejection rule.  One compile per
    distinct window width, mirroring the prefill buckets.  The engine
    calls :meth:`round` wherever it would have called ``_decode_once``.
    """

    def __init__(self, engine, spec: SpeculativeConfig):
        cfg = engine.cfg
        if not cfg.moe.enabled:
            raise ValueError(
                "self-speculation drafts the same weights at a reduced "
                "expert budget; a non-MoE model has no cheaper draft")
        if not spec.draft_k <= cfg.moe.num_experts:
            raise ValueError(f"draft_k={spec.draft_k} > "
                             f"{cfg.moe.num_experts} experts")
        if any(cfg.layer_kind(p) != "attn"
               for p in range(cfg.pattern_period)):
            raise ValueError(
                "speculative decoding requires attention-only models: "
                "SSM state is cumulative and cannot roll back to a "
                "rejected position")
        if engine.dispatch == "capacity":
            raise ValueError(
                "speculative verify requires a loss-free dispatch mode "
                "(ragged/dense): capacity dispatch makes the verify "
                "distribution depend on co-batched rows")
        if 0 < cfg.attention_window < engine.slot_len:
            raise ValueError(
                "speculative rollback requires a non-wrapping KV cache: "
                f"attention_window={cfg.attention_window} < slot_len="
                f"{engine.slot_len} would alias window positions")
        self.eng = engine
        self.window = spec.window
        self.draft_k = spec.draft_k
        self._np_keys = {}                 # rid -> host copy of base key
        # slot -> window base position (the last verified cache_pos)
        # while that slot's draft window is OPEN: set when the round
        # advances positions for the draft/verify, cleared as each
        # slot's window resolves (advance or truncate).  A preemption
        # swapping the slot out mid-window rolls back through
        # rollback_open so the swap state never carries draft positions.
        self._open: Dict[int, int] = {}
        self._draft_fn = self._build_draft_window_fn()
        self._verify_fn = engine._build_verify_fn()
        self._draft_trainable = engine._build_draft_trainable(spec.draft_k)
        sc = engine._sampler
        self._reject_fn = jax.jit(jax.vmap(
            lambda key, d, ql, tl: _verify_window(key, d, ql, tl, sc)))

    # ------------------------------------------------------- compiled pieces
    def _build_draft_window_fn(self):
        """W draft steps fused into one jitted ``lax.scan``
        (models.draft_window): each step decodes every slot at the scalar
        ``draft_k``, samples the next token in-graph (greedy argmax, or
        the engine's sampler with per-slot per-step keys), and feeds it
        back — so a whole draft window is ONE device launch + ONE host
        sync instead of W, and the cache is only ever READ (the window's
        K/V ride in a small scan-carried buffer; verify overwrites those
        positions with full-k K/V anyway).  One compile per distinct
        window width (``keys.shape[0]``).  Returns
        ``(draft_logits (W,B,V) fp32, draft_tokens (W,B) int32)``.
        """
        eng = self.eng
        cfg, dispatch, sc = eng.cfg, eng.dispatch, eng._sampler
        dk = self.draft_k
        page_span = eng.pool.attn_len if eng.paged else None

        def pick(logits, keys_j):
            if sc.kind == "greedy":
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            probs = sampler_probs(logits, sc)
            return jax.vmap(sample_from_probs)(keys_j,
                                               probs).astype(jnp.int32)

        if eng.paged:
            @jax.jit
            def _draft(params, trainable, cache, tok0, pos0, tables, keys):
                return model_lib.draft_window(
                    cfg, params, cache, tok0, pos0, keys, sample_fn=pick,
                    window=keys.shape[0], trainable=trainable, k=dk,
                    block_table=tables, page_span=page_span,
                    dispatch=dispatch)
        else:
            @jax.jit
            def _draft(params, trainable, cache, tok0, pos0, keys):
                return model_lib.draft_window(
                    cfg, params, cache, tok0, pos0, keys, sample_fn=pick,
                    window=keys.shape[0], trainable=trainable, k=dk,
                    dispatch=dispatch)
        return _draft

    def _base_key(self, rid: int) -> np.ndarray:
        """Host copy of the request's PRNG base key, memoized — pulling
        it from the device once per request instead of once per round
        (a per-slot sync every round would dominate the host budget)."""
        nk = self._np_keys.get(rid)
        if nk is None:
            nk = np.asarray(self.eng._req_key(rid))
            self._np_keys[rid] = nk
        return nk

    def _draw_keys(self, active: List[int], W: int) -> jnp.ndarray:
        """(W, B) draw keys for the draft window from each active slot's
        event counter (inactive rows get dummy keys; their draws steer
        only their own garbage tokens).  Greedy needs no randomness —
        a zero placeholder keeps the compiled signature uniform."""
        eng = self.eng
        B = eng.num_slots
        if eng._sampler.kind == "greedy":
            return jnp.zeros((W, B, 2), jnp.uint32)
        base = np.zeros((B, 2), np.uint32)
        events = np.zeros((B,), np.int32)
        for s in active:
            a = eng._active[s]
            base[s] = self._base_key(a.req.rid)
            events[s] = a.events
            a.events += W
        return _fold_event_keys(jnp.asarray(base), jnp.asarray(events), W)

    def _reject_keys(self, active: List[int]) -> jnp.ndarray:
        """(B, 2) keys for the rejection rule's accept/resample draws,
        batched into one fold launch.  Greedy verify is key-independent
        (one-hot p and q make every accept test and resample
        deterministic), so zeros suffice there."""
        eng = self.eng
        B = eng.num_slots
        if eng._sampler.kind == "greedy":
            return jnp.zeros((B, 2), jnp.uint32)
        base = np.zeros((B, 2), np.uint32)
        events = np.zeros((B,), np.int32)
        for s in active:
            a = eng._active[s]
            base[s] = self._base_key(a.req.rid)
            events[s] = a.events
            a.events += 1
        return _fold_event_keys(jnp.asarray(base), jnp.asarray(events), 1)[0]

    # ------------------------------------------------------------------
    def _round_window(self, active: List[int]) -> int:
        """Largest safe W this round: every active slot must have room
        for the verify step's top position (``pos + W <= attn_len - 1``)
        and for ``W + 1`` emitted tokens within its budget."""
        eng = self.eng
        W = self.window
        for s in active:
            a = eng._active[s]
            W = min(W,
                    eng.pool.attn_len - 1 - int(eng.pool.cache_pos[s]),
                    a.max_new - len(a.tokens) - 1)
        return W

    def round(self, report) -> None:
        """One draft/verify iteration over every active slot; falls back
        to a plain decode step when no window fits."""
        eng = self.eng
        pool = eng.pool
        active = [s for s, a in enumerate(eng._active) if a is not None]
        W = self._round_window(active)
        if W < 1:
            eng._decode_once(report)
            return
        active_mask = jnp.asarray(
            [a is not None for a in eng._active], jnp.float32)
        pos0 = pool.cache_pos.copy()                       # (B,)
        first = eng._last_tok.copy()                       # (B, 1)
        for s in active:
            self._open[s] = int(pos0[s])

        # ---- draft: one fused launch covering W cheap read-only steps ----
        t0 = time.perf_counter()
        if eng.paged:
            # the draft never writes pages — the tables are passed only
            # for the one-shot prefix gather — but the VERIFY step writes
            # positions pos0 .. pos0+W-1, so allocate every window
            # position's block up front (covered by the admit-time
            # reservation)
            for _ in range(W):
                pool.prepare_decode(active)
                pool.advance(active)
            extra = (pool.tables(),)
        else:
            for _ in range(W):
                pool.advance(active)
            extra = ()
        qs, toks = self._draft_fn(
            eng.params, self._draft_trainable, pool.cache,
            jnp.asarray(first), jnp.asarray(pos0), *extra,
            self._draw_keys(active, W))
        d_toks = np.asarray(toks)                          # (W, B) — sync
        dt = time.perf_counter() - t0
        report.draft_step_s.append(dt)
        report.draft_hist.observe(dt * 1e3)
        if eng._tracer.enabled:
            end = eng._now()
            eng._tracer.complete("draft", end - dt, end, cat="engine",
                                 args={"window": W, "active": len(active)})

        # ---- verify + reject: one full-k step over the W+1 window
        # tokens, then the vmapped rejection rule over all slots ----
        t0 = time.perf_counter()
        extra = ()
        if eng.paged:
            pool.prepare_decode(active)                    # pos0 + W
            extra = (pool.tables(),)
        verify_in = np.concatenate([first, d_toks.T], axis=1)  # (B, W+1)
        lv, cache = self._verify_fn(
            eng.params, eng._decode_trainable, pool.cache,
            jnp.asarray(verify_in), jnp.asarray(pos0), active_mask, *extra)
        pool.cache = cache
        out_toks, n_emit, n_acc = self._reject_fn(
            self._reject_keys(active), jnp.asarray(d_toks.T),
            jnp.moveaxis(qs, 0, 1), lv)
        out_toks = np.asarray(out_toks)                    # (B, W+1) — sync
        n_emit, n_acc = np.asarray(n_emit), np.asarray(n_acc)
        dt = time.perf_counter() - t0
        report.verify_step_s.append(dt)
        report.verify_hist.observe(dt * 1e3)
        if eng._tracer.enabled:
            end = eng._now()
            eng._tracer.complete("verify", end - dt, end, cat="engine",
                                 args={"window": W})

        for s in active:
            a = eng._active[s]
            acc = int(n_acc[s])
            emitted = [int(t) for t in out_toks[s, :int(n_emit[s])]]
            a.tokens.extend(emitted)
            eng._last_tok[s, 0] = emitted[-1]
            report.spec_drafted += W
            report.spec_accepted += acc
            self._open.pop(s, None)        # window resolved below
            if acc == W:
                # position pos0+W holds the ACCEPTED last draft's K/V —
                # keep it and advance past it (the bonus token's K/V is
                # written by the next step, exactly as in plain decode)
                pool.advance([s])
            else:
                pool.truncate_to(s, int(pos0[s]) + acc + 1)
            if len(a.tokens) >= a.max_new or pool.slot_full(s):
                eng._finish(s, report)
        report.spec_rounds += 1

    def rollback_open(self, slot: int) -> None:
        """Preemption safety: if ``slot`` is being swapped out while its
        draft window is open (cache positions advanced past the last
        verified token for the in-flight draft/verify), roll the row
        back to the window base and forget the draft state — swap_out
        then captures exactly the verified prefix, and the resumed
        request re-enters decoding as if the round never started.

        A no-op in the normal engine loop: :meth:`round` is atomic with
        respect to admission (``_admit`` runs between rounds), so every
        window it opens is resolved before a preemption can fire.  The
        hook is what makes that atomicity a guarantee rather than an
        accident of control flow."""
        base = self._open.pop(slot, None)
        if base is not None:
            self.eng.pool.truncate_to(slot, base)
