"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  [arXiv:2407.21783]"""
from .base import LoRAConfig, ModelConfig

FULL = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16_384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53_248,
    vocab_size=128_256,
    rope_theta=500_000.0,
    lora=LoRAConfig(rank=16),
    source="arXiv:2407.21783",
)

SMOKE = FULL.replace(
    name="llama3-smoke",
    num_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    lora=LoRAConfig(rank=4),
)

SWA_WINDOW = 8192
