"""Trip-count-aware HLO accounting.

CALIBRATION (see EXPERIMENTS.md §Dry-run): XLA's ``cost_analysis()`` counts
every computation ONCE — a ``lax.scan`` of 126 layers reports the FLOPs of a
single layer body.  Measured: scan(10 matmuls) reports exactly 1 matmul of
FLOPs.  Any roofline built directly on cost_analysis() under-counts a
scanned-and-microbatched train step by ~n_layers × n_micro (≈2000×).

This module parses the optimized HLO text instead:

  * split the module into named computations;
  * find every ``while`` op, its body/condition computations, and the trip
    count (the s32 constant feeding the condition's LT compare — lax.scan
    always lowers to this pattern);
  * propagate multipliers: ops inside a while body execute
    ``trip × multiplier(parent)`` times (nested scans multiply);
  * sum collective bytes **weighted by multiplier** — an all-gather inside
    the layer scan of a 16-microbatch step costs 126·16 executions, not 1.

Shapes in the optimized HLO are per-device shards, so the returned bytes are
per-device — divide by per-chip link bandwidth directly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_CALL = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text.

    HLO prints each computation starting at column 0 as
    ``%name (args...) -> retty {`` (or ``ENTRY %name ...``); the body lines
    are indented and the closing ``}`` is back at column 0.  Brace counts
    inside shape layouts (``{1,0}``) balance within their own line, so a
    column-0 ``}`` reliably terminates the computation."""
    comps: Dict[str, str] = {}
    lines = hlo.splitlines()
    i = 0
    while i < len(lines):
        line = lines[i]
        m = _COMP_HDR.match(line)
        if m and not line.startswith((" ", "\t")) and \
                line.rstrip().endswith("{"):
            name = m.group(1)
            body = [line]
            i += 1
            while i < len(lines) and not lines[i].startswith("}"):
                body.append(lines[i])
                i += 1
            comps[name] = "\n".join(body)
        i += 1
    return comps


@dataclasses.dataclass
class WhileInfo:
    parent: str
    cond: str
    body: str
    trip: int


def find_whiles(comps: Dict[str, str]) -> List[WhileInfo]:
    out = []
    for parent, text in comps.items():
        for m in _WHILE.finditer(text):
            cond, body = m.group(1), m.group(2)
            trip = _trip_count(comps, cond)
            out.append(WhileInfo(parent, cond, body, trip))
    return out


def _trip_count(comps: Dict[str, str], cond: str) -> int:
    """Max s32 constant visible from the condition computation (following
    one level of called fusions) — lax.scan lowers to `lt(i, trips)`."""
    seen = set()
    frontier = [cond]
    best = 1
    while frontier:
        name = frontier.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        text = comps[name]
        for c in _CONST.findall(text):
            best = max(best, int(c))
        for m in _CALL.finditer(text):
            frontier.append(m.group(1))
    return best


def computation_multipliers(hlo: str) -> Tuple[Dict[str, str], Dict[str, float]]:
    """Returns (computations, multiplier per computation name).

    multiplier = product of trip counts of enclosing whiles.  Non-while
    call edges (fusions, custom-calls) propagate the caller's multiplier.
    """
    comps = split_computations(hlo)
    whiles = find_whiles(comps)
    parent_edge: Dict[str, Tuple[str, float]] = {}
    for w in whiles:
        parent_edge[w.body] = (w.parent, float(w.trip))
        parent_edge[w.cond] = (w.parent, float(w.trip))
    for parent, text in comps.items():
        for m in _CALL.finditer(text):
            callee = m.group(1)
            if callee not in parent_edge:
                parent_edge[callee] = (parent, 1.0)

    mult: Dict[str, float] = {}

    def resolve(name: str, depth=0) -> float:
        if name in mult:
            return mult[name]
        if depth > 64 or name not in parent_edge:
            mult[name] = 1.0
            return 1.0
        parent, trip = parent_edge[name]
        m = trip * resolve(parent, depth + 1)
        mult[name] = m
        return m

    for name in comps:
        resolve(name)
    return comps, mult


_METADATA = re.compile(r'op_name="([^"]*)"')


def top_collectives(hlo: str, n: int = 20) -> List[Dict]:
    """The n most expensive collectives (trip-weighted bytes), with their
    jaxpr provenance (op_name metadata) — the perf-loop's profile view."""
    comps, mult = computation_multipliers(hlo)
    rows = []
    for name, text in comps.items():
        m = mult.get(name, 1.0)
        for line in text.splitlines():
            cm = _COLLECTIVE.search(line)
            if not cm:
                continue
            b = shape_bytes(cm.group(1))
            md = _METADATA.search(line)
            rows.append({
                "kind": cm.group(2), "comp": name, "mult": m,
                "bytes_once": b, "bytes_total": b * m,
                "op_name": md.group(1) if md else "?",
                "shape": cm.group(1),
            })
    rows.sort(key=lambda r: -r["bytes_total"])
    return rows[:n]


def collective_bytes_weighted(hlo: str) -> Tuple[Dict[str, float],
                                                 Dict[str, float]]:
    """Per-kind (bytes, op-executions), weighted by loop trip multipliers.

    all-reduce is charged 2× (ring moves ~2·(n-1)/n of the buffer)."""
    comps, mult = computation_multipliers(hlo)
    bytes_by_kind: Dict[str, float] = {}
    execs_by_kind: Dict[str, float] = {}
    for name, text in comps.items():
        m = mult.get(name, 1.0)
        for cm in _COLLECTIVE.finditer(text):
            shape_str, kind = cm.group(1), cm.group(2)
            b = shape_bytes(shape_str) * (2.0 if kind == "all-reduce" else 1.0)
            bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + b * m
            execs_by_kind[kind] = execs_by_kind.get(kind, 0.0) + m
    return bytes_by_kind, execs_by_kind
