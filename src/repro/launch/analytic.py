"""Analytic per-device FLOPs / HBM-traffic models for the roofline terms.

Why analytic: XLA's ``cost_analysis()`` counts loop bodies once (measured —
see hlo_parse.py docstring), so a scanned 126-layer microbatched step is
under-counted ~2000×.  Rather than reverse-engineering per-computation
costs out of the HLO, the compute and memory terms come from the same
first-principles accounting the paper's Table 1 uses (``core/flops.py``),
extended with a traffic model; the collective term stays HLO-derived
(trip-weighted) because the collective schedule is exactly what GSPMD
decided and cannot be predicted analytically.

All quantities returned are PER DEVICE per step.
"""
from __future__ import annotations

from typing import Any, Dict

from ..configs.base import ModelConfig, ShapeConfig
from ..core import flops as F

DT = 2          # bf16 bytes


def device_flops(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                 knobs: Dict[str, Any]) -> float:
    """Executed FLOPs per device per step (incl. backward + remat)."""
    k = knobs.get("k") or (cfg.moe.top_k if cfg.moe.enabled else None)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        fwd = F.flops_detailed(cfg, tokens, shape.seq_len, k=k,
                               lora_rank=cfg.lora.rank)
        # fwd + 2×bwd + (remat ≈ one extra fwd; two-level remat adds one
        # more re-forward for the outer checkpoint level)
        mult = 3.0
        if knobs.get("remat", True):
            mult = 5.0 if knobs.get("remat_chunk") else 4.0
        return fwd * mult / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return F.flops_detailed(cfg, tokens, shape.seq_len, k=k,
                                lora_rank=cfg.lora.rank) / chips
    # decode: 1 token/request; per-layer context = cache length
    tokens = shape.global_batch
    ctx = _cache_len(cfg, shape.seq_len)
    f = F.flops_detailed(cfg, tokens, 1, k=k, lora_rank=cfg.lora.rank)
    # flops_detailed's attention-context term used seq/2=0.5; replace with
    # the true cache-read matmul flops
    hd = cfg.head_dim_
    attn_layers = sum(1 for l in range(cfg.num_layers)
                      if cfg.layer_kind(l) == "attn")
    f += 2.0 * tokens * ctx * cfg.n_heads * hd * 2 * attn_layers
    return f / chips


def _cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.attention_window > 0:
        return min(cfg.attention_window, seq_len)
    return seq_len


def _param_bytes(cfg: ModelConfig) -> float:
    return F.count_params(cfg)["total"] * DT


def _cache_bytes(cfg: ModelConfig, batch: int, seq_len: int) -> float:
    clen = _cache_len(cfg, seq_len)
    hd = cfg.head_dim_
    total = 0.0
    for l in range(cfg.num_layers):
        if cfg.layer_kind(l) == "attn":
            total += 2 * batch * clen * cfg.n_kv_heads * hd * DT
        else:
            from ..models.mamba2 import mamba_dims
            d = mamba_dims(cfg)
            total += batch * (d["conv_dim"] * (d["conv_width"] - 1) * DT
                              + d["n_heads"] * d["head_dim"] * d["d_state"]
                              * 4)
    return total


def device_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                 knobs: Dict[str, Any]) -> float:
    """HBM traffic per device per step (first-order: weight reads +
    activation reads/writes + cache traffic; fp32 grad-accum buffers)."""
    p_local = _param_bytes(cfg) / chips
    d = cfg.d_model
    L = cfg.num_layers

    if shape.kind == "train":
        n_micro = knobs.get("n_micro", 1)
        mb_tok_local = shape.global_batch * shape.seq_len / (n_micro * chips)
        # weights: read on fwd, bwd, and the remat re-forward, per microbatch
        w = 3.0 * n_micro * p_local
        # activations: ~12 touches of the residual stream per layer
        # (reads+writes over fwd, remat re-fwd, bwd), per microbatch
        a = n_micro * L * 12.0 * mb_tok_local * d * DT
        # flash attention KV re-streaming: each of nq query blocks re-reads
        # the visible KV span (≈S/2 causal avg)
        kv_w = cfg.n_kv_heads * cfg.head_dim_ * 2
        nq = max(shape.seq_len // 512, 1)
        attn_layers = sum(1 for l in range(L) if cfg.layer_kind(l) == "attn")
        a += (n_micro * attn_layers * (mb_tok_local / shape.seq_len)
              * nq * (shape.seq_len / 2) * kv_w * DT * 3)   # fwd+remat+bwd
        # LoRA grads + Adam state (fp32 accumulate + m + v, read+write)
        g = knobs.get("trainable_bytes", 0) / chips * (2 / DT) * 6
        return w + a + g

    tok_local = shape.global_batch * (shape.seq_len
                                      if shape.kind == "prefill" else 1)
    tok_local /= chips
    if shape.kind == "prefill":
        w = p_local
        a = L * 8.0 * tok_local * d * DT
        kv_w = cfg.n_kv_heads * cfg.head_dim_ * 2
        nq = max(shape.seq_len // 512, 1)
        attn_layers = sum(1 for l in range(L) if cfg.layer_kind(l) == "attn")
        a += (attn_layers * (tok_local / shape.seq_len) * nq
              * (shape.seq_len / 2) * kv_w * DT)
        c = _cache_bytes(cfg, shape.global_batch, shape.seq_len) / chips
        return w + a + c
    # decode: every weight + the whole cache are read once per token
    c = _cache_bytes(cfg, shape.global_batch, shape.seq_len) / chips
    a = L * 8.0 * tok_local * d * DT
    return p_local + c + a


def model_flops_global(cfg: ModelConfig, shape: ShapeConfig,
                       knobs: Dict[str, Any]) -> float:
    """MODEL_FLOPS: 6·N_active·D (train) / 2·N_active·D (inference)."""
    k = knobs.get("k") or (cfg.moe.top_k if cfg.moe.enabled else None)
    n_active = F.count_params(cfg, k=k)["active"]
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    return (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
