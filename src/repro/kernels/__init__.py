# Pallas TPU kernels for the FLAME hot paths (fused LoRA matmul, flash
# attention, top-k routing) + their pure-jnp oracles (ref.py).
#
# Model code selects an implementation through `repro.kernels.backend`
# (driven by `ModelConfig.kernels`); `ops.py` remains the thin manual
# use_kernel=True/False dispatch for scripts and benchmarks.
from . import backend, ops, ragged_dispatch, ref  # noqa: F401
