"""Request queue and admission policy for the serving engine.

Requests arrive (open-loop) and wait in a queue; each engine step the
scheduler packs waiting requests into free KV-cache slots.  Slots are
tier-typed — the engine compiles ONE decode step with a static per-slot
expert-budget vector (premium slots at full k, constrained slots at
k=1–2), so admission is ordered *per tier*: a request is placed into the
first free slot whose budget matches, and otherwise keeps waiting without
blocking requests of other tiers behind it.

Two queue orderings:

* ``policy="fifo"`` (default) — arrival order, the PR 3 behaviour.
* ``policy="slo"`` — earliest-deadline-first: each request's deadline is
  ``arrival + tier_slo_s[k]`` (its tier's TTFT target); requests whose
  tier has no target sort last (deadline ``inf``) and stay FIFO among
  themselves.  Under overload this admits latency-critical tiers ahead
  of best-effort traffic instead of strict arrival order, and it is the
  ordering the engine's decode preemption keys victim selection off.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Request:
    """One serving request.

    ``k``: requested expert budget (None = take any slot / server default).
    ``forced``: optional teacher-forced continuation — when set, the engine
    feeds these tokens back instead of its argmax samples and accumulates
    their negative log-likelihood (quality evaluation through the engine,
    used by examples/adaptive_serving.py).
    """
    rid: int
    prompt: np.ndarray                 # (L,) int32 token ids
    max_new_tokens: int
    k: Optional[int] = None
    arrival: float = 0.0               # seconds on the engine clock
    forced: Optional[np.ndarray] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclass
class Completion:
    """Per-request record emitted when a request leaves its slot."""
    rid: int
    prompt_len: int
    tokens: np.ndarray                 # generated token ids
    k: int                             # budget the request decoded at
    arrival: float
    admitted: float                    # prefill start (queueing delay ends)
    first_token: float                 # TTFT reference point
    finished: float
    nll_sum: float = 0.0               # teacher-forced NLL (forced mode)
    truncated: bool = False            # slot capacity hit before max_new
    preemptions: int = 0               # times swapped out mid-decode

    @property
    def ttft(self) -> float:
        """Time to first token: queueing delay + prefill."""
        return self.first_token - self.arrival

    @property
    def latency(self) -> float:
        """End-to-end request latency (arrival to final token)."""
        return self.finished - self.arrival

    @property
    def n_generated(self) -> int:
        return int(self.tokens.shape[0])


@dataclass
class Scheduler:
    """Request queue + tier-aware slot admission (FIFO or EDF order)."""

    queue: List[Request] = field(default_factory=list)
    policy: str = "fifo"               # "fifo" | "slo" (EDF)
    tier_slo_s: Optional[Dict[Optional[int], float]] = None
    enqueued: int = 0                  # cumulative adds (incl. re-queues)

    def __post_init__(self) -> None:
        assert self.policy in ("fifo", "slo"), self.policy
        if self.policy == "slo":
            assert self.tier_slo_s, "policy='slo' needs tier_slo_s targets"

    def add(self, req: Request) -> None:
        """Enqueue an arrived request."""
        self.queue.append(req)
        self.enqueued += 1

    def __len__(self) -> int:
        return len(self.queue)

    def publish(self, reg) -> None:
        """Set queue gauges on ``reg`` (a repro.obs.MetricsRegistry);
        the engine registers this as a snapshot-time pull source."""
        reg.gauge("serving.scheduler.queue_depth").set(len(self.queue))
        reg.gauge("serving.scheduler.enqueued_total").set(self.enqueued)

    def deadline(self, req: Request) -> float:
        """The request's TTFT deadline on the engine clock: arrival plus
        its tier's SLO target; ``inf`` when the tier has no target (such
        requests are never considered urgent)."""
        if not self.tier_slo_s:
            return float("inf")
        slo = self.tier_slo_s.get(req.k, float("inf"))
        return req.arrival + slo

    def _order(self) -> None:
        """Re-order the queue by the active policy.  EDF sort is stable,
        so equal deadlines (and untargeted tiers) stay FIFO."""
        if self.policy == "slo":
            self.queue.sort(key=self.deadline)

    def admit(self, free_slots: Sequence[int],
              slot_k: Sequence[Optional[int]],
              can_admit: Optional[Callable[[Request, int], bool]] = None
              ) -> List[Tuple[Request, int]]:
        """Pack queued requests into ``free_slots``.

        ``slot_k[s]`` is slot ``s``'s static expert budget (None for
        non-MoE models).  Queue-order per tier (FIFO, or EDF under
        ``policy="slo"``): each queued request takes the first free slot
        matching its requested ``k`` (any slot when the request doesn't
        care); non-matching requests are skipped, not blocked on.
        Returns (request, slot) assignments and removes the admitted
        requests from the queue.

        ``can_admit``: optional resource predicate ``(request, slot) ->
        bool`` (the paged engine's projected-block-need + tier-quota
        check), consulted AFTER a slot match — a request the predicate
        accepts is guaranteed admitted, so the predicate may account
        resources as it accepts (rejected probes must be side-effect
        free).  A rejection blocks the probed SLOT tier for the rest of
        this admit round (head-of-line per tier): later requests —
        including wildcard ``k=None`` ones — cannot take that tier's
        slots and leapfrog an earlier request that is only waiting on
        blocks, since a stream of small requests could otherwise starve
        a big one forever; other tiers' admission proceeds untouched.
        A wildcard request is probed against one slot of EACH distinct
        unblocked tier (in free-list order) before it is deemed
        blocked, so a single tier's quota saturation cannot idle slots
        another tier could have given it.
        """
        self._order()
        free = list(free_slots)
        assigned: List[Tuple[Request, int]] = []
        remaining: List[Request] = []
        blocked_tiers: set = set()
        for req in self.queue:
            candidates: List[int] = []
            seen_tiers: set = set()
            for s in free:
                t = slot_k[s]
                if t in blocked_tiers or t in seen_tiers:
                    continue
                if req.k is None or t == req.k:
                    seen_tiers.add(t)
                    candidates.append(s)
                    if req.k is not None:
                        break
            placed = False
            for slot in candidates:
                if can_admit is None or can_admit(req, slot):
                    free.remove(slot)
                    assigned.append((req, slot))
                    placed = True
                    break
                blocked_tiers.add(slot_k[slot])
            if not placed:
                remaining.append(req)
        self.queue = remaining
        return assigned
