"""Production-mesh launch example (the deliverable-e companion).

Shows the exact pjit/shard_map assembly a real multi-pod job would use:
build the 2×16×16 mesh, bind the sharded train step for an assigned
architecture, and (on real hardware) run it.  In this container it stops
after lower()+compile() — the same artifact the dry-run validates — and
prints the memory/roofline summary.

  PYTHONPATH=src python examples/multipod_launch.py --arch qwen3-moe-235b-a22b
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-235b-a22b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true", default=True)
    args = ap.parse_args()

    # dryrun must be imported FIRST: it owns the XLA_FLAGS device-count
    # override (512 placeholder devices) that the production mesh needs.
    from repro.launch.dryrun import run_pair

    rec = run_pair(args.arch, args.shape, multi_pod=args.multi_pod)
    r = rec["roofline"]
    print(f"\n{args.arch} × {args.shape} on mesh {rec['mesh']} "
          f"({rec['chips']} chips):")
    print(f"  step = {rec['step']}  knobs = {rec['meta']}")
    print(f"  HBM/device: {rec['memory']['peak_gb']:.2f} GB")
    print(f"  roofline: compute {r['t_compute_ms']:.2f} ms | "
          f"memory {r['t_memory_ms']:.2f} ms | "
          f"collective {r['t_collective_ms']:.2f} ms "
          f"-> bottleneck: {r['bottleneck']}")
    print(f"  useful-compute fraction: {r['useful_frac']:.2%}  "
          f"roofline-MFU: {r['mfu']:.2%}")
    print("\nOn a real v5e pod slice this compiled step executes as-is "
          "(same mesh axes, same shardings).")


if __name__ == "__main__":
    main()
