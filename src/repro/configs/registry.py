"""Architecture registry: ``--arch <id>`` resolution.

Every assigned architecture module defines ``FULL`` (the exact assigned
config, exercised via lower/compile dry-runs only) and ``SMOKE`` (a reduced
same-family variant: ≤2 effective periods, d_model ≤ 512, ≤4 experts — runs a
real forward/train step on CPU in the test suite).
"""
from __future__ import annotations

from importlib import import_module
from typing import Dict, Tuple

from .base import ModelConfig

_ARCH_MODULES = {
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mamba2-780m": "mamba2_780m",
    "granite-20b": "granite_20b",
    "chameleon-34b": "chameleon_34b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "llama3-405b": "llama3_405b",
    "musicgen-large": "musicgen_large",
    # the paper's own evaluation models
    "olmo-1.3b": "olmo_1_3b",
    "olmoe-1.3b-6.9b": "olmoe_1_3b_6_9b",
}

ASSIGNED_ARCHS = list(_ARCH_MODULES)[:10]


def _load(module: str):
    return import_module(f"repro.configs.{module}")


def list_archs():
    return list(_ARCH_MODULES)


def get_config(arch: str, variant: str = "full") -> ModelConfig:
    """variant: full | smoke | swa (full with sliding-window attention,
    the sub-quadratic option required for long_500k on attention archs)."""
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_ARCH_MODULES)}")
    module = _load(_ARCH_MODULES[arch])
    if variant == "full":
        cfg = module.FULL
    elif variant == "smoke":
        cfg = module.SMOKE
    elif variant == "swa":
        cfg = module.FULL
        if cfg.family != "ssm" and cfg.n_heads > 0:
            window = getattr(module, "SWA_WINDOW", 8192)
            cfg = cfg.replace(attention_window=window)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    cfg.validate()
    return cfg


def supports_long_context(arch: str) -> bool:
    """True when long_500k decode is runnable: native for SSM/hybrid, via the
    sliding-window variant for attention archs."""
    return True  # every assigned arch has a sub-quadratic path (see DESIGN §7)


def long_context_variant(arch: str) -> str:
    cfg = get_config(arch, "full")
    if cfg.family in ("ssm",):
        return "full"           # attention-free: natively sub-quadratic
    return "swa"
