"""Dirichlet client partitioning (paper §3.2).

Examples are distributed across N clients by drawing, for every latent task
cluster, a Dirichlet(α) vector over clients and routing that cluster's
examples accordingly.  α = 5 ⇒ near-uniform; α = 0.5 ⇒ heavily skewed —
matching the paper's heterogeneity settings.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .synthetic import Corpus


def dirichlet_partition(corpus: Corpus, num_clients: int, alpha: float,
                        seed: int = 0, min_per_client: int = 2
                        ) -> List[Corpus]:
    rng = np.random.default_rng(seed)
    n_clusters = int(corpus.clusters.max()) + 1
    assignment = np.empty(len(corpus.tokens), np.int64)

    for c in range(n_clusters):
        idx = np.where(corpus.clusters == c)[0]
        rng.shuffle(idx)
        probs = rng.dirichlet(np.full(num_clients, alpha))
        counts = rng.multinomial(len(idx), probs)
        start = 0
        for client, cnt in enumerate(counts):
            assignment[idx[start:start + cnt]] = client
            start += cnt

    # guarantee a minimum shard size (a client with no data can't train):
    # repeatedly split off examples from the currently-largest donor.
    # ``floor`` caps the guarantee at what the corpus can actually support;
    # within that cap the loop always terminates with every client at or
    # above the floor: whenever some client is short, the largest other
    # shard must be strictly above the floor (otherwise the total would be
    # < num_clients * floor <= len(corpus)), so each iteration moves >= 1
    # example without pushing the donor below the floor.
    floor = min(min_per_client, len(assignment) // num_clients)
    for client in range(num_clients):
        while True:
            need = floor - int((assignment == client).sum())
            if need <= 0:
                break
            sizes = np.bincount(assignment,
                                minlength=num_clients).astype(np.int64)
            sizes[client] = -1
            donor = int(sizes.argmax())
            pool = np.where(assignment == donor)[0]
            give = min(need, len(pool) - floor)
            assert give >= 1, (client, donor, sizes)
            assignment[pool[:give]] = client
    counts = np.bincount(assignment, minlength=num_clients)
    assert counts.min() >= floor, (counts, floor)

    shards = []
    for client in range(num_clients):
        sl = np.where(assignment == client)[0]
        shards.append(Corpus(corpus.tokens[sl], corpus.labels[sl],
                             corpus.mask[sl], corpus.clusters[sl]))
    return shards


def heterogeneity_stats(shards: List[Corpus]) -> dict:
    """Per-client sizes and cluster histograms (for EXPERIMENTS.md)."""
    n_clusters = max(int(s.clusters.max(initial=0)) for s in shards) + 1
    hists = np.stack([np.bincount(s.clusters, minlength=n_clusters)
                      for s in shards])
    return {"sizes": [len(s.tokens) for s in shards],
            "cluster_hist": hists}
