"""Attention: GQA with RoPE, optional qk-norm, full-causal or sliding-window.

Two execution paths, selected per call by ``cfg.kernels`` through
``repro.kernels.backend`` (dispatch rules in docs/kernels.md):
  * ``flash_attention_jnp`` — blockwise online-softmax attention written with
    ``lax.scan`` so no (S, S) score tensor is ever materialised.  The
    reference backend, the GSPMD dry-run path, and the fallback for
    logit-softcap models and single-token decode.
  * ``repro.kernels.flash_attention`` — the Pallas TPU kernel (same math),
    used on the pallas backend (interpret mode off-TPU).

Sliding-window attention fetches only the KV span each query block can see
(``lax.dynamic_slice``), making long-context prefill genuinely sub-quadratic.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import backend as kernel_backend
from .layers import apply_rope, lora_dense, rms_norm, softcap

NEG_INF = -1e30


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------

def init_attention(key, cfg) -> dict:
    from .layers import dense_init
    d, hd = cfg.d_model, cfg.head_dim_
    kq, kk, kv, ko = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    p = {
        "wq": dense_init(kq, (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(kk, (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(kv, (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ko, (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


# --------------------------------------------------------------------------
# blockwise flash attention (jnp path)
# --------------------------------------------------------------------------

def _pick_block(seq_len: int, target: int = 512) -> int:
    b = min(target, seq_len)
    while seq_len % b:
        b //= 2
    return max(b, 1)


def flash_attention_jnp(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = 0,
                        logit_softcap: float = 0.0,
                        block_q: int = 512, block_k: int = 512) -> jnp.ndarray:
    """q: (B,S,H,D); k,v: (B,S,KV,D).  Returns (B,S,H,D).

    GQA is handled by reshaping query heads into (KV, rep) groups.  Online
    softmax runs in fp32.  ``window > 0`` limits each query to the previous
    ``window`` positions (inclusive of itself) and restricts the scanned KV
    span accordingly.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    rep = H // KV
    bq = _pick_block(S, block_q)
    bk = _pick_block(S, block_k)
    nq = S // bq
    scale = jnp.asarray(D ** -0.5, jnp.float32)

    # (B, nq, bq, KV, rep, D) query blocks
    qb = q.reshape(B, nq, bq, KV, rep, D)

    if window > 0:
        # Each query block sees span [blk_start - window_pad, blk_end): a
        # static-width slice of K/V, fetched with dynamic_slice.
        span = ((window + bk - 1) // bk) * bk + bq
        span = min(span, S)

        def per_qblock(i, qblk):
            start = jnp.maximum(i * bq + bq - span, 0)
            ks = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kpos = start + jnp.arange(span)
            qpos = i * bq + jnp.arange(bq)
            return _attend_block(qblk, ks, vs, qpos, kpos, scale,
                                 causal=True, window=window,
                                 logit_softcap=logit_softcap)

        out = jax.lax.map(lambda args: per_qblock(*args),
                          (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
        out = jnp.moveaxis(out, 0, 1)          # (B, nq, bq, KV, rep, D)
        return out.reshape(B, S, H, D)

    # full causal: scan over kv blocks with online softmax
    nk = S // bk
    kb = k.reshape(B, nk, bk, KV, D)
    vb = v.reshape(B, nk, bk, KV, D)

    def per_qblock(i, qblk):
        # qblk: (B, bq, KV, rep, D)
        qpos = i * bq + jnp.arange(bq)

        def body(carry, inputs):
            m, l, acc = carry
            j, kblk, vblk = inputs           # (B, bk, KV, D)
            kpos = j * bk + jnp.arange(bk)
            s = jnp.einsum("bqkrd,bskd->bkrqs", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            s = softcap(s, logit_softcap)
            mask = qpos[:, None] >= kpos[None, :] if causal else None
            if mask is not None:
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkrqs,bskd->bkrqd", p,
                            vblk.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, rep, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, rep, bq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, KV, rep, bq, D) -> (B, bq, KV, rep, D)
        return jnp.transpose(out, (0, 3, 1, 2, 4))

    out = jax.lax.map(lambda args: per_qblock(*args),
                      (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(out, 0, 1)
    return out.reshape(B, S, H, D).astype(q.dtype)


def _attend_block(qblk, ks, vs, qpos, kpos, scale, *, causal, window,
                  logit_softcap):
    """Single query block vs a contiguous KV span (used by the SWA path).

    qblk: (B, bq, KV, rep, D); ks/vs: (B, span, KV, D).
    """
    s = jnp.einsum("bqkrd,bskd->bkrqs", qblk.astype(jnp.float32),
                   ks.astype(jnp.float32)) * scale
    s = softcap(s, logit_softcap)
    valid = kpos[None, :] <= qpos[:, None]
    if window > 0:
        valid &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bkrqd", p, vs.astype(jnp.float32))
    return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(qblk.dtype)


# --------------------------------------------------------------------------
# decode-time attention against a KV cache
# --------------------------------------------------------------------------

def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray,
                     *, window: int = 0,
                     logit_softcap: float = 0.0) -> jnp.ndarray:
    """One-token attention.  q: (B,1,H,D); caches: (B,Sc,KV,D).

    ``pos`` is the absolute position of the current token: a scalar, or a
    ``(B,)`` vector of per-row positions (the serving engine's slotted
    decode, where every slot is at a different depth).  For a ring
    (sliding-window) cache every slot is valid once the ring has wrapped;
    for a linear cache only slots ``<= pos`` are valid.
    """
    B, Sc, KV, D = k_cache.shape
    H = q.shape[2]
    rep = H // KV
    scale = jnp.asarray(D ** -0.5, jnp.float32)
    qh = q.reshape(B, KV, rep, D)

    s = jnp.einsum("bkrd,bskd->bkrs", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = softcap(s, logit_softcap)
    idx = jnp.arange(Sc)
    posb = jnp.broadcast_to(jnp.asarray(pos), (B,))
    if window > 0:
        # ring cache of size Sc == window: slot valid iff it has been written
        n_valid = jnp.minimum(posb + 1, Sc)
        valid = idx[None, :] < n_valid[:, None]
    else:
        valid = idx[None, :] <= posb[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrs,bskd->bkrd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def draft_attention(q: jnp.ndarray, k_win: jnp.ndarray, v_win: jnp.ndarray,
                    k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                    pos: jnp.ndarray, j: jnp.ndarray, *,
                    logit_softcap: float = 0.0) -> jnp.ndarray:
    """One speculative-draft step's attention: frozen prefix + window buffer.

    q: (B,1,H,D) RoPE'd at ``pos + j``; ``k_win``/``v_win``: (B,W,KV,D)
    window buffer holding the draft pass's own K/V at indices ``<= j``;
    ``k_cache``/``v_cache``: (B,Sc,KV,D) contiguous prefix, valid strictly
    below ``pos`` (the window's start).  The prefix is never written — the
    verify step later deposits full-k K/V at the window's positions — so a
    W-step draft scan carries only the small buffer, not the whole cache.
    Requires a non-wrapping cache (the serving engine guards this), so
    the sliding-window constraint can never bind within the window.
    """
    B, Sc, KV, D = k_cache.shape
    W = k_win.shape[1]
    H = q.shape[2]
    rep = H // KV
    scale = jnp.asarray(D ** -0.5, jnp.float32)
    qh = q.reshape(B, KV, rep, D)

    s_old = jnp.einsum("bkrd,bskd->bkrs", qh.astype(jnp.float32),
                       k_cache.astype(jnp.float32)) * scale
    s_new = jnp.einsum("bkrd,btkd->bkrt", qh.astype(jnp.float32),
                       k_win.astype(jnp.float32)) * scale
    s_old = softcap(s_old, logit_softcap)
    s_new = softcap(s_new, logit_softcap)

    posb = jnp.broadcast_to(jnp.asarray(pos), (B,))
    valid_old = jnp.arange(Sc)[None, :] < posb[:, None]          # (B, Sc)
    valid_new = jnp.broadcast_to(jnp.arange(W)[None, :] <= j, (B, W))
    valid = jnp.concatenate([valid_old, valid_new], axis=-1)     # (B, Sc+W)
    scores = jnp.concatenate([s_old, s_new], axis=-1)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = (jnp.einsum("bkrs,bskd->bkrd", p[..., :Sc],
                      v_cache.astype(jnp.float32))
           + jnp.einsum("bkrt,btkd->bkrd", p[..., Sc:],
                        v_win.astype(jnp.float32)))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def verify_attention(q: jnp.ndarray, k_new: jnp.ndarray, v_new: jnp.ndarray,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     pos: jnp.ndarray, *, window: int = 0,
                     logit_softcap: float = 0.0) -> jnp.ndarray:
    """Teacher-forced attention for an S-token speculative verify window.

    q/k_new/v_new: (B,S,H|KV,D) — the window's projections, already
    RoPE'd at absolute positions ``pos + s``; caches: (B,Sc,KV,D);
    ``pos``: (B,) per-row window start (== the row's pre-draft cache_pos).

    Query ``s`` attends the cache at indices ``< pos`` (the context
    written by prefill + previous accepted tokens) plus window keys
    ``t <= s``.  The cache is consumed PRE-write: positions ``>= pos``
    may hold the draft pass's k=1 K/V, which must not leak into full-k
    scores — the caller overwrites them with ``k_new``/``v_new`` after.
    Requires a non-wrapping cache (``window == 0``, or every window
    position still below the ring modulus — the serving engine guards
    this), so cache index == absolute position.
    """
    B, S, H, D = q.shape
    Sc, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    scale = jnp.asarray(D ** -0.5, jnp.float32)
    qh = q.reshape(B, S, KV, rep, D)

    s_old = jnp.einsum("bskrd,bckd->bkrsc", qh.astype(jnp.float32),
                       k_cache.astype(jnp.float32)) * scale
    s_new = jnp.einsum("bskrd,btkd->bkrst", qh.astype(jnp.float32),
                       k_new.astype(jnp.float32)) * scale
    s_old = softcap(s_old, logit_softcap)
    s_new = softcap(s_new, logit_softcap)

    posb = jnp.broadcast_to(jnp.asarray(pos), (B,))
    qpos = posb[:, None] + jnp.arange(S)[None, :]             # (B, S)
    idx = jnp.arange(Sc)
    valid_old = jnp.broadcast_to(
        idx[None, None, :] < posb[:, None, None], (B, S, Sc))
    valid_new = jnp.broadcast_to(
        jnp.arange(S)[None, None, :] <= jnp.arange(S)[None, :, None],
        (B, S, S))
    if window > 0:
        kpos_new = posb[:, None, None] + jnp.arange(S)[None, None, :]
        valid_old &= idx[None, None, :] > qpos[:, :, None] - window
        valid_new &= kpos_new > qpos[:, :, None] - window

    valid = jnp.concatenate([valid_old, valid_new], axis=-1)  # (B,S,Sc+S)
    scores = jnp.concatenate([s_old, s_new], axis=-1)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = (jnp.einsum("bkrsc,bckd->bskrd", p[..., :Sc],
                      v_cache.astype(jnp.float32))
           + jnp.einsum("bkrst,btkd->bskrd", p[..., Sc:],
                        v_new.astype(jnp.float32)))
    return out.reshape(B, S, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# block-paged decode attention (serving/kv_cache.BlockPool)
# --------------------------------------------------------------------------

def paged_decode_write(k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                       k_tok: jnp.ndarray, v_tok: jnp.ndarray,
                       block_table: jnp.ndarray, cache_pos: jnp.ndarray,
                       *, page_span: int, window: int):
    """Scatter one token's K/V per row into the block pool.

    ``k_pool``/``v_pool``: (NB+1, bs, KV, D) — block 0 is the trash block
    that free rows (block table zeroed) harmlessly write into.
    ``k_tok``/``v_tok``: (B, KV, D).  Row ``r`` writes logical slot
    ``pos % page_span`` (ring) or ``pos`` (linear), i.e. block-table entry
    ``slot // bs`` at offset ``slot % bs``.
    """
    bs = k_pool.shape[1]
    B = k_tok.shape[0]
    cp = jnp.broadcast_to(jnp.asarray(cache_pos), (B,))
    logical = cp % page_span if window > 0 else cp
    bi = block_table[jnp.arange(B), logical // bs]
    off = logical % bs
    return (k_pool.at[bi, off].set(k_tok),
            v_pool.at[bi, off].set(v_tok))


def paged_verify_write(k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                       k_win: jnp.ndarray, v_win: jnp.ndarray,
                       block_table: jnp.ndarray, cache_pos: jnp.ndarray,
                       *, page_span: int, window: int):
    """Scatter an S-token verify window's K/V per row into the block pool
    (the multi-token generalisation of :func:`paged_decode_write`): row
    ``r`` writes logical slots ``pos + s`` for ``s in [0, S)``, which
    overwrites the draft pass's k=1 K/V at the same positions.  Free rows
    (zeroed block table) write the trash block harmlessly.

    ``k_win``/``v_win``: (B, S, KV, D).
    """
    bs = k_pool.shape[1]
    B, S = k_win.shape[:2]
    cp = jnp.broadcast_to(jnp.asarray(cache_pos), (B,))
    pos = cp[:, None] + jnp.arange(S)[None, :]                # (B, S)
    logical = pos % page_span if window > 0 else pos
    bi = block_table[jnp.arange(B)[:, None], logical // bs]
    off = logical % bs
    return (k_pool.at[bi, off].set(k_win),
            v_pool.at[bi, off].set(v_win))


def paged_gather(pool: jnp.ndarray, block_table: jnp.ndarray,
                 page_span: int) -> jnp.ndarray:
    """Gather each row's KV pages into a contiguous (B, page_span, KV, D)
    view — the exact layout the slotted :func:`decode_attention` consumes,
    so the paged and slotted decode steps share one score/softmax graph
    (and stay bitwise-comparable).  Unallocated table entries gather the
    trash block; anything past a row's valid length is masked by the
    per-row validity in :func:`decode_attention`, so freed or padding
    blocks can never leak into scores."""
    B, MB = block_table.shape
    bs = pool.shape[1]
    pages = pool[block_table]                  # (B, MB, bs, KV, D)
    return pages.reshape(B, MB * bs, *pool.shape[2:])[:, :page_span]


# --------------------------------------------------------------------------
# full attention sub-layer (projections + rope + attention + output)
# --------------------------------------------------------------------------

def apply_attention(p: dict, cfg, x: jnp.ndarray, positions: jnp.ndarray,
                    *, lora: Optional[dict] = None, lora_scale: float = 0.0,
                    cache: Optional[dict] = None,
                    cache_pos: Optional[jnp.ndarray] = None,
                    return_cache: bool = False,
                    block_table: Optional[jnp.ndarray] = None,
                    page_span: Optional[int] = None,
                    suffix_readonly: bool = False):
    """x: (B,S,D_model).  Training/prefill when ``cache`` is None or being
    built; decode (S==1) when ``cache`` holds the K/V ring; speculative
    verify (S>1 with a cache) teacher-forces an S-token window against
    the cache and overwrites the window's positions (verify_attention).

    ``block_table``/``page_span``: block-paged decode — the cache leaves
    are the global block pool (NB+1, bs, KV, D) instead of per-row rings;
    each row's pages are selected by its block-table row and gathered back
    into the slotted layout before attending (see paged_gather).

    ``suffix_readonly`` (with a block table and S > 1): the suffix-only
    cached-prefill mode — queries sit at per-row offset ``cache_pos``
    (the already-cached prefix length) and attend the gathered prefix
    pages plus the in-flight suffix, exactly the verify-window graph, but
    the pool is NOT written in-graph: the new K/V come back as a
    contiguous (B,S,KV,D) piece the caller scatters host-side
    (serving/kv_cache.BlockPool.write), because rows sharing attached
    blocks must not re-write them.

    Returns (out, new_cache) where new_cache is None unless requested.
    """
    B, S, _ = x.shape
    hd = cfg.head_dim_
    lg = lora or {}

    q = lora_dense(x, p["wq"], lg.get("wq"), lora_scale, kernels=cfg.kernels)
    k = lora_dense(x, p["wk"], lg.get("wk"), lora_scale, kernels=cfg.kernels)
    v = lora_dense(x, p["wv"], lg.get("wv"), lora_scale, kernels=cfg.kernels)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)

    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.rms_eps)
        k = rms_norm(p["k_norm"], k, cfg.rms_eps)

    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if (cache is not None and cache_pos is not None and S == 1
            and block_table is not None):
        # paged decode: scatter this token's K/V into the row's current
        # block, gather the row's pages into the slotted layout, and run
        # the same masked decode attention as the slotted path.
        k_pool, v_pool = paged_decode_write(
            cache["k"], cache["v"], k[:, 0], v[:, 0], block_table,
            cache_pos, page_span=page_span, window=cfg.attention_window)
        kg = paged_gather(k_pool, block_table, page_span)
        vg = paged_gather(v_pool, block_table, page_span)
        out = decode_attention(q, kg, vg, cache_pos,
                               window=cfg.attention_window,
                               logit_softcap=cfg.attn_logit_softcap)
        new_cache = {"k": k_pool, "v": v_pool}
    elif cache is not None and cache_pos is not None and S == 1:
        # decode: write this token's K/V into the ring/linear cache.
        # ``cache_pos`` may be a scalar (uniform batch) or a (B,) vector of
        # per-row positions (slotted serving decode) — the vector case
        # scatters each row's K/V at its own depth.
        Sc = cache["k"].shape[1]
        cp = jnp.asarray(cache_pos)
        slot = cp % Sc if cfg.attention_window > 0 else cp
        if cp.ndim == 1:
            bidx = jnp.arange(B)
            k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
            v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k,
                                                          slot, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v,
                                                          slot, 1)
        out = decode_attention(q, k_cache, v_cache, cache_pos,
                               window=cfg.attention_window,
                               logit_softcap=cfg.attn_logit_softcap)
        new_cache = {"k": k_cache, "v": v_cache}
    elif (cache is not None and cache_pos is not None
            and block_table is not None and suffix_readonly):
        # suffix-only cached prefill (S > 1): per-row query offset
        # cache_pos (= the prefix length), prefix pages read-only, new
        # K/V returned as a contiguous piece instead of scattered —
        # attached shared blocks must never be re-written in-graph.
        kg = paged_gather(cache["k"], block_table, page_span)
        vg = paged_gather(cache["v"], block_table, page_span)
        out = verify_attention(q, k, v, kg, vg, cache_pos,
                               window=cfg.attention_window,
                               logit_softcap=cfg.attn_logit_softcap)
        new_cache = {"k": k, "v": v}
    elif (cache is not None and cache_pos is not None
            and block_table is not None):
        # paged speculative verify (S > 1): attend each row's gathered
        # pages PRE-write (positions >= cache_pos may hold draft-phase
        # k=1 K/V that must not leak into full-k scores), then overwrite
        # the window's positions with the full-k K/V.
        kg = paged_gather(cache["k"], block_table, page_span)
        vg = paged_gather(cache["v"], block_table, page_span)
        out = verify_attention(q, k, v, kg, vg, cache_pos,
                               window=cfg.attention_window,
                               logit_softcap=cfg.attn_logit_softcap)
        k_pool, v_pool = paged_verify_write(
            cache["k"], cache["v"], k, v, block_table, cache_pos,
            page_span=page_span, window=cfg.attention_window)
        new_cache = {"k": k_pool, "v": v_pool}
    elif cache is not None and cache_pos is not None:
        # slotted speculative verify (S > 1): same pre-write attention,
        # then scatter the window's K/V at each row's own depth.
        cp = jnp.broadcast_to(jnp.asarray(cache_pos), (B,))
        out = verify_attention(q, k, v, cache["k"], cache["v"], cp,
                               window=cfg.attention_window,
                               logit_softcap=cfg.attn_logit_softcap)
        Sc = cache["k"].shape[1]
        slots = cp[:, None] + jnp.arange(S)[None, :]
        if cfg.attention_window > 0:
            slots = slots % Sc
        bidx = jnp.arange(B)[:, None]
        new_cache = {"k": cache["k"].at[bidx, slots].set(k),
                     "v": cache["v"].at[bidx, slots].set(v)}
    else:
        # backend dispatch (docs/kernels.md): the Pallas flash kernel when
        # selected and applicable; logit-softcap models fall back to the
        # blockwise jnp path (the kernel does not implement softcap), as
        # do the decode branch above (single-token attention) and
        # degenerate-block sequence lengths.
        if (kernel_backend.use_pallas(cfg.kernels)
                and cfg.attn_logit_softcap == 0.0
                and kernel_backend.flash_blocks_ok(S)):
            out = kernel_backend.flash_attention(
                cfg.kernels, q, k, v, causal=True,
                window=cfg.attention_window)
        else:
            out = flash_attention_jnp(
                q, k, v, causal=True, window=cfg.attention_window,
                logit_softcap=cfg.attn_logit_softcap)
        if return_cache:
            w = cfg.attention_window
            if w > 0 and S >= w:
                # ring cache: token t lives at slot t % w — roll so the
                # last w tokens land on their ring slots and subsequent
                # decode writes overwrite the oldest entry
                kc, vc = k[:, S - w:], v[:, S - w:]
                shift = S % w
                if shift:
                    kc = jnp.roll(kc, shift, axis=1)
                    vc = jnp.roll(vc, shift, axis=1)
                new_cache = {"k": kc, "v": vc}
            else:
                new_cache = {"k": k, "v": v}

    out = out.reshape(B, S, cfg.n_heads * hd)
    out = lora_dense(out, p["wo"], lg.get("wo"), lora_scale,
                     kernels=cfg.kernels)
    return out, new_cache


def apply_draft_attention(p: dict, cfg, x: jnp.ndarray,
                          positions: jnp.ndarray, j: jnp.ndarray,
                          win: dict, static_kv: dict, pos: jnp.ndarray,
                          *, lora: Optional[dict] = None,
                          lora_scale: float = 0.0):
    """Attention sub-layer for one speculative-draft step (S == 1).

    Identical projections/RoPE to :func:`apply_attention`, but the new
    K/V are written into the small per-round window buffer ``win``
    ((B,W,KV,D), at index ``j``) instead of the decode cache, and
    attention runs via :func:`draft_attention` against the read-only
    contiguous prefix ``static_kv`` — the draft scan therefore never
    carries (or copies) the big cache.  Returns (out, updated win).
    """
    B, S, _ = x.shape
    hd = cfg.head_dim_
    lg = lora or {}

    q = lora_dense(x, p["wq"], lg.get("wq"), lora_scale, kernels=cfg.kernels)
    k = lora_dense(x, p["wk"], lg.get("wk"), lora_scale, kernels=cfg.kernels)
    v = lora_dense(x, p["wv"], lg.get("wv"), lora_scale, kernels=cfg.kernels)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)

    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.rms_eps)
        k = rms_norm(p["k_norm"], k, cfg.rms_eps)

    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    k_win = jax.lax.dynamic_update_slice_in_dim(win["k"],
                                                k.astype(win["k"].dtype),
                                                j, axis=1)
    v_win = jax.lax.dynamic_update_slice_in_dim(win["v"],
                                                v.astype(win["v"].dtype),
                                                j, axis=1)
    out = draft_attention(q, k_win, v_win, static_kv["k"], static_kv["v"],
                          pos, j, logit_softcap=cfg.attn_logit_softcap)
    out = out.reshape(B, S, cfg.n_heads * hd)
    out = lora_dense(out, p["wo"], lg.get("wo"), lora_scale,
                     kernels=cfg.kernels)
    return out, {"k": k_win, "v": v_win}
