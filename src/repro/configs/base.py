"""Config dataclasses shared by the whole framework.

A single ``ModelConfig`` describes every architecture family we support
(dense / moe / ssm / hybrid / vlm / audio).  ``ShapeConfig`` describes the
assigned input shapes.  Configs are plain frozen dataclasses so they hash and
can be used as jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

LayerKind = str  # "attn" | "ssm"


@dataclass(frozen=True)
class MoEConfig:
    """Sparse-MoE FFN configuration."""

    num_experts: int = 0          # routed experts (0 = no MoE)
    top_k: int = 0                # experts activated per token (full budget k)
    d_expert: int = 0             # expert hidden dim
    num_shared_experts: int = 0   # always-active experts (Qwen2-MoE style)
    d_shared_expert: int = 0      # hidden dim of the shared expert block
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # which layers carry an MoE FFN: layer l is MoE iff (l % moe_every == moe_offset)
    moe_every: int = 1
    moe_offset: int = 0

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) configuration."""

    d_state: int = 0
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1

    @property
    def enabled(self) -> bool:
        return self.d_state > 0


@dataclass(frozen=True)
class KernelConfig:
    """Kernel-backend selection (see docs/kernels.md §Selecting a backend).

    ``backend``:
      * ``"auto"``      — fused Pallas kernels on TPU, jnp references
        elsewhere (the safe default: nothing changes on CPU);
      * ``"pallas"``    — force the Pallas kernels everywhere; off-TPU they
        execute under the Pallas interpreter (slow, bit-faithful — the CI
        parity configuration);
      * ``"reference"`` — force the pure-jnp reference implementations
        everywhere, including on TPU (the debugging oracle).

    ``interpret`` forces the Pallas interpreter even on TPU — the escape
    hatch for debugging a miscompiled kernel without leaving the device.
    Frozen + hashable so ``ModelConfig`` stays usable as a jit static arg.
    """

    backend: str = "auto"         # auto|pallas|reference
    interpret: bool = False

    def validate(self) -> None:
        assert self.backend in ("auto", "pallas", "reference"), self.backend


@dataclass(frozen=True)
class LoRAConfig:
    """LoRA adapter configuration (the paper's trainable surface)."""

    rank: int = 0
    alpha: float = 16.0
    # which weight groups get adapters
    target_attn: bool = True      # q/k/v/o projections
    target_ffn: bool = True       # dense FFN w1/w2/w3
    target_expert: bool = True    # per-expert FFN matrices (FLAME's A^j/B^j)
    target_ssm: bool = True       # mamba in/out projections

    @property
    def enabled(self) -> bool:
        return self.rank > 0

    @property
    def scale(self) -> float:
        return self.alpha / max(self.rank, 1)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                     # dense FFN hidden dim (0 for pure-MoE FFN archs)
    vocab_size: int
    source: str = ""              # citation for the assigned config

    # attention details
    head_dim: int = 0             # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    attention_window: int = 0     # 0 = full causal; >0 = sliding window
    attn_logit_softcap: float = 0.0

    # per-family sub-configs
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    lora: LoRAConfig = field(default_factory=LoRAConfig)
    kernels: KernelConfig = field(default_factory=KernelConfig)

    # hybrid layer pattern, cycled over depth; None -> homogeneous
    #   e.g. Jamba period-8: ("ssm","ssm","ssm","attn","ssm","ssm","ssm","ssm")
    layer_pattern: Optional[Tuple[LayerKind, ...]] = None

    # audio: number of parallel codebooks (MusicGen/EnCodec); 0 = plain text
    num_codebooks: int = 0

    # norms / misc
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ---------------------------------------------------------------- helpers
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_rep(self) -> int:
        """query heads per kv head (GQA replication factor)."""
        return self.n_heads // max(self.n_kv_heads, 1)

    def layer_kind(self, layer_idx: int) -> LayerKind:
        if self.layer_pattern is None:
            return "ssm" if self.family == "ssm" else "attn"
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    def layer_is_moe(self, layer_idx: int) -> bool:
        m = self.moe
        return m.enabled and (layer_idx % m.moe_every == m.moe_offset)

    @property
    def pattern_period(self) -> int:
        """Length of the repeating layer-type period (for scan grouping)."""
        p = len(self.layer_pattern) if self.layer_pattern else 1
        if self.moe.enabled and self.moe.moe_every > 1:
            # need lcm(pattern, moe_every) so every scanned block is uniform
            import math
            p = math.lcm(p, self.moe.moe_every)
        return p

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        assert self.num_layers % self.pattern_period == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern period {self.pattern_period}")
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.moe.enabled:
            assert self.moe.top_k <= self.moe.num_experts
        self.kernels.validate()


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    """Optimizer / local-training hyper-parameters (paper A2.2)."""

    learning_rate: float = 1.5e-4
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    batch_size: int = 16
    local_epochs: int = 1
    seq_len: int = 128


@dataclass(frozen=True)
class FederatedConfig:
    """Server-side orchestration hyper-parameters."""

    num_clients: int = 4
    rounds: int = 2
    participation: float = 1.0        # client sampling rate p
    dirichlet_alpha: float = 5.0      # data heterogeneity
    temperature: int = 2              # t in Eq. 6
    method: str = "flame"             # flame|trivial|hlora|flexlora
    rescaler: str = "learnable"       # learnable|static|none
    seed: int = 0
    # round execution engine: "batched" runs each budget cohort's local
    # training in one compiled computation (vmap/lax.map over clients);
    # "looped" is the sequential per-client reference oracle.
    round_engine: str = "batched"     # batched|looped
    # batched-engine lowering: "vmap" batches clients into one program,
    # "map" (lax.map) runs them sequentially inside one compiled call —
    # the fallback when C × local batch does not fit memory.
    cohort_backend: str = "vmap"      # vmap|map
    # round-loop driver: "host" iterates run_round in Python (the oracle —
    # one device program per cohort per round); "device" folds the whole
    # multi-round loop, per-round subsampled cohorts AND streaming FLAME
    # aggregation into ONE lax.scan program (FLAME only — see
    # federated/server.py §device driver).
    round_driver: str = "host"        # host|device
    # device driver: rounds per device program segment — the driver syncs
    # to the host every `checkpoint_every` rounds to stream a resumable
    # checkpoint (run(checkpoint_to=...)); with no checkpoint target the
    # whole run is one program.
    checkpoint_every: int = 1
