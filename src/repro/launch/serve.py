"""Batched serving launcher (decode loop on the production mesh).

``--local`` runs a real prefill + autoregressive decode loop on this
host's devices with a reduced config, demonstrating FLAME's reduced-k
deployment; without ``--local`` it builds the sharded serve step for the
production mesh (use repro.launch.dryrun in this offline container).

  PYTHONPATH=src python -m repro.launch.serve --local \
      --arch olmoe-1.3b-6.9b --k 1 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import INPUT_SHAPES, ShapeConfig
from ..configs.registry import get_config
from ..models import model as model_lib
from . import steps as steps_lib
from .mesh import make_local_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1.3b-6.9b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--k", type=int, default=None,
                    help="activated experts at serving time (FLAME)")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if not args.local:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch, "full")
        shape = INPUT_SHAPES[args.shape]
        with mesh:
            bundle = steps_lib.build_serve(cfg, shape, mesh, k=args.k)
            print(f"serve_step for {cfg.name} × {shape.name} on "
                  f"{mesh.devices.shape}: cache "
                  f"{bundle.meta['cache_bytes'] / 2 ** 30:.1f} GiB global, "
                  f"k={bundle.meta['k']}")
            print("lowering...")
            compiled = bundle.fn.lower(*bundle.args).compile()
            mem = compiled.memory_analysis()
            print(f"compiled; {mem.temp_size_in_bytes / 2 ** 30:.2f} GiB "
                  f"temp/device — ready for real hardware")
        return

    # ---- local demo: prefill + decode a batch of requests ----
    cfg = get_config(args.arch, "smoke")
    k = args.k if args.k is not None else (cfg.moe.top_k or None)
    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(key, cfg)
    B, prompt_len = 4, 16
    total = prompt_len + args.new_tokens
    shape_tok = ((B, prompt_len, cfg.num_codebooks) if cfg.num_codebooks
                 else (B, prompt_len))
    prompts = jax.random.randint(key, shape_tok, 0, cfg.vocab_size)

    t0 = time.time()
    logits, cache = model_lib.prefill(cfg, params, prompts, k=k,
                                      cache_len=total)
    decode = jax.jit(
        lambda p, c, t, pos: model_lib.decode_step(cfg, p, c, t, pos, k=k))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if cfg.num_codebooks:
        tok = tok.reshape(B, 1, cfg.num_codebooks)
    out = [tok]
    for i in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok, prompt_len + i)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if cfg.num_codebooks:
            tok = tok.reshape(B, 1, cfg.num_codebooks)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print(f"{cfg.name} (k={k}): decoded {gen.shape} in "
          f"{time.time() - t0:.2f}s")
    print("sample token ids:", np.asarray(gen)[0].ravel()[:16].tolist())


if __name__ == "__main__":
    main()
