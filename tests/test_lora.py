"""LoRA adapter tests: no-op init, merge equivalence, rank surgery, SVD."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_dense, tiny_moe
from repro.core import lora as L
from repro.models import model as M


def _setup(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    params = M.init_params(key, cfg)
    lora = L.init_lora(jax.random.fold_in(key, 1), cfg, params)
    return key, params, lora


def test_fresh_adapter_is_noop():
    cfg = tiny_dense()
    key, params, lora = _setup(cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    base, _ = M.forward(cfg, params, toks)
    with_lora, _ = M.forward(cfg, params, toks,
                             trainable={"lora": lora})
    np.testing.assert_allclose(np.asarray(base), np.asarray(with_lora),
                               rtol=1e-6, atol=1e-6)


def test_expert_adapters_inherit_expert_axis():
    cfg = tiny_moe()
    _, params, lora = _setup(cfg)
    e = lora["blocks"]["pos0"]["moe"]["experts"]
    E = cfg.moe.num_experts
    assert e["w1"]["a"].shape[1] == E       # (n_periods, E, d, r)
    assert e["w1"]["a"].shape[-1] == cfg.lora.rank
    assert e["w2"]["b"].shape[-2] == cfg.lora.rank


def test_merge_into_params_matches_unmerged():
    cfg = tiny_dense()
    key, params, lora = _setup(cfg)
    # give B nonzero values so the adapter actually does something
    lora = jax.tree.map(
        lambda t: t + 0.02 * jax.random.normal(key, t.shape, t.dtype), lora)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    unmerged, _ = M.forward(cfg, params, toks, trainable={"lora": lora})
    merged = L.merge_into_params(params, lora, cfg.lora.scale)
    merged_out, _ = M.forward(cfg, merged, toks)
    np.testing.assert_allclose(np.asarray(unmerged), np.asarray(merged_out),
                               rtol=2e-4, atol=2e-4)


def test_truncate_then_pad_roundtrip():
    cfg = tiny_dense()
    _, params, lora = _setup(cfg)
    r = cfg.lora.rank
    small = L.truncate_rank(lora, 2)
    back = L.pad_rank(small, r)
    pair0 = lora["blocks"]["pos0"]["attn"]["wq"]
    pad0 = back["blocks"]["pos0"]["attn"]["wq"]
    assert pad0["a"].shape == pair0["a"].shape
    np.testing.assert_allclose(np.asarray(pad0["a"][..., :2]),
                               np.asarray(pair0["a"][..., :2]))
    np.testing.assert_allclose(np.asarray(pad0["a"][..., 2:]), 0.0)


def test_svd_refactor_reconstructs_low_rank_delta():
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (1, 16, 3))
    b = jax.random.normal(jax.random.fold_in(key, 1), (1, 3, 16))
    scale = 0.5
    delta = L.merge_delta({"x": {"a": a, "b": b}}, scale)
    re = L.svd_refactor(delta, rank=3, scale=scale)
    recon = L.merge_delta(re, scale)
    np.testing.assert_allclose(np.asarray(recon["x"]),
                               np.asarray(delta["x"]), rtol=1e-4, atol=1e-5)
    # rank-2 refactor = best rank-2 approximation (error no worse than
    # truncating the true singular spectrum)
    re2 = L.svd_refactor(delta, rank=2, scale=scale)
    recon2 = L.merge_delta(re2, scale)
    s = np.linalg.svd(np.asarray(delta["x"][0]), compute_uv=False)
    err = np.linalg.norm(np.asarray(recon2["x"][0] - delta["x"][0]))
    np.testing.assert_allclose(err, s[2], rtol=1e-3)


def test_rescaler_init_values():
    cfg = tiny_moe()
    r = L.init_rescalers(cfg, k_client=1)
    # top_k=2, k_i=1 -> init at k/k_i = 2
    np.testing.assert_allclose(np.asarray(r["pos0"]), 2.0)
    assert L.init_rescalers(cfg, k_client=2, mode="none") is None
    dense = tiny_dense()
    assert L.init_rescalers(dense, 1) is None
