#!/usr/bin/env python
"""Coverage threshold gate for the hot subsystems (`make coverage`).

Parses a Cobertura ``coverage.xml`` (as written by
``pytest --cov=repro --cov-report=xml``) and fails if the aggregate line
coverage of any named package subtree falls below the floor.  Gating only
the correctness-critical subtrees (kernels, serving) keeps the signal
sharp: a PR that lands untested dispatch or pool code fails CI even when
repo-wide coverage looks fine.

Usage:
    PYTHONPATH=src python -m pytest -q --cov=repro --cov-report=xml
    python tools/coverage_gate.py coverage.xml --min 70 \\
        repro/kernels repro/serving
"""
from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET


def normalise(filename: str) -> str:
    """Class filenames may be relative to the package source dir
    ("kernels/backend.py") or to the repo ("src/repro/kernels/..."):
    normalise both to "repro/...."."""
    f = filename.replace("\\", "/")
    if "repro/" in f:
        return "repro/" + f.split("repro/", 1)[1]
    return "repro/" + f


def gate(xml_path: str, targets: list, floor: float) -> int:
    root = ET.parse(xml_path).getroot()
    stats = {t: [0, 0] for t in targets}              # covered, total
    for cls in root.iter("class"):
        nf = normalise(cls.get("filename", ""))
        owners = [t for t in targets
                  if nf == t or nf.startswith(t.rstrip("/") + "/")]
        if not owners:
            continue
        for line in cls.iter("line"):
            hit = int(line.get("hits", "0")) > 0
            for t in owners:
                stats[t][0] += hit
                stats[t][1] += 1
    failed = False
    for t in targets:
        covered, total = stats[t]
        if total == 0:
            print(f"coverage-gate: {t}: NO LINES FOUND in {xml_path} "
                  f"(wrong --cov target or path?)")
            failed = True
            continue
        pct = 100.0 * covered / total
        verdict = "ok" if pct >= floor else f"BELOW FLOOR {floor:.0f}%"
        print(f"coverage-gate: {t}: {pct:.1f}% "
              f"({covered}/{total} lines) — {verdict}")
        failed |= pct < floor
    return 1 if failed else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("xml", help="Cobertura coverage.xml from pytest-cov")
    ap.add_argument("targets", nargs="+",
                    help="package subtrees to gate, e.g. repro/kernels")
    ap.add_argument("--min", type=float, default=70.0,
                    help="minimum aggregate line coverage percent per "
                         "subtree (a ratchet floor, not a target)")
    args = ap.parse_args()
    return gate(args.xml, args.targets, args.min)


if __name__ == "__main__":
    sys.exit(main())
