"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060]"""
from .base import LoRAConfig, ModelConfig, SSMConfig

FULL = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                      # pure mamba blocks, no FFN
    vocab_size=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, n_groups=1),
    lora=LoRAConfig(rank=16, target_attn=False, target_ffn=False,
                    target_expert=False, target_ssm=True),
    source="arXiv:2405.21060",
)

SMOKE = FULL.replace(
    name="mamba2-smoke",
    num_layers=2,
    d_model=256,
    ssm=SSMConfig(d_state=32, head_dim=32, expand=2, conv_width=4,
                  chunk_size=64, n_groups=1),
    vocab_size=512,
    lora=LoRAConfig(rank=4, target_attn=False, target_ffn=False,
                    target_expert=False, target_ssm=True),
)
