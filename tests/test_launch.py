"""Distribution-layer tests that run on the real (single-CPU) device:
sharding rules are structurally valid, step builders execute end-to-end on
a 1×1 mesh with the production axis names, FLOPs model reproduces Table 1."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import tiny_moe
from repro.configs.base import INPUT_SHAPES, ShapeConfig, TrainConfig
from repro.configs.registry import ASSIGNED_ARCHS, get_config
from repro.core import flops as F
from repro.launch import sharding as shd
from repro.launch import specs as specs_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh


# ---------------------------------------------------------------- specs

@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_rank_matches_leaves(arch):
    cfg = get_config(arch, "full")
    mesh = make_local_mesh()
    a = specs_lib.abstract_params(cfg)
    spec = shd.param_specs(cfg, a, mesh)
    flat_a = jax.tree_util.tree_leaves_with_path(a)
    flat_s = jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_a) == len(flat_s)
    for (path, leaf), s in zip(flat_a, flat_s):
        assert len(s) <= len(leaf.shape), (path, leaf.shape, s)


def test_input_specs_shapes():
    cfg = get_config("qwen3-1.7b", "full")
    sp = specs_lib.input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096)
    assert sp["mask"].dtype == jnp.float32
    dec = specs_lib.input_specs(cfg, INPUT_SHAPES["decode_32k"])
    assert dec["tokens"].shape == (128, 1)       # ONE token per request
    audio = get_config("musicgen-large", "full")
    sp_a = specs_lib.input_specs(audio, INPUT_SHAPES["train_4k"])
    assert sp_a["tokens"].shape == (256, 4096, 4)     # EnCodec codebooks


def test_abstract_state_is_allocation_free():
    cfg = get_config("llama3-405b", "full")
    a = specs_lib.abstract_params(cfg)
    total = specs_lib.state_bytes(a)
    assert total > 700e9                 # 405B bf16 ≈ 810 GB
    for leaf in jax.tree.leaves(a):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_batch_spec_divisibility():
    mesh = make_local_mesh()
    # PartitionSpec normalises the 1-tuple ("data",) to "data"
    assert shd.batch_spec(8, mesh)[0] in ("data", ("data",))
    s = shd.batch_spec(1, mesh)
    assert s[0] in ("data", ("data",), None)  # 1 % 1 == 0 -> still shardable


# ---------------------------------------------------------------- steps

SMOKE_SHAPE_TRAIN = ShapeConfig("smoke_train", seq_len=32, global_batch=4,
                                kind="train")
SMOKE_SHAPE_DECODE = ShapeConfig("smoke_decode", seq_len=64, global_batch=2,
                                 kind="decode")
SMOKE_SHAPE_PREFILL = ShapeConfig("smoke_prefill", seq_len=32,
                                  global_batch=2, kind="prefill")


def _concrete(tree, key=0):
    k = jax.random.PRNGKey(key)
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            out.append(jnp.zeros(leaf.shape, leaf.dtype))
        else:
            out.append(0.01 * jax.random.normal(
                jax.random.fold_in(k, i), leaf.shape).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def test_train_step_runs_on_local_mesh():
    cfg = tiny_moe()
    mesh = make_local_mesh()
    with mesh:
        bundle = steps_lib.build_train(cfg, SMOKE_SHAPE_TRAIN, mesh,
                                       n_micro=2, tc=TrainConfig())
        args = [_concrete(a, i) for i, a in enumerate(bundle.args)]
        new_tr, new_opt, metrics = bundle.fn(*args)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt.step) == 1
    # MoE arch reports activation counts for FLAME aggregation
    assert metrics["counts"], "train step must surface expert counts"
    total = sum(float(v.sum()) for v in metrics["counts"].values())
    # 2 layers MoE × (B·S tokens) × k=2
    assert total == 2 * 4 * 32 * 2


def test_serve_step_runs_on_local_mesh():
    cfg = tiny_moe()
    mesh = make_local_mesh()
    with mesh:
        bundle = steps_lib.build_serve(cfg, SMOKE_SHAPE_DECODE, mesh, k=1)
        args = [_concrete(a, i) for i, a in enumerate(bundle.args)]
        args[4] = jnp.asarray(5, jnp.int32)
        logits, cache = bundle.fn(*args)
    assert logits.shape[:2] == (2, 1)
    assert not bool(jnp.isnan(logits).any())


def test_prefill_step_runs_on_local_mesh():
    cfg = tiny_moe()
    mesh = make_local_mesh()
    with mesh:
        bundle = steps_lib.build_prefill(cfg, SMOKE_SHAPE_PREFILL, mesh)
        args = [_concrete(a, i) for i, a in enumerate(bundle.args)]
        logits, cache = bundle.fn(*args)
    assert logits.shape[:2] == (2, 1)
    leaves = jax.tree.leaves(cache)
    assert leaves and all(l.shape[1] == 2 for l in leaves)


def test_knob_autoselection_scales_with_model():
    mesh = make_local_mesh()
    small = get_config("qwen3-1.7b", "full")
    big = get_config("llama3-405b", "full")
    shape = INPUT_SHAPES["train_4k"]
    k_small = steps_lib.choose_train_knobs(small, shape, mesh)
    k_big = steps_lib.choose_train_knobs(big, shape, mesh)
    assert k_big["n_micro"] >= k_small["n_micro"] or \
        k_big["act_mode"] != "batch"


# ---------------------------------------------------------------- flops

def test_table1_flame_grid_matches_paper():
    """Paper Table 1 / §3.2: FLAME β-grid = {153.6, 179.2, 230.4, 332.8} B
    FLOPs for k = {1, 2, 4, 8} (2·P_a·T convention, T = 128·batch...);
    our analytic model must land within 5% of every row."""
    cfg = get_config("olmoe-1.3b-6.9b", "full")
    paper = {1: 153.6e9, 2: 179.2e9, 4: 230.4e9, 8: 332.8e9}
    for k, want in paper.items():
        got = F.flops_paper_convention(cfg, tokens=128, k=k)
        assert abs(got - want) / want < 0.05, (k, got / 1e9, want / 1e9)


def test_table1_rank_compression_barely_moves_flops():
    """The paper's central negative finding: rank compression changes FLOPs
    by <2% across the full β1→β4 range."""
    cfg = get_config("olmoe-1.3b-6.9b", "full")
    f_hi = F.flops_paper_convention(cfg, 128, k=8, lora_rank=20)
    f_lo = F.flops_paper_convention(cfg, 128, k=8, lora_rank=6)
    assert (f_hi - f_lo) / f_hi < 0.02
    # while FLAME's expert reduction halves it
    f_flame = F.flops_paper_convention(cfg, 128, k=1, lora_rank=20)
    assert f_flame / f_hi < 0.55


def test_active_params_match_paper():
    """OLMoE: P=6.9B total / P_a=1.3B at k=8 (±10%)."""
    cfg = get_config("olmoe-1.3b-6.9b", "full")
    p = F.count_params(cfg, k=8)
    assert abs(p["total"] - 6.9e9) / 6.9e9 < 0.10
    assert abs(p["active"] - 1.3e9) / 1.3e9 < 0.10
