"""Kernel-backend dispatch: one switch between Pallas kernels and jnp refs.

This is the layer that turns the kernel suite from a validated appendix into
the actual training hot path.  Model code never imports a Pallas kernel
directly — it asks this module for an op, passing the model's
:class:`repro.configs.base.KernelConfig`, and gets back either the fused
Pallas implementation or the pure-jnp reference:

==================  =======================  ============================
``backend=``        on TPU                   off TPU (CPU/GPU)
==================  =======================  ============================
``"auto"``          Pallas (compiled)        jnp reference
``"pallas"``        Pallas (compiled, or     Pallas **interpreter**
                    interpreter if
                    ``interpret=True``)
``"reference"``     jnp reference            jnp reference
==================  =======================  ============================

Two further dispatch rules live at the call sites (documented in
docs/kernels.md):

* attention falls back to the blockwise jnp path whenever
  ``attn_logit_softcap > 0`` (the Pallas kernel does not implement softcap)
  and on the decode path (single-token attention has no flash structure);
* a matrix with no LoRA adapter (``lp is None``) uses the plain einsum —
  the fused kernel only pays off when the bypass rides along.

Differentiability
-----------------
``pallas_call`` has no autodiff rule, so every op here is wrapped in
``jax.custom_vjp``: the **forward** runs the Pallas kernel, the **backward**
is reference math (exact analytic formulas for the linear LoRA ops; the vjp
of the jnp oracle for attention and routing).  Gradients through a
``backend="pallas"`` model are therefore the *reference* gradients evaluated
at kernel-forward primals — which is exactly what the CI parity suite
(tests/test_backend.py) asserts.  A dedicated Pallas backward kernel for
flash attention is future work; until then the attention backward
re-materialises the (S, S) score matrix like the oracle does.

Block sizes are chosen per call as the largest divisor of each dim below the
MXU-friendly target; shapes whose best divisor is tiny (prime dims) fall
back to the reference implementation rather than dispatching a degenerate
near-1-wide grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import KernelConfig
from . import ref
from .flash_attention import flash_attention as _flash_pallas
from .lora_matmul import lora_matmul as _lora_pallas
from .lora_matmul import lora_matmul_experts as _lora_experts_pallas
from .ops import on_tpu
from .ragged_dispatch import ragged_combine as _ragged_combine_pallas
from .ragged_dispatch import ragged_expert_matmul as _ragged_mm_pallas
from .ragged_dispatch import ragged_gather as _ragged_gather_pallas
from .topk_router import topk_router as _router_pallas

_F32 = jnp.float32


# ==========================================================================
# resolution
# ==========================================================================

def resolve(kcfg: KernelConfig | None) -> str:
    """Resolve ``backend="auto"`` against the runtime platform."""
    if kcfg is None:
        return "reference"
    if kcfg.backend == "auto":
        return "pallas" if on_tpu() else "reference"
    assert kcfg.backend in ("pallas", "reference"), kcfg.backend
    return kcfg.backend


def use_pallas(kcfg: KernelConfig | None) -> bool:
    return resolve(kcfg) == "pallas"


def resolve_interpret(kcfg: KernelConfig) -> bool:
    """Pallas only compiles on TPU — everywhere else the interpreter runs
    the kernel; ``interpret=True`` forces it even on TPU (escape hatch)."""
    return bool(kcfg.interpret) or not on_tpu()


def _block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= ``target``."""
    b = min(dim, target)
    while dim % b:
        b -= 1
    return max(b, 1)


# A dim whose largest divisor under the target is tiny (prime seq lens etc.)
# would produce a pathological near-1-wide Pallas grid.  Such shapes fall
# back to the reference implementation instead of dispatching — no silent
# performance cliffs.
_BLOCK_FLOOR = 8


def _degenerate(dim: int, target: int) -> bool:
    return dim >= _BLOCK_FLOOR and _block(dim, target) < _BLOCK_FLOOR


def flash_blocks_ok(seq_len: int) -> bool:
    """Whether the flash kernel gets non-degenerate blocks for this S
    (checked at the attention call site alongside the softcap rule)."""
    return not _degenerate(seq_len, 128)


# ==========================================================================
# fused LoRA matmul (2-D): y = x @ W + (x @ A) @ B * scale
# ==========================================================================

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _lora_matmul_p(scale, interpret, x, w, a, b):
    M, K = x.shape
    N = w.shape[1]
    return _lora_pallas(x, w, a, b, scale=scale,
                        block_m=_block(M, 256), block_n=_block(N, 256),
                        block_k=_block(K, 256), interpret=interpret)


def _lora_matmul_fwd(scale, interpret, x, w, a, b):
    return _lora_matmul_p(scale, interpret, x, w, a, b), (x, w, a, b)


def _lora_matmul_bwd(scale, interpret, res, g):
    # exact vjp of ref.lora_matmul_ref (fp32 math, single output cast)
    x, w, a, b = res
    gf, xf, wf, af, bf = (t.astype(_F32) for t in (g, x, w, a, b))
    gb = gf @ bf.T                                    # (M, r)
    dx = (gf @ wf.T + (gb @ af.T) * scale).astype(x.dtype)
    dw = (xf.T @ gf).astype(w.dtype)
    da = ((xf.T @ gb) * scale).astype(a.dtype)
    db = (((xf @ af).T @ gf) * scale).astype(b.dtype)
    return dx, dw, da, db


_lora_matmul_p.defvjp(_lora_matmul_fwd, _lora_matmul_bwd)


def lora_matmul(kcfg: KernelConfig, x, w, a, b, *, scale: float):
    """Differentiable fused LoRA matmul.  x (M,K); w (K,N); a (K,r); b (r,N)."""
    M, K = x.shape
    N = w.shape[1]
    if use_pallas(kcfg) and not (_degenerate(M, 256) or _degenerate(N, 256)
                                 or _degenerate(K, 256)):
        return _lora_matmul_p(float(scale), resolve_interpret(kcfg),
                              x, w, a, b)
    return ref.lora_matmul_ref(x, w, a, b, scale)


# ==========================================================================
# fused LoRA matmul, stacked per expert: x (E,C,K) w (E,K,N) a (E,K,r) b (E,r,N)
# ==========================================================================

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _lora_experts_p(scale, interpret, x, w, a, b):
    E, C, K = x.shape
    N = w.shape[-1]
    return _lora_experts_pallas(x, w, a, b, scale=scale,
                                block_m=_block(C, 128), block_n=_block(N, 256),
                                block_k=_block(K, 256), interpret=interpret)


def _lora_experts_fwd(scale, interpret, x, w, a, b):
    return _lora_experts_p(scale, interpret, x, w, a, b), (x, w, a, b)


def _lora_experts_bwd(scale, interpret, res, g):
    x, w, a, b = res
    gf, xf, wf, af, bf = (t.astype(_F32) for t in (g, x, w, a, b))
    gb = jnp.einsum("ecn,ern->ecr", gf, bf)           # g @ B^T per expert
    xa = jnp.einsum("eck,ekr->ecr", xf, af)           # x @ A  per expert
    dx = (jnp.einsum("ecn,ekn->eck", gf, wf)
          + jnp.einsum("ecr,ekr->eck", gb, af) * scale).astype(x.dtype)
    dw = jnp.einsum("eck,ecn->ekn", xf, gf).astype(w.dtype)
    da = (jnp.einsum("eck,ecr->ekr", xf, gb) * scale).astype(a.dtype)
    db = (jnp.einsum("ecr,ecn->ern", xa, gf) * scale).astype(b.dtype)
    return dx, dw, da, db


_lora_experts_p.defvjp(_lora_experts_fwd, _lora_experts_bwd)


def lora_matmul_experts(kcfg: KernelConfig, x, w, a, b, *, scale: float):
    """Differentiable stacked per-expert fused LoRA matmul (3-D operands)."""
    E, C, K = x.shape
    N = w.shape[-1]
    if use_pallas(kcfg) and not (_degenerate(C, 128) or _degenerate(N, 256)
                                 or _degenerate(K, 256)):
        return _lora_experts_p(float(scale), resolve_interpret(kcfg),
                               x, w, a, b)
    return ref.lora_matmul_experts_ref(x, w, a, b, scale)


# ==========================================================================
# flash attention (model layout: q (B,S,H,D); k,v (B,S,KV,D))
# ==========================================================================

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _flash_p(causal, window, interpret, q, k, v):
    # kernel layout is (B, H, S, D)
    S = q.shape[2]
    bq = _block(S, 128)
    bk = _block(S, 128)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         block_q=bq, block_k=bk, interpret=interpret)


def _flash_fwd(causal, window, interpret, q, k, v):
    return _flash_p(causal, window, interpret, q, k, v), (q, k, v)


def _flash_bwd(causal, window, interpret, res, g):
    # vjp of the jnp oracle at the same primals: reference gradients.  This
    # re-materialises the (S, S) scores — acceptable until a Pallas flash
    # backward lands (see docs/kernels.md).
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window), q, k, v)
    return vjp(g)


_flash_p.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(kcfg: KernelConfig, q, k, v, *, causal: bool = True,
                    window: int = 0):
    """Differentiable flash attention in the MODEL layout:
    q (B,S,H,D); k,v (B,S,KV,D) -> (B,S,H,D).

    Only called on the pallas path — the reference path is the blockwise
    ``repro.models.attention.flash_attention_jnp`` (which also owns the
    softcap and decode fallbacks, see its module docstring)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _flash_p(causal, window, resolve_interpret(kcfg), qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)


# ==========================================================================
# top-k router
# ==========================================================================

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _router_p(k, interpret, logits):
    T = logits.shape[0]
    return _router_pallas(logits, k, block_t=_block(T, 1024),
                          interpret=interpret)


def _router_fwd(k, interpret, logits):
    return _router_p(k, interpret, logits), (logits,)


def _router_bwd(k, interpret, res, g):
    (logits,) = res
    _, vjp = jax.vjp(lambda l: ref.topk_router_ref(l, k), logits)
    return vjp(g)


_router_p.defvjp(_router_fwd, _router_bwd)


def router(kcfg: KernelConfig, logits, k: int):
    """Differentiable fused router.  logits (T,E) ->
    (weights (T,E) f32, mask (T,E) f32, counts (E,) f32)."""
    if use_pallas(kcfg) and not _degenerate(logits.shape[0], 1024):
        return _router_p(k, resolve_interpret(kcfg), logits)
    return ref.topk_router_ref(logits, k)


# ==========================================================================
# ragged (sort-based) MoE dispatch: gather / grouped matmul / combine
# ==========================================================================
# The three ops behind ``apply_moe(dispatch="ragged")`` — see
# kernels/ragged_dispatch.py for the layout and docs/kernels.md for the
# dispatch-mode trade-offs.  The plan arrays (src/valid/block_expert/rows)
# are int32 and carry no gradient: the backward rules return ``None``
# cotangents for them and reference-math gradients for the float operands.

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ragged_gather_p(interpret, x, src, valid):
    return _ragged_gather_pallas(x, src, valid, interpret=interpret)


def _ragged_gather_fwd(interpret, x, src, valid):
    return _ragged_gather_p(interpret, x, src, valid), (x, src, valid)


def _ragged_gather_bwd(interpret, res, g):
    x, src, valid = res
    _, vjp = jax.vjp(lambda x_: ref.ragged_gather_ref(x_, src, valid), x)
    return vjp(g)[0], None, None


_ragged_gather_p.defvjp(_ragged_gather_fwd, _ragged_gather_bwd)


def ragged_gather(kcfg: KernelConfig, x, src, valid):
    """Differentiable ragged dispatch gather: x (T,D); src, valid (N,)
    int32 -> xs (N,D) with ``xs[i] = x[src[i]] * valid[i]``.

    No degenerate-shape guard needed: the grid is always N/8 (the plan
    pads the buffer to 8-row blocks) and rows copy at full width."""
    if use_pallas(kcfg):
        return _ragged_gather_p(resolve_interpret(kcfg), x, src, valid)
    return ref.ragged_gather_ref(x, src, valid)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _ragged_mm_p(scale, interpret, xs, be, w):
    return _ragged_mm_pallas(xs, be, w, scale=scale, interpret=interpret)


def _ragged_mm_fwd(scale, interpret, xs, be, w):
    return _ragged_mm_p(scale, interpret, xs, be, w), (xs, be, w)


def _ragged_mm_bwd(scale, interpret, res, g):
    xs, be, w = res
    _, vjp = jax.vjp(
        lambda xs_, w_: ref.ragged_expert_matmul_ref(xs_, be, w_), xs, w)
    dxs, dw = vjp(g)
    return dxs, None, dw


_ragged_mm_p.defvjp(_ragged_mm_fwd, _ragged_mm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _ragged_mm_lora_p(scale, interpret, xs, be, w, a, b):
    return _ragged_mm_pallas(xs, be, w, a, b, scale=scale,
                             interpret=interpret)


def _ragged_mm_lora_fwd(scale, interpret, xs, be, w, a, b):
    return (_ragged_mm_lora_p(scale, interpret, xs, be, w, a, b),
            (xs, be, w, a, b))


def _ragged_mm_lora_bwd(scale, interpret, res, g):
    xs, be, w, a, b = res
    _, vjp = jax.vjp(
        lambda xs_, w_, a_, b_: ref.ragged_expert_matmul_ref(
            xs_, be, w_, a_, b_, scale), xs, w, a, b)
    dxs, dw, da, db = vjp(g)
    return dxs, None, dw, da, db


_ragged_mm_lora_p.defvjp(_ragged_mm_lora_fwd, _ragged_mm_lora_bwd)


def ragged_expert_matmul(kcfg: KernelConfig, xs, block_expert, w,
                         a=None, b=None, *, scale: float = 0.0):
    """Differentiable grouped (segment) LoRA matmul over the ragged
    buffer: xs (N,K); block_expert (N//bm,) int32; w (E,K,H); optional
    per-expert LoRA a (E,K,r) / b (E,r,H).  Contraction/output dims with
    tiny divisors fall back to the reference, like every other matmul op
    here — no degenerate compiled tiles."""
    K = xs.shape[1]
    H = w.shape[-1]
    if use_pallas(kcfg) and not (_degenerate(K, 256) or _degenerate(H, 256)):
        interp = resolve_interpret(kcfg)
        if a is None:
            return _ragged_mm_p(float(scale), interp, xs, block_expert, w)
        return _ragged_mm_lora_p(float(scale), interp, xs, block_expert,
                                 w, a, b)
    return ref.ragged_expert_matmul_ref(xs, block_expert, w, a, b, scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ragged_combine_p(interpret, eo, rows, wrank):
    return _ragged_combine_pallas(eo, rows, wrank, interpret=interpret)


def _ragged_combine_fwd(interpret, eo, rows, wrank):
    return _ragged_combine_p(interpret, eo, rows, wrank), (eo, rows, wrank)


def _ragged_combine_bwd(interpret, res, g):
    eo, rows, wrank = res
    _, vjp = jax.vjp(
        lambda eo_, w_: ref.ragged_combine_ref(eo_, rows, w_), eo, wrank)
    deo, dwrank = vjp(g)
    return deo, None, dwrank


_ragged_combine_p.defvjp(_ragged_combine_fwd, _ragged_combine_bwd)


def ragged_combine(kcfg: KernelConfig, eo, rows, wrank):
    """Differentiable ragged combine: eo (N,D); rows (T,max_k) int32;
    wrank (T,max_k) -> out (T,D) = sum_j wrank[t,j] * eo[rows[t,j]]."""
    if use_pallas(kcfg) and not _degenerate(rows.shape[0], 8):
        return _ragged_combine_p(resolve_interpret(kcfg), eo, rows, wrank)
    return ref.ragged_combine_ref(eo, rows, wrank)
