"""Consolidate dry-run JSON records into the EXPERIMENTS.md roofline table.

  PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(dirpath: str) -> List[Dict]:
    out = []
    for name in sorted(os.listdir(dirpath)):
        if name.endswith(".json"):
            with open(os.path.join(dirpath, name)) as f:
                out.append(json.load(f))
    return out


def advice(r: Dict) -> str:
    """One sentence: what would move the dominant roofline term down."""
    rl = r["roofline"]
    b = rl["bottleneck"]
    moe = "moe" in r["arch"] or r["arch"].startswith(("jamba", "olmoe"))
    kind = ("train" if r["shape"].startswith("train") else
            "prefill" if r["shape"].startswith("prefill") else "decode")
    if b == "collective":
        if moe:
            return ("shard_map all-to-all of slot payloads instead of "
                    "GSPMD-inferred gathers around dispatch/combine")
        if kind == "train":
            return ("data-heavier mesh (64×4) — TP-AR volume ∝ local batch "
                    "(§Perf H2 it.5: −39%)")
        return ("overlap weight all-gathers with the layer compute "
                "(double-buffered prefetch)")
    if b == "memory":
        if kind == "decode":
            return ("int8 KV cache halves the floor; grouped-query width "
                    "already minimal")
        return "larger microbatch raises arithmetic intensity per weight read"
    return "already compute-bound — kernel-level (MXU utilisation) work only"


def fmt_row(r: Dict) -> str:
    rl = r["roofline"]
    mem = r["memory"]["peak_gb"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{rl['t_compute_ms']:.1f} | {rl['t_memory_ms']:.1f} | "
            f"{rl['t_collective_ms']:.1f} | **{rl['bottleneck']}** | "
            f"{mem:.1f} | {rl['model_gflops'] / 1e3:.1f} | "
            f"{rl['useful_frac']:.2f} | {rl['mfu'] * 100:.1f}% | "
            f"{advice(r)} |")


def main() -> None:
    dirpath = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load_records(dirpath)
    ok = [r for r in recs if r.get("ok")]
    fail = [r for r in recs if not r.get("ok")]

    print("| arch | shape | mesh | t_comp ms | t_mem ms | t_coll ms | "
          "bottleneck | HBM GB/dev | model TFLOPs | useful | roofline-MFU | "
          "what moves the dominant term |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                     if r["shape"] in SHAPE_ORDER else 9, r["mesh"])
    for r in sorted(ok, key=key):
        print(fmt_row(r))

    if fail:
        print(f"\nFAILED ({len(fail)}):")
        for r in fail:
            print(f"  {r['arch']} × {r['shape']} × {r['mesh']}: "
                  f"{r.get('error', '?')}")

    over = [r for r in ok if r["memory"]["peak_gb"] > 16.0]
    if over:
        print(f"\nOVER 16 GB/device HBM budget ({len(over)}):")
        for r in sorted(over, key=lambda r: -r["memory"]["peak_gb"]):
            print(f"  {r['arch']} × {r['shape']} × {r['mesh']}: "
                  f"{r['memory']['peak_gb']:.1f} GB  (knobs {r['meta']})")


if __name__ == "__main__":
    main()
