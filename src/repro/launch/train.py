"""Production training launcher.

Two modes:

  * ``--local``  — run real federated fine-tuning on this host's devices
    (CPU in this container) at a reduced scale; this is what the e2e
    example drives.
  * default      — build the production mesh (requires a real multi-host
    TPU slice, or the dry-run's forced host-device count), bind the
    sharded train step for ``--arch``, and run ``--steps`` steps on
    synthetic on-device batches.  In this offline container use
    ``repro.launch.dryrun`` instead, which stops after compile.

  PYTHONPATH=src python -m repro.launch.train --local --arch olmoe-1.3b-6.9b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import INPUT_SHAPES, ShapeConfig, TrainConfig
from ..configs.registry import get_config
from . import steps as steps_lib
from .mesh import make_local_mesh, make_production_mesh


def synthetic_batch(cfg, shape, key):
    tshape = ((shape.global_batch, shape.seq_len, cfg.num_codebooks)
              if cfg.num_codebooks else (shape.global_batch, shape.seq_len))
    tokens = jax.random.randint(key, tshape, 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((shape.global_batch, shape.seq_len), jnp.float32)
    return tokens, labels, mask


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1.3b-6.9b")
    ap.add_argument("--variant", default=None,
                    help="full|smoke|swa (default: smoke for --local)")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--k", type=int, default=None,
                    help="FLAME client expert budget k_i")
    args = ap.parse_args()

    if args.local:
        mesh = make_local_mesh()
        cfg = get_config(args.arch, args.variant or "smoke")
        shape = ShapeConfig("local_train", seq_len=64, global_batch=8,
                            kind="train")
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch, args.variant or "full")
        shape = INPUT_SHAPES[args.shape]

    key = jax.random.PRNGKey(0)
    with mesh:
        bundle = steps_lib.build_train(cfg, shape, mesh, k=args.k,
                                       tc=TrainConfig())
        print(f"{cfg.name} × {shape.name} on {mesh.devices.shape}: "
              f"knobs={bundle.meta}")
        # materialise real state (local mode only — production state comes
        # from the checkpoint/restore path)
        from ..core import lora as lora_lib
        from ..models import model as model_lib
        from ..optim import adam
        params = model_lib.init_params(key, cfg)
        lora = lora_lib.init_lora(jax.random.fold_in(key, 1), cfg, params)
        resc = (lora_lib.init_rescalers(cfg, bundle.meta["k"] or 1)
                if cfg.moe.enabled else None)
        trainable = lora_lib.make_trainable(lora, resc)
        opt = adam.init(trainable)

        for step in range(args.steps):
            tokens, labels, mask = synthetic_batch(
                cfg, shape, jax.random.fold_in(key, 100 + step))
            t0 = time.time()
            trainable, opt, metrics = bundle.fn(params, trainable, opt,
                                                tokens, labels, mask)
            loss = float(metrics["loss"])
            print(f"step {step}: loss {loss:.4f} "
                  f"({time.time() - t0:.2f}s)")
            assert np.isfinite(loss)
    print("done")


if __name__ == "__main__":
    main()
