"""Observability layer: metrics registry, span tracer, expert-load
telemetry — unit behaviour plus engine/federated integration.

Integration evidence mirrors the ISSUE acceptance bar: a mixed-tier
serving run with a tracer attached yields a schema-valid Chrome trace
with queued/prefill/decode spans for every completed request (and
balanced swap_out/swap_in pairs under preemption); a 3-round federated
run emits per-round activation-frequency drift with ``l1_drift`` None
on the first round and finite after.
"""
import json

import jax
import numpy as np
import pytest

from conftest import tiny_dense, tiny_moe
from repro.models import model as M
from repro.obs import (ActivationDriftTracker, Counter, ExpertLoadTracker,
                       Gauge, Histogram, MetricsRegistry, NULL_TRACER,
                       Tracer, entropy, exp_buckets, gini,
                       validate_chrome_trace)
from repro.obs.trace import PID_ENGINE, PID_REQUESTS
from repro.serving import Request, ServingEngine, SpeculativeConfig
from repro.serving.engine import ServingReport

CFG = tiny_moe()
PARAMS = M.init_params(jax.random.PRNGKey(0), CFG)
RNG = np.random.default_rng(3)


# ==========================================================================
# metrics primitives
# ==========================================================================

def test_counter_gauge_and_kind_mismatch():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2)              # get-or-create returns the same
    reg.gauge("g").set(7.5)
    snap = reg.snapshot()
    assert snap["c"] == {"type": "counter", "value": 3.0}
    assert snap["g"] == {"type": "gauge", "value": 7.5}
    with pytest.raises(TypeError):
        reg.gauge("c")


def test_histogram_percentiles_track_exact():
    xs = RNG.uniform(0.1, 50.0, 2000)
    h = Histogram()
    for x in xs:
        h.observe(float(x))
    assert h.count == len(xs)
    assert h.min == pytest.approx(xs.min()) and h.max == pytest.approx(
        xs.max())
    # 15%-growth buckets: interpolated percentiles land within one
    # bucket (~7.5% relative) of the exact order statistic
    for q in (50, 90, 99):
        exact = float(np.percentile(xs, q))
        assert h.percentile(q) == pytest.approx(exact, rel=0.08)
    assert h.min <= h.percentile(0) <= h.percentile(100) <= h.max


def test_histogram_empty_and_single():
    h = Histogram()
    assert h.percentile(50) is None and h.mean is None
    s = h.snapshot()
    assert s["count"] == 0 and s["p50"] is None and s["buckets"] == []
    h.observe(3.0)
    assert h.percentile(50) == pytest.approx(3.0)
    assert h.percentile(99) == pytest.approx(3.0)


def test_registry_snapshot_json_safe_and_sources():
    reg = MetricsRegistry()
    reg.gauge("bad").set(float("inf"))   # non-finite becomes None
    reg.add_source(lambda r: r.gauge("live").set(11))
    ext = Histogram()
    ext.observe(1.0)
    reg.register("ext", ext)
    snap = reg.snapshot()
    json.dumps(snap, allow_nan=False)
    assert snap["bad"]["value"] is None
    assert snap["live"]["value"] == 11.0
    assert snap["ext"]["count"] == 1


def test_registry_dump_round_trips(tmp_path):
    reg = MetricsRegistry()
    reg.counter("n").inc(4)
    p = tmp_path / "m.json"
    reg.dump(str(p))
    assert json.loads(p.read_text())["n"]["value"] == 4.0


# ==========================================================================
# tracer primitives
# ==========================================================================

def test_tracer_ring_bound_and_dropped():
    tr = Tracer(ring=4)
    for i in range(10):
        tr.instant(f"e{i}", i * 1e-3)
    assert len(tr.events) == 4 and tr.dropped == 6
    assert tr.to_dict()["otherData"]["dropped_events"] == 6


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.span("x"):
        pass
    NULL_TRACER.complete("x", 0.0, 1.0)
    assert len(NULL_TRACER.events) == 0
    assert NULL_TRACER.flight_dump() is None
    with pytest.raises(RuntimeError):
        NULL_TRACER.dump("/dev/null")


def test_span_nesting_validates_and_dump(tmp_path):
    tr = Tracer()
    tr.process_name(PID_ENGINE, "engine")
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    tr.counter("load", tr.now(), {"q": 2})
    assert validate_chrome_trace(tr.to_dict()) == []
    p = tmp_path / "t.json"
    tr.dump(str(p))
    loaded = json.loads(p.read_text())
    assert validate_chrome_trace(loaded) == []
    names = [e["name"] for e in loaded["traceEvents"]]
    assert "outer" in names and "inner" in names


def test_validator_flags_partial_overlap_and_bad_events():
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 0},
        {"name": "b", "ph": "X", "ts": 5, "dur": 10, "pid": 1, "tid": 0},
        {"ph": "X", "ts": 0, "dur": 1, "pid": 1, "tid": 0},
        {"name": "c", "ph": "i", "ts": -2, "pid": 1, "tid": 0},
    ]}
    errs = validate_chrome_trace(bad)
    assert any("overlaps" in e for e in errs)
    assert any("missing" in e for e in errs)
    assert any("bad ts" in e for e in errs)
    assert validate_chrome_trace({}) == ["missing traceEvents list"]


def test_flight_recorder_dump(tmp_path):
    crash = tmp_path / "crash.json"
    tr = Tracer(ring=8, flight_path=str(crash))
    for i in range(20):
        tr.instant(f"e{i}", i * 1e-3)
    assert tr.flight_dump() == str(crash)
    loaded = json.loads(crash.read_text())
    assert validate_chrome_trace(loaded) == []
    kept = [e["name"] for e in loaded["traceEvents"] if e["ph"] == "i"]
    assert kept == [f"e{i}" for i in range(12, 20)]   # newest 8 survive


# ==========================================================================
# expert-load + drift primitives
# ==========================================================================

def test_gini_entropy_extremes():
    assert gini(np.ones(8)) == pytest.approx(0.0, abs=1e-12)
    assert entropy(np.ones(8)) == pytest.approx(1.0)
    hot = np.zeros(8)
    hot[3] = 10.0
    assert gini(hot) == pytest.approx(7 / 8)
    assert entropy(hot) == pytest.approx(0.0)
    assert gini([]) == 0.0 and entropy(np.zeros(4)) == 0.0


def test_expert_tracker_accumulates_and_publishes():
    t = ExpertLoadTracker(num_experts=4)
    t.observe_step({"pos0": np.array([[2, 1, 0, 1]])})
    t.observe_step({"pos0": np.array([[0, 1, 1, 0]])})
    snap = t.snapshot()
    assert snap["steps"] == 2 and snap["assignments_total"] == 6.0
    assert snap["totals"]["pos0"] == [[2.0, 2.0, 1.0, 1.0]]
    assert snap["hot_expert"] in (0, 1)
    json.dumps(snap, allow_nan=False)
    reg = MetricsRegistry()
    t.publish(reg)
    s = reg.snapshot()
    assert s["serving.experts.assignments_total"]["value"] == 6.0
    assert s["serving.experts.step_occupancy"]["count"] == 2


def test_activation_drift_tracker():
    d = ActivationDriftTracker()
    a = {"pos0": np.array([[0.5, 0.5, 0.0, 0.0]])}
    r0 = d.update(a)
    assert r0["pos0"]["l1_drift"] is None
    r1 = d.update(a)                           # identical -> zero drift
    assert r1["pos0"]["l1_drift"] == pytest.approx(0.0)
    b = {"pos0": np.array([[0.0, 0.5, 0.5, 0.0]])}
    r2 = d.update(b)
    assert r2["pos0"]["l1_drift"] == pytest.approx(1.0)   # 0.5+0.5 moved
    assert 0.0 <= r2["pos0"]["entropy_mean"] <= 1.0


# ==========================================================================
# serving integration: one instrumented mixed-tier run shared below
# ==========================================================================

@pytest.fixture(scope="module")
def traced_run():
    tracer = Tracer()
    reg = MetricsRegistry()
    eng = ServingEngine(CFG, PARAMS, num_slots=4, slot_len=16,
                        slot_k=(2, 2, 1, 1), tracer=tracer, metrics=reg,
                        expert_telemetry=True)
    reqs = [Request(rid=i,
                    prompt=RNG.integers(0, CFG.vocab_size, (8,))
                    .astype(np.int32),
                    max_new_tokens=6, k=(2 if i % 2 == 0 else 1))
            for i in range(6)]
    rep = eng.run(reqs)
    return eng, tracer, reg, rep


def test_trace_request_lifecycle_spans(traced_run, tmp_path):
    _, tracer, _, rep = traced_run
    trace = tracer.to_dict()
    assert validate_chrome_trace(trace) == []
    by_rid = {}
    for e in trace["traceEvents"]:
        if e["pid"] == PID_REQUESTS and e["ph"] == "X":
            by_rid.setdefault(e["tid"], set()).add(e["name"])
    for c in rep.completions:                  # every completed request
        assert {"request", "queued", "prefill", "decode"} <= by_rid[c.rid]
    engine_names = {e["name"] for e in trace["traceEvents"]
                    if e["pid"] == PID_ENGINE and e["ph"] == "X"}
    assert {"admit", "prefill", "decode_step"} <= engine_names
    p = tmp_path / "serve-trace.json"
    tracer.dump(str(p))                        # strict-JSON round trip
    assert validate_chrome_trace(json.loads(p.read_text())) == []


def test_engine_registry_snapshot(traced_run):
    _, _, reg, rep = traced_run
    snap = reg.snapshot()
    json.dumps(snap, allow_nan=False)
    assert snap["serving.completions"]["value"] == len(rep.completions)
    assert snap["serving.gen_tokens"]["value"] == sum(
        c.n_generated for c in rep.completions)
    assert snap["serving.decode_step_ms"]["count"] == len(rep.decode_step_s)
    assert snap["serving.kv.num_slots"]["value"] == 4.0
    assert snap["serving.scheduler.enqueued_total"]["value"] >= 6
    assert snap["serving.experts.assignments_total"]["value"] > 0


def test_summary_decode_step_percentiles(traced_run):
    _, _, _, rep = traced_run
    s = rep.summary()
    lo = min(rep.decode_step_s) * 1e3
    hi = max(rep.decode_step_s) * 1e3
    assert lo * 0.999 <= s["decode_step_ms_p50"] <= s["decode_step_ms_p99"]
    assert s["decode_step_ms_p99"] <= hi * 1.001
    json.dumps(s, allow_nan=False)


def test_engine_expert_load_snapshot(traced_run):
    _, _, _, rep = traced_run
    el = rep.expert_load
    assert el["steps"] == len(rep.decode_step_s)
    assert el["num_experts"] == CFG.moe.num_experts
    assert el["assignments_total"] > 0
    assert 0.0 <= el["gini"] <= 1.0 and 0.0 <= el["entropy"] <= 1.0
    total = sum(sum(sum(row) for row in t) for t in el["totals"].values())
    assert total == pytest.approx(el["assignments_total"])
    assert rep.summary()["expert_load"]["hot_expert"] == el["hot_expert"]


def test_preemption_swap_spans_balanced():
    """The test_traffic preemption scenario, traced: every swap-out has
    a matching swap-in instant and a ``swapped_out`` span covering the
    off-device interval."""
    tracer = Tracer()
    eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=48,
                        slot_k=(2, 1), kv_layout="paged", block_size=4,
                        num_blocks=14, preemption=True,
                        slo_ms={2: 0.0, 1: 60000.0}, tracer=tracer)
    rep = eng.run([
        Request(rid=0, prompt=RNG.integers(0, CFG.vocab_size, (8,))
                .astype(np.int32), max_new_tokens=40, k=1, arrival=0.0),
        Request(rid=1, prompt=RNG.integers(0, CFG.vocab_size, (8,))
                .astype(np.int32), max_new_tokens=4, k=2, arrival=0.02),
    ])
    assert rep.preemptions >= 1
    evs = list(tracer.events)
    outs = [e for e in evs if e["name"] == "swap_out"]
    ins = [e for e in evs if e["name"] == "swap_in"]
    gaps = [e for e in evs if e["name"] == "swapped_out"]
    assert len(outs) == len(ins) == len(gaps) == rep.preemptions
    for o, g in zip(outs, gaps):               # gap starts at its swap-out
        assert g["ts"] == pytest.approx(o["ts"], abs=1.0)
        assert g["dur"] > 0
    assert validate_chrome_trace(tracer.to_dict()) == []


def test_speculative_summary_percentiles():
    eng = ServingEngine(CFG, PARAMS, num_slots=3, slot_len=16,
                        slot_k=(2, 2, 2), kv_layout="paged", block_size=4,
                        speculative=SpeculativeConfig(window=3, draft_k=1))
    reqs = [Request(rid=i, prompt=RNG.integers(0, CFG.vocab_size, (6,))
                    .astype(np.int32), max_new_tokens=6, k=2)
            for i in range(3)]
    s = eng.run(reqs).summary()
    for key in ("draft_step_ms_p50", "draft_step_ms_p99",
                "verify_step_ms_p50", "verify_step_ms_p99"):
        assert s[key] is not None and s[key] > 0.0
    assert s["draft_step_ms_p50"] <= s["draft_step_ms_p99"]
    assert s["verify_step_ms_p50"] <= s["verify_step_ms_p99"]
    json.dumps(s, allow_nan=False)


def test_zero_completion_summary_is_json_safe():
    """Regression: summary()/per_tier() on a run with no completions
    must return None fields, never NaN (json.dumps(nan) emits invalid
    JSON) and never raise on empty percentile input."""
    rep = ServingReport(completions=[])
    s = rep.summary()
    assert s["n_requests"] == 0 and s["gen_tokens"] == 0
    for key in ("ttft_p50_ms", "ttft_p95_ms", "ttft_p99_ms",
                "latency_p50_ms", "latency_p95_ms",
                "decode_step_ms_mean", "decode_step_ms_p50",
                "decode_step_ms_p99"):
        assert s[key] is None, key
    assert rep.per_tier() == {}
    assert "NaN" not in json.dumps(s, allow_nan=False)


def test_expert_telemetry_rejects_bad_combos():
    dense = tiny_dense()
    dparams = M.init_params(jax.random.PRNGKey(1), dense)
    with pytest.raises(ValueError, match="MoE"):
        ServingEngine(dense, dparams, num_slots=2, slot_len=16,
                      expert_telemetry=True)
    with pytest.raises(ValueError, match="speculative"):
        ServingEngine(CFG, PARAMS, num_slots=2, slot_len=16,
                      slot_k=(2, 2), expert_telemetry=True,
                      speculative=SpeculativeConfig(window=3, draft_k=1))


# ==========================================================================
# federated integration: 3 rounds -> drift series + metrics/trace files
# ==========================================================================

def test_federated_round_drift_metrics_and_trace(tmp_path):
    from repro.configs.base import FederatedConfig, TrainConfig
    from repro.configs.registry import get_config
    from repro.data.synthetic import DataConfig
    from repro.federated.simulation import build_experiment

    cfg = get_config("olmoe-1.3b-6.9b", "smoke")
    fed = FederatedConfig(num_clients=2, rounds=3, method="flame",
                          temperature=2)
    exp = build_experiment(
        cfg, fed=fed, tc=TrainConfig(batch_size=8, local_epochs=1),
        data=DataConfig(vocab_size=cfg.vocab_size, n_examples=96,
                        seq_len=64, n_clusters=4))
    mpath = tmp_path / "fed-metrics.json"
    tpath = tmp_path / "fed-trace.json"
    history = exp.server.run(metrics_to=str(mpath), trace_to=str(tpath))
    assert len(history) == 3

    # drift: None on the first observed round, finite after
    for r, res in enumerate(history):
        assert res.activation_drift, f"round {r} recorded no drift"
        for pos, d in res.activation_drift.items():
            assert 0.0 <= d["entropy_mean"] <= 1.0
            if r == 0:
                assert d["l1_drift"] is None
            else:
                assert d["l1_drift"] is not None
                assert 0.0 <= d["l1_drift"] <= 2.0

    snap = json.loads(mpath.read_text())
    assert snap["fed.rounds"]["value"] == 3.0
    assert snap["fed.participants"]["value"] == 2.0
    assert any(k.startswith("fed.activation.entropy.") for k in snap)
    assert any(k.startswith("fed.activation.l1_drift.") for k in snap)

    trace = json.loads(tpath.read_text())
    assert validate_chrome_trace(trace) == []
    names = [e["name"] for e in trace["traceEvents"] if e["ph"] == "X"]
    for r in range(3):
        assert f"round {r}" in names
    for phase in ("distribute", "cohort_update", "aggregate"):
        assert names.count(phase) >= 3        # once per round at least
