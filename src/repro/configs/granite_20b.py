"""granite-20b [dense] — 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, llama-arch, code model.  [arXiv:2405.04324]"""
from .base import LoRAConfig, ModelConfig

FULL = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,                # MQA
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    rope_theta=10_000.0,
    lora=LoRAConfig(rank=16),
    source="arXiv:2405.04324",
)

SMOKE = FULL.replace(
    name="granite-smoke",
    num_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=1,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    lora=LoRAConfig(rank=4),
)

SWA_WINDOW = 8192
