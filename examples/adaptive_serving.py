"""Adaptive-activation serving (FLAME's deployment-efficiency claim).

A model fine-tuned under reduced expert activation can be SERVED with
reduced activation: this example merges the federated LoRA into the base
weights, prefills a batch of requests, then decodes autoregressively at
k ∈ {top_k, …, 1}, reporting per-k perplexity and the analytic FLOPs saved.

  PYTHONPATH=src python examples/adaptive_serving.py --new-tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core import flops as F
from repro.core import lora as lora_lib
from repro.data.synthetic import DataConfig
from repro.federated.simulation import build_experiment
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=1)
    args = ap.parse_args()

    from repro.configs.olmoe_1_3b_6_9b import BENCH as cfg
    fed = FederatedConfig(num_clients=2, rounds=args.rounds, method="flame")
    tc = TrainConfig(batch_size=8)
    data = DataConfig(vocab_size=cfg.vocab_size, n_examples=128, seq_len=64)
    exp = build_experiment(cfg, fed=fed, tc=tc, data=data)
    exp.server.run()

    # deployment: merge LoRA into the base weights (zero serving overhead)
    params = lora_lib.merge_into_params(exp.server.params,
                                        exp.server.global_lora,
                                        cfg.lora.scale)

    # a batch of requests = prompts from the held-out set
    prompts = jnp.asarray(exp.test.tokens[:args.batch, :32])
    golds = jnp.asarray(exp.test.tokens[:args.batch,
                                        32:32 + args.new_tokens])

    print(f"serving {cfg.name}: {cfg.moe.num_experts} experts, "
          f"trained top-{cfg.moe.top_k}; batch={args.batch}, "
          f"prefill 32 + decode {args.new_tokens}\n")
    print("k,active_params_M,decode_GFLOPs_per_tok,nll,wall_s")

    decode = jax.jit(
        lambda p, c, t, pos, k: M.decode_step(cfg, p, c, t, pos, k=k),
        static_argnames=("k",))

    for k in sorted({cfg.moe.top_k, max(cfg.moe.top_k // 2, 1), 1},
                    reverse=True):
        t0 = time.time()
        logits, cache = M.prefill(cfg, params, prompts, k=k,
                                  cache_len=32 + args.new_tokens)
        nll, tok = 0.0, prompts[:, -1:]
        for i in range(args.new_tokens):
            logits, cache = decode(params, cache, tok, 32 + i, k)
            logp = jax.nn.log_softmax(logits[:, 0].astype(jnp.float32), -1)
            gold = golds[:, i]
            nll += float(-jnp.take_along_axis(
                logp, gold[:, None], -1).mean())
            tok = gold[:, None]           # teacher-forced continuation
        wall = time.time() - t0
        p_act = F.count_params(cfg, k=k)["active"] / 1e6
        gflops = F.flops_paper_convention(cfg, tokens=1, k=k) / 1e9
        print(f"{k},{p_act:.1f},{gflops:.3f},{nll / args.new_tokens:.4f},"
              f"{wall:.2f}")

    print("\nlower k => proportionally fewer active params/FLOPs per token "
          "with modest quality cost — the paper's Table 1 economics at "
          "serving time.")


if __name__ == "__main__":
    main()
