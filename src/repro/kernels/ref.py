"""Pure-jnp oracles for every Pallas kernel (the correctness references).

These are deliberately naive (materialise the full score matrix, loop the
top-k) — clarity over speed.  Kernel tests sweep shapes/dtypes and
``assert_allclose`` against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = 0,
                        scale: float | None = None) -> jnp.ndarray:
    """q: (B,H,S,D); k,v: (B,KV,S,D) -> (B,H,S,D).  fp32 softmax."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5
    kr = jnp.repeat(k, rep, axis=1)
    vr = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    if causal:
        valid = kpos <= qpos
        if window > 0:
            valid &= kpos > qpos - window
        s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def lora_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
                    b: jnp.ndarray, scale: float) -> jnp.ndarray:
    """x: (M,K); w: (K,N); a: (K,r); b: (r,N) -> x@w + (x@a)@b·scale."""
    f32 = jnp.float32
    y = x.astype(f32) @ w.astype(f32)
    y = y + (x.astype(f32) @ a.astype(f32)) @ b.astype(f32) * scale
    return y.astype(x.dtype)


def lora_matmul_experts_ref(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
                            b: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Stacked per-expert oracle: x (E,C,K); w (E,K,N); a (E,K,r);
    b (E,r,N) -> (E,C,N).  Same numerics contract as the kernel: all math
    in fp32, one cast at the end."""
    f32 = jnp.float32
    xf, wf, af, bf = (t.astype(f32) for t in (x, w, a, b))
    y = jnp.einsum("eck,ekn->ecn", xf, wf)
    xa = jnp.einsum("eck,ekr->ecr", xf, af)
    y = y + jnp.einsum("ecr,ern->ecn", xa, bf) * scale
    return y.astype(x.dtype)


def topk_router_ref(logits: jnp.ndarray, k: int):
    """logits: (T,E) -> (weights (T,E) fp32, mask (T,E) fp32, counts (E,)).

    Softmax -> iterative argmax top-k -> renormalised weights.  Identical
    semantics to models.moe_layer.topk_routing plus the count reduction.
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    masked = probs
    mask = jnp.zeros_like(probs)
    for _ in range(k):
        idx = jnp.argmax(masked, axis=-1)
        onehot = jax.nn.one_hot(idx, probs.shape[-1], dtype=probs.dtype)
        mask = mask + onehot
        masked = masked * (1.0 - onehot)
    weights = probs * mask
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, mask, mask.sum(axis=0)


def ragged_gather_ref(x: jnp.ndarray, src: jnp.ndarray,
                      valid: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the ragged dispatch gather: x (T, D); src, valid (N,)
    int32 -> (N, D) with ``out[i] = x[src[i]] * valid[i]`` (padding rows
    land zero)."""
    return x[src] * valid.astype(x.dtype)[:, None]


def ragged_expert_matmul_ref(xs: jnp.ndarray, block_expert: jnp.ndarray,
                             w: jnp.ndarray, a: jnp.ndarray = None,
                             b: jnp.ndarray = None,
                             scale: float = 0.0) -> jnp.ndarray:
    """Oracle for the grouped (segment) LoRA matmul over the ragged
    buffer: xs (N, K); block_expert (N // bm,) int32; w (E, K, H);
    optional LoRA factors a (E, K, r), b (E, r, H).  Row block ``i``
    multiplies expert ``block_expert[i]``'s weights — here spelled as a
    per-block weight gather + batched einsum.  Same numerics contract as
    the kernel: fp32 accumulate, one cast."""
    f32 = jnp.float32
    N, K = xs.shape
    nb = block_expert.shape[0]
    xb = xs.reshape(nb, N // nb, K).astype(f32)
    y = jnp.einsum("bmk,bkh->bmh", xb, w[block_expert].astype(f32))
    if a is not None:
        xa = jnp.einsum("bmk,bkr->bmr", xb, a[block_expert].astype(f32))
        y = y + jnp.einsum("bmr,brh->bmh", xa,
                           b[block_expert].astype(f32)) * scale
    return y.reshape(N, -1).astype(xs.dtype)


def ragged_combine_ref(eo: jnp.ndarray, rows: jnp.ndarray,
                       wrank: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the ragged combine: eo (N, D); rows (T, max_k) int32;
    wrank (T, max_k) -> (T, D), ``out[t] = sum_j wrank[t,j] *
    eo[rows[t,j]]`` — a per-token gather (ranks past the token's budget
    carry weight 0 and point at row 0)."""
    g = eo[rows].astype(jnp.float32)                   # (T, max_k, D)
    out = (g * wrank[..., None].astype(jnp.float32)).sum(axis=1)
    return out.astype(eo.dtype)


def adaptive_topk_router_ref(logits: jnp.ndarray, k_tok: jnp.ndarray,
                             max_k: int):
    """Per-token-budget routing: token ``t`` activates its top ``k_tok[t]``
    experts (FLAME's adaptive-k at serving time, per slot of a mixed batch).

    logits: (T,E); k_tok: (T,) int with 0 <= k_tok[t] <= max_k (static;
    ``k_tok`` itself may be traced — budget 0 deselects the token entirely,
    which is how the serving engine masks free slots out of routing).
    Returns (weights, mask, counts) with the same layout as
    :func:`topk_router_ref`.  Because top-k selection is nested (the top-j
    experts are a prefix of the top-(j+1) experts under the same argmax tie
    break), truncating the ranked selection at ``k_tok[t]`` and
    renormalising is *exactly* ``topk_router_ref(logits[t], k_tok[t])`` per
    token — uniform ``k_tok == k`` reproduces the static router bit-for-bit.
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    masked = probs
    mask = jnp.zeros_like(probs)
    take = k_tok.astype(jnp.int32)[:, None]
    for rank in range(max_k):
        idx = jnp.argmax(masked, axis=-1)
        onehot = jax.nn.one_hot(idx, probs.shape[-1], dtype=probs.dtype)
        mask = mask + onehot * (rank < take)
        masked = masked * (1.0 - onehot)
    weights = probs * mask
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, mask, mask.sum(axis=0)
