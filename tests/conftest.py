"""Shared fixtures.  NOTE: no XLA_FLAGS manipulation here — tests must see
the real (single-CPU) device set; only launch/dryrun.py forces 512 devices.
"""
import jax
import pytest

from repro.configs.base import LoRAConfig, ModelConfig, MoEConfig, SSMConfig


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="module")
def _bounded_jit_cache():
    """Drop compiled executables at module boundaries.

    The suite compiles hundreds of programs in one process; XLA:CPU keeps
    every executable's JIT code alive for the process lifetime, and past
    a few GB of accumulated code the LLVM JIT starts segfaulting inside
    ``backend_compile`` on otherwise-fine programs.  Modules share almost
    no jitted callables (engines/servers build their own closures), so
    clearing per module costs little recompilation and keeps the live
    footprint bounded no matter how many test files the repo grows.
    """
    yield
    jax.clear_caches()


def tiny_dense(**kw) -> ModelConfig:
    base = dict(
        name="tiny-dense", family="dense", num_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
        lora=LoRAConfig(rank=4), dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def tiny_moe(**kw) -> ModelConfig:
    base = dict(
        name="tiny-moe", family="moe", num_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=0, vocab_size=128, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64),
        lora=LoRAConfig(rank=4), dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def tiny_ssm(**kw) -> ModelConfig:
    base = dict(
        name="tiny-ssm", family="ssm", num_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=128,
        ssm=SSMConfig(d_state=16, head_dim=32, chunk_size=16),
        lora=LoRAConfig(rank=4), dtype="float32")
    base.update(kw)
    return ModelConfig(**base)
