import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture × input-shape) step for the production
mesh — 16×16 single-pod and 2×16×16 multi-pod — against ShapeDtypeStruct
stand-ins (no allocation), then records memory_analysis(), cost_analysis()
and the collective schedule for the roofline table.

THE FIRST TWO LINES of this module set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any other
import: jax locks the device count at first initialisation.  No other
module sets this — smoke tests and benches see the real single device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
      --shape train_4k --multi-pod --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from ..configs.base import INPUT_SHAPES
from ..configs.registry import ASSIGNED_ARCHS, get_config, long_context_variant
from . import analytic
from . import roofline as roofline_lib
from . import steps as steps_lib
from .mesh import make_production_mesh


def variant_for(arch: str, shape_name: str) -> str:
    """long_500k needs a sub-quadratic attention path: native for SSM/hybrid
    (mamba state is O(1)); the sliding-window variant for attention archs."""
    if shape_name == "long_500k":
        return long_context_variant(arch)
    return "full"


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             overrides: Optional[Dict[str, Any]] = None,
             keep_hlo: bool = False,
             mesh_shape: Optional[tuple] = None) -> Dict[str, Any]:
    """Lower + compile one (arch × shape × mesh) combination; return the
    dry-run record (roofline terms, memory, collective schedule).

    ``mesh_shape``: override the (data, model) split of the 256 chips —
    the §Perf beyond-paper knob (the deliverable mesh stays 16×16)."""
    t0 = time.time()
    shape = INPUT_SHAPES[shape_name]
    variant = variant_for(arch, shape_name)
    cfg = get_config(arch, variant)
    if mesh_shape is not None:
        from .mesh import make_mesh_compat
        mesh = make_mesh_compat(tuple(mesh_shape), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size

    with mesh:
        bundle = steps_lib.build_step(cfg, shape, mesh, **(overrides or {}))
        lowered = bundle.fn.lower(*bundle.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    model_flops = analytic.model_flops_global(cfg, shape, bundle.meta)
    dev_flops = analytic.device_flops(cfg, shape, chips, bundle.meta)
    dev_bytes = analytic.device_bytes(cfg, shape, chips, bundle.meta)

    rl = roofline_lib.extract(
        compiled, hlo, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops=model_flops, device_flops=dev_flops,
        device_bytes=dev_bytes, meta=bundle.meta)

    mem = compiled.memory_analysis()
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "step": bundle.name, "chips": chips,
        "ok": True,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 2 ** 30,
            "output_gb": mem.output_size_in_bytes / 2 ** 30,
            "temp_gb": mem.temp_size_in_bytes / 2 ** 30,
            "alias_gb": mem.alias_size_in_bytes / 2 ** 30,
            "peak_gb": (mem.argument_size_in_bytes +
                        mem.output_size_in_bytes +
                        mem.temp_size_in_bytes -
                        mem.alias_size_in_bytes) / 2 ** 30,
        },
        "roofline": rl.row(),
        "collectives": {
            "execs_by_kind": rl.meta["collective_execs_by_kind"],
            "bytes_by_kind": rl.meta["collective_bytes_by_kind"],
        },
        "meta": rl.meta,
    }
    if keep_hlo:
        record["hlo_text"] = hlo
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--act-mode", default=None)
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    overrides: Dict[str, Any] = {}
    if args.n_micro is not None:
        overrides["n_micro"] = args.n_micro
    if args.act_mode is not None:
        overrides["act_mode"] = args.act_mode

    n_ok = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    ov = dict(overrides)
                    if INPUT_SHAPES[shape_name].kind != "train":
                        ov.pop("n_micro", None)
                        ov.pop("act_mode", None)
                    rec = run_pair(arch, shape_name, multi_pod=mp,
                                   overrides=ov)
                    n_ok += 1
                    r = rec["roofline"]
                    print(f"OK   {tag}: peak={rec['memory']['peak_gb']:.2f}GB"
                          f" bottleneck={r['bottleneck']}"
                          f" tc={r['t_compute_ms']:.1f}ms"
                          f" tm={r['t_memory_ms']:.1f}ms"
                          f" tx={r['t_collective_ms']:.1f}ms"
                          f" (compile {rec['t_compile_s']}s)", flush=True)
                except Exception as e:  # noqa: BLE001 — record + continue
                    n_fail += 1
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "ok": False, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
