"""ShapeDtypeStruct stand-ins for every model input / state tree.

``input_specs(cfg, shape)`` returns exactly what the corresponding step
function consumes — weak-type-correct, shardable, and **zero allocation**
(the full llama3-405b state exists only abstractly; the dry-run lowers and
compiles against these).

Modality note (the one sanctioned stub): the VLM/audio *frontends* are not
implemented — chameleon's VQ image tokens share the text vocabulary so its
backbone input is plain token ids, and musicgen consumes EnCodec codebook
ids of shape (B, S, K=4).  Both are exactly what ``input_specs`` emits.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..core import lora as lora_lib
from ..models import model as model_lib
from ..optim import adam

PyTree = Any
SDS = jax.ShapeDtypeStruct


def _token_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.num_codebooks > 0:
        return (batch, seq, cfg.num_codebooks)
    return (batch, seq)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    """Abstract inputs for the step the shape lowers (train/prefill/decode)."""
    if shape.kind == "train":
        ts = _token_shape(cfg, shape.global_batch, shape.seq_len)
        return {
            "tokens": SDS(ts, jnp.int32),
            "labels": SDS(ts, jnp.int32),
            "mask": SDS((shape.global_batch, shape.seq_len), jnp.float32),
        }
    if shape.kind == "prefill":
        return {"tokens": SDS(_token_shape(cfg, shape.global_batch,
                                           shape.seq_len), jnp.int32)}
    # decode: ONE new token against a seq_len-deep cache
    return {
        "tokens": SDS(_token_shape(cfg, shape.global_batch, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }


# --------------------------------------------------------------------------
# abstract state trees (params / trainable / optimizer / cache)
# --------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig) -> PyTree:
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: model_lib.init_params(k, cfg), key)


def abstract_trainable(cfg: ModelConfig, k_client: int = 0,
                       rescaler: str = "learnable") -> PyTree:
    key = jax.random.PRNGKey(0)

    def build(k):
        params = model_lib.init_params(k, cfg)
        lora = lora_lib.init_lora(k, cfg, params)
        resc = None
        if cfg.moe.enabled and rescaler != "none":
            resc = lora_lib.init_rescalers(
                cfg, k_client or cfg.moe.top_k, rescaler)
        return lora_lib.make_trainable(lora, resc)

    return jax.eval_shape(build, key)


def abstract_opt_state(abstract_trainable_tree: PyTree) -> PyTree:
    return jax.eval_shape(adam.init, abstract_trainable_tree)


def abstract_cache(cfg: ModelConfig, batch: int, seq_len: int) -> PyTree:
    return jax.eval_shape(
        functools.partial(model_lib.init_cache, cfg, batch, seq_len))


def state_bytes(tree: PyTree) -> int:
    return sum(int(jnp.dtype(l.dtype).itemsize) *
               int(functools.reduce(lambda a, b: a * b, l.shape, 1))
               for l in jax.tree.leaves(tree))
