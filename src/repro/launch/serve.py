"""Serving launcher: the continuous-batching engine on this host's devices.

``--local`` runs the adaptive-k serving engine (repro.serving) over a
synthetic open-loop workload on a reduced config — a real request queue,
block-paged KV pool (``--kv-layout paged``, sized by ``--block-size`` /
``--num-blocks``; ``--kv-layout slotted`` for the legacy fixed-slot
pool), batched prefill and one compiled mixed-k decode step, reporting
throughput and TTFT/latency percentiles; without ``--local`` it builds
the sharded serve step for the production mesh (use repro.launch.dryrun
in this offline container).

Production-traffic knobs (docs/serving.md): ``--prefix-cache`` (shared
prompt blocks pay KV once), ``--preemption`` + ``--slo-ms K:MS,...``
(per-tier TTFT targets driving EDF admission and decode swap-out), and
trace shaping via ``--arrival {poisson,diurnal,burst}``,
``--length-dist {categorical,zipf}``, ``--shared-prefix N``.

  PYTHONPATH=src python -m repro.launch.serve --local \
      --arch olmoe-1.3b-6.9b --slots 8 --mix 8:0.5,1:0.5 \
      --requests 16 --rate 20 --new-tokens 16 --block-size 16 \
      --prefix-cache --shared-prefix 4 --slo-ms 8:250,1:2000 --preemption
"""
from __future__ import annotations

import argparse

import jax

from ..configs.base import INPUT_SHAPES
from ..configs.registry import get_config
from ..models import model as model_lib
from ..obs import MetricsRegistry, Tracer
from ..serving import (ServingEngine, SpeculativeConfig, WorkloadConfig,
                       make_trace)
from . import steps as steps_lib
from .mesh import make_production_mesh


def parse_mix(spec: str, top_k: int):
    """``"8:0.5,1:0.5"`` -> tier mix tuple; ``""`` -> uniform top_k."""
    if not spec:
        return ((top_k, 1.0),)
    out = []
    for part in spec.split(","):
        k, frac = part.split(":")
        out.append((int(k), float(frac)))
    return tuple(out)


def slot_k_for_mix(mix, num_slots: int):
    """Partition the slot pool proportionally to the tier mix.

    Every tier keeps >= 1 slot — a tier with zero slots but nonzero
    traffic would strand its requests in the queue (the engine raises once
    nothing else is runnable)."""
    if num_slots < len(mix):
        raise SystemExit(f"--slots {num_slots} < {len(mix)} tiers in --mix;"
                         " every tier needs at least one slot")
    total = sum(f for _, f in mix)
    counts = [max(1, round(num_slots * f / total)) for _, f in mix]
    while sum(counts) > num_slots:
        counts[counts.index(max(counts))] -= 1   # > 1: len(mix) <= num_slots
    while sum(counts) < num_slots:
        counts[counts.index(min(counts))] += 1
    slot_k = []
    for (k, _), n in zip(mix, counts):
        slot_k.extend([k] * n)
    return tuple(slot_k)


def parse_slo(spec: str):
    """``"8:150,1:1000"`` -> per-tier TTFT targets {k: ms}; ``""`` -> None.

    A single bare number (``"250"``) has no tier to attach to — require
    the k:ms form so the target unambiguously names a tier."""
    if not spec:
        return None
    out = {}
    for part in spec.split(","):
        try:
            k, ms = part.split(":")
            out[int(k)] = float(ms)
        except ValueError:
            raise SystemExit(f"--slo-ms: bad entry {part!r} "
                             "(expected K:MILLISECONDS[,K:MS...])")
    return out


def build_parser() -> argparse.ArgumentParser:
    """The serving launcher's CLI (kept separate from :func:`main` so
    tools/docs_check.py can verify every flag docs/serving.md names)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1.3b-6.9b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--k", type=int, default=None,
                    help="uniform serving budget (all slots / production "
                         "step); shorthand for --mix K:1.0 with --local")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--slot-len", type=int, default=48)
    ap.add_argument("--kv-layout", choices=("paged", "slotted"),
                    default="paged",
                    help="paged: block-paged KV pool (admission follows "
                         "block availability); slotted: one fixed-capacity "
                         "slot per request (the PR 3 layout)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV tokens per page block (paged layout)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="usable KV blocks in the pool; default sizes the "
                         "pool so every slot can hold a max-length request "
                         "— set lower to make blocks the scarce resource")
    ap.add_argument("--dispatch", choices=("ragged", "dense", "capacity"),
                    default="ragged",
                    help="MoE token dispatch: ragged = sort-based, "
                         "loss-free AND sum(slot_k)-proportional (default); "
                         "dense = loss-free one-hot at worst-case padding; "
                         "capacity = GShard capacity-limited throughput "
                         "mode (batching may change results)")
    ap.add_argument("--speculate", action="store_true",
                    help="self-speculative decoding: draft a window of "
                         "tokens per slot at --draft-k through the same "
                         "weights, verify it in one full-k step, accept "
                         "by the exact rejection rule "
                         "(serving/speculative.py)")
    ap.add_argument("--window", type=int, default=4,
                    help="speculative draft window W (tokens drafted per "
                         "round and verified in one step)")
    ap.add_argument("--draft-k", type=int, default=1,
                    help="expert budget for the draft pass (the cheap k)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-addressed prompt block sharing in the "
                         "paged pool (refcounts + copy-on-write): requests "
                         "with a common system prompt pay its KV once")
    ap.add_argument("--preemption", action="store_true",
                    help="SLO-driven decode preemption: swap the most "
                         "lenient-deadline active request out to host when "
                         "a waiter misses its TTFT target (needs --slo-ms "
                         "and the paged layout)")
    ap.add_argument("--slo-ms", default="",
                    help="per-tier TTFT targets K:MS[,K:MS...] — switches "
                         "admission to earliest-deadline-first and adds "
                         "per-tier SLO attainment to the report")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=float("inf"),
                    help="mean arrival rate (req/s); inf = closed batch")
    ap.add_argument("--arrival", choices=("poisson", "diurnal", "burst"),
                    default="poisson",
                    help="arrival process around --rate: homogeneous "
                         "Poisson, sinusoidal day/night modulation, or "
                         "periodic flash-crowd bursts (serving/workload.py)")
    ap.add_argument("--length-dist", choices=("categorical", "zipf"),
                    default="categorical",
                    help="output-length distribution: fixed --new-tokens, "
                         "or a heavy Zipf tail capped at 64")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="shared system-prompt tokens at the head of every "
                         "prompt (exercises --prefix-cache)")
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--mix", default="",
                    help="tier mix k:frac[,k:frac...] (FLAME adaptive-k); "
                         "empty = full top_k everywhere")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON of the run "
                         "(request lifecycle + engine-loop spans; open in "
                         "Perfetto).  Also arms the flight recorder: on an "
                         "engine exception the trace ring is dumped to "
                         "PATH.crash.json (repro.obs.trace)")
    ap.add_argument("--metrics-out", default="",
                    help="write a metrics-registry JSON snapshot after the "
                         "run: engine counters, step-time histograms, KV "
                         "pool / scheduler gauges (repro.obs.metrics)")
    ap.add_argument("--expert-telemetry", action="store_true",
                    help="per-decode-step expert activation counts, "
                         "host-side: occupancy histogram, gini/entropy, "
                         "hot expert in the report (MoE archs, not with "
                         "--speculate)")
    ap.add_argument("--multi-pod", action="store_true")
    return ap


def main() -> None:
    args = build_parser().parse_args()

    if not args.local:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch, "full")
        shape = INPUT_SHAPES[args.shape]
        with mesh:
            bundle = steps_lib.build_serve(cfg, shape, mesh, k=args.k)
            print(f"serve_step for {cfg.name} × {shape.name} on "
                  f"{mesh.devices.shape}: cache "
                  f"{bundle.meta['cache_bytes'] / 2 ** 30:.1f} GiB global, "
                  f"k={bundle.meta['k']}")
            print("lowering...")
            compiled = bundle.fn.lower(*bundle.args).compile()
            mem = compiled.memory_analysis()
            print(f"compiled; {mem.temp_size_in_bytes / 2 ** 30:.2f} GiB "
                  f"temp/device — ready for real hardware")
        return

    # ---- local: the continuous-batching engine over a synthetic trace ----
    cfg = get_config(args.arch, "smoke")
    if cfg.num_codebooks > 0:
        raise SystemExit(f"{cfg.name}: the serving engine is text-only; "
                         "codebook (audio) archs have no engine path yet")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    top_k = cfg.moe.top_k if cfg.moe.enabled else 0
    if args.k is not None and top_k:
        if args.mix:
            raise SystemExit("--k and --mix are mutually exclusive; "
                             "--k N is shorthand for --mix N:1.0")
        args.mix = f"{args.k}:1.0"       # uniform reduced-k pool
    mix = parse_mix(args.mix, top_k) if top_k else ()
    bad = [k for k, _ in mix if not 1 <= k <= cfg.moe.num_experts]
    if bad:
        raise SystemExit(
            f"--mix tiers {bad} out of range: {cfg.name} (the --local "
            f"reduced config) has {cfg.moe.num_experts} experts")
    slot_k = slot_k_for_mix(mix, args.slots) if mix else None

    # prompts must leave room for at least one generated token in a slot
    prompt_lens = tuple(L for L in (8, 16) if L + 1 <= args.slot_len)
    if not prompt_lens:
        raise SystemExit(f"--slot-len {args.slot_len} too small for the "
                         "workload's 8-token prompts (need >= 9)")
    if args.shared_prefix and args.shared_prefix >= min(prompt_lens):
        raise SystemExit(f"--shared-prefix {args.shared_prefix} must be "
                         f"shorter than the shortest prompt "
                         f"({min(prompt_lens)} tokens)")
    wl = WorkloadConfig(
        n_requests=args.requests, rate=args.rate,
        prompt_lens=prompt_lens, new_tokens=(args.new_tokens,),
        tier_mix=mix, vocab_size=cfg.vocab_size,
        arrival=args.arrival, length_dist=args.length_dist,
        shared_prefix_len=args.shared_prefix)
    slo = parse_slo(args.slo_ms)
    spec = None
    if args.speculate:
        if not cfg.moe.enabled:
            raise SystemExit(f"--speculate needs an MoE arch: {cfg.name} "
                             "has no cheaper draft budget")
        spec = SpeculativeConfig(window=args.window, draft_k=args.draft_k)
    tracer = (Tracer(flight_path=f"{args.trace_out}.crash.json")
              if args.trace_out else None)
    registry = MetricsRegistry() if args.metrics_out else None
    if args.expert_telemetry and not cfg.moe.enabled:
        raise SystemExit(f"--expert-telemetry needs an MoE arch: "
                         f"{cfg.name} routes nothing to observe")
    engine = ServingEngine(cfg, params, num_slots=args.slots,
                           slot_len=args.slot_len, slot_k=slot_k,
                           kv_layout=args.kv_layout,
                           block_size=args.block_size,
                           num_blocks=args.num_blocks,
                           dispatch=args.dispatch,
                           speculative=spec,
                           prefix_cache=args.prefix_cache,
                           preemption=args.preemption,
                           slo_ms=slo,
                           tracer=tracer, metrics=registry,
                           expert_telemetry=args.expert_telemetry)
    pool_desc = (f"{engine.pool.num_blocks} x {engine.pool.block_size}"
                 f"-token KV blocks" if engine.paged
                 else "slotted KV pool")
    spec_desc = (f", speculative W={args.window} draft_k={args.draft_k}"
                 if spec else "")
    traffic = [flag for flag, on in
               (("prefix-cache", args.prefix_cache),
                ("preemption", args.preemption),
                (f"slo={args.slo_ms}", bool(slo))) if on]
    traffic_desc = f", {' '.join(traffic)}" if traffic else ""
    print(f"{cfg.name}: {args.slots} slots × {args.slot_len} tokens "
          f"({pool_desc}), slot_k={engine.slot_k}, "
          f"dispatch={engine.dispatch}{spec_desc}{traffic_desc}")
    report = engine.run(make_trace(wl))
    for key, val in report.summary().items():
        print(f"  {key}: {val:.2f}" if isinstance(val, float)
              else f"  {key}: {val}")
    if tracer is not None:
        print(f"trace: {tracer.dump(args.trace_out)} "
              f"({len(tracer.events)} events — open in Perfetto)")
    if registry is not None:
        registry.dump(args.metrics_out)
        print(f"metrics: {args.metrics_out}")


if __name__ == "__main__":
    main()
