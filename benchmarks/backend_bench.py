"""End-to-end kernel-backend benchmark (the kernel_bench successor).

For each config family (dense = flash-attention hot path; moe = router +
fused expert-LoRA hot path) time one full **forward+backward** training
step — ``jax.value_and_grad`` of ``repro.models.model.lm_loss`` over the
LoRA trainables — under each kernel backend:

  * ``reference``        — the jnp oracles (what CPU runs by default);
  * ``pallas-interpret`` — the Pallas kernels under the interpreter (the
    CI parity configuration; *expected to be slower on CPU* — the
    interpreter exists for correctness, not speed).

On real TPU hardware the same harness compares compiled-Pallas against the
references; CPU numbers only track relative regressions of each path.  The
per-op micro-benchmarks live on in ``benchmarks.kernel_bench``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import KernelConfig, LoRAConfig, ModelConfig, \
    MoEConfig
from repro.core import lora as lora_lib
from repro.models import model as model_lib

from .common import emit, timeit

BACKENDS = {
    "reference": KernelConfig(backend="reference"),
    "pallas-interpret": KernelConfig(backend="pallas", interpret=True),
}


def _families():
    common = dict(num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  vocab_size=256, head_dim=16, lora=LoRAConfig(rank=8),
                  dtype="float32")
    return {
        "dense": ModelConfig(name="bench-dense", family="dense", d_ff=128,
                             **common),
        "moe": ModelConfig(name="bench-moe", family="moe", d_ff=0,
                           moe=MoEConfig(num_experts=8, top_k=2,
                                         d_expert=64), **common),
    }


def _step_time_us(cfg, batch=4, seq=64):
    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(key, cfg)
    lora = lora_lib.init_lora(jax.random.fold_in(key, 1), cfg, params)
    resc = lora_lib.init_rescalers(cfg, cfg.moe.top_k) \
        if cfg.moe.enabled else None
    trainable = lora_lib.make_trainable(lora, resc)
    tokens = jax.random.randint(jax.random.fold_in(key, 2), (batch, seq),
                                0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((batch, seq), jnp.float32)

    @jax.jit
    def step(tr):
        def f(tr):
            loss, _ = model_lib.lm_loss(cfg, params, tokens, labels, mask,
                                        trainable=tr, k=cfg.moe.top_k or None)
            return loss
        return jax.value_and_grad(f)(tr)

    return timeit(lambda: jax.block_until_ready(step(trainable)))


def run() -> None:
    rows = []
    per_family = {}
    for fam, cfg in _families().items():
        for bname, kcfg in BACKENDS.items():
            us = _step_time_us(cfg.replace(kernels=kcfg))
            rows.append({"family": fam, "backend": bname,
                         "fwd_bwd_us_per_step": us})
            per_family.setdefault(fam, {})[bname] = us
    emit("backend_bench", rows, ["family", "backend", "fwd_bwd_us_per_step"])
    for fam, t in per_family.items():
        ratio = t["pallas-interpret"] / t["reference"]
        print(f"# [{fam}] pallas-interpret / reference step time = "
              f"{ratio:.2f}x (interpreter overhead on CPU; compiled Pallas "
              f"is the TPU path)")


if __name__ == "__main__":
    run()
