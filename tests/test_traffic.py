"""Production traffic layer: prefix-cached paged KV, decode preemption,
SLO-aware admission, per-tier reporting, trace shapes.

Three layers of evidence, mirroring tests/test_paged_kv.py:

* **pool mechanics**: content-addressed block sharing (attach / revive /
  copy-on-write / adopt / evict) keeps the refcounted free list exact —
  ``check_invariants`` after every step;
* **engine differentials**: the full traffic stack (prefix cache + EDF
  admission + decode preemption) is token-for-token identical to the
  cold PR 4/5 engine, to the slotted engine, and to solo naive decodes —
  serving features must be invisible to results;
* **property suite**: seeded random interleavings of admit / share /
  decode / truncate / swap-out / swap-in / release hold the
  used+free==total, no-leak, no-double-free invariants with shared
  chains in play.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_moe
from repro.configs.base import KernelConfig
from repro.serving import (BlockPool, Request, Scheduler, ServingEngine,
                           SpeculativeConfig, WorkloadConfig, make_trace)
from repro.serving.engine import ServingReport
from repro.serving.scheduler import Completion
from repro.models import model as M

from test_serving import naive_decode

CFG = tiny_moe()
PARAMS = M.init_params(jax.random.PRNGKey(0), CFG)
RNG = np.random.default_rng(7)


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_cache():
    """This module runs last in the alphabetical suite, on top of every
    executable the earlier modules compiled; shed them first so its own
    engine/decode compiles don't push the process over the edge on
    small CI hosts."""
    jax.clear_caches()
    yield


def _piece(prompts, cache_len, k=2):
    """A prefill cache tree for ``prompts`` (np (nb, L)) — what the
    engine hands to ``pool.write``."""
    _, piece = M.prefill(CFG, PARAMS, jnp.asarray(prompts), k=k,
                         cache_len=cache_len)
    return piece


def _admit(pool, prompt, proj):
    """allocate + reserve + write one prompt; returns the slot."""
    s = pool.allocate()
    pool.reserve(s, proj)
    pool.write([s], _piece(prompt[None], pool.slot_len), [len(prompt)],
               tokens=[prompt])
    return s


# ==========================================================================
# pool mechanics: sharing, refcounts, CoW, revive, evict
# ==========================================================================

def test_prefix_sharing_refcounts_and_revival():
    """Two identical prompts pay the KV once; released blocks revive from
    the free list with their content intact."""
    pool = BlockPool(CFG, num_slots=4, slot_len=16, block_size=4,
                     num_blocks=12, prefix_cache=True)
    A = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)

    s0 = _admit(pool, A, 12)                     # 2 blocks written
    assert pool.blocks_in_use == 2
    assert pool.prefix_stats()["hit_tokens"] == 0
    s1 = _admit(pool, A, 12)                     # both blocks attached
    assert pool.blocks_in_use == 2               # shared, counted once
    assert pool.prefix_stats() == {
        "hit_blocks": 2, "hit_tokens": 8, "cow_copies": 0,
        "evictions": 0, "cached_blocks": 2}
    # debt counts owned blocks only: s0 owns 2 of 3 reserved, s1 owns 0
    assert pool.available_blocks == (12 - 2) - (1 + 3)
    pool.check_invariants()

    pool.release(s0)
    assert pool.blocks_in_use == 2               # s1 still reads them
    pool.release(s1)
    assert pool.blocks_in_use == 0
    assert pool.prefix_stats()["cached_blocks"] == 2   # index survives

    s2 = _admit(pool, A, 12)                     # revived out of the free
    assert pool.blocks_in_use == 2               # list, nothing written
    assert pool.prefix_stats()["hit_tokens"] == 16
    assert pool._nshared[s2] == 0                # revival is an OWNED alloc
    pool.check_invariants()

    # revived content is the original K/V: gather matches a fresh prefill
    from repro.models.attention import paged_gather
    want = _piece(A[None], pool.slot_len)
    for leaf in ("k", "v"):
        pooled = pool.cache["pos0"]["attn"][leaf]
        ref = np.asarray(want["pos0"]["attn"][leaf])
        for p in range(pooled.shape[0]):
            got = np.asarray(paged_gather(pooled[p], pool.tables(),
                                          pool.attn_len))
            np.testing.assert_allclose(got[s2, :8], ref[p, 0, :8])


def test_prefix_divergence_attaches_only_the_common_blocks():
    """A prompt sharing one leading block attaches exactly that block and
    writes its own suffix."""
    pool = BlockPool(CFG, num_slots=4, slot_len=16, block_size=4,
                     num_blocks=12, prefix_cache=True)
    A = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
    B = A.copy()
    B[4:] = (B[4:] + 1) % CFG.vocab_size         # diverges at block 1

    s0 = _admit(pool, A, 12)
    s1 = _admit(pool, B, 12)
    assert pool.prefix_stats()["hit_tokens"] == 4
    assert pool.blocks_in_use == 3               # A0(shared), A1, B1
    assert int(pool.block_table[s0, 0]) == int(pool.block_table[s1, 0])
    assert int(pool.block_table[s0, 1]) != int(pool.block_table[s1, 1])
    pool.check_invariants()


def test_prefix_partial_tail_shares_only_on_full_prompt_match():
    """The partial tail block is shareable only when the whole prompt
    matches — a longer prompt with the same prefix must not read it."""
    pool = BlockPool(CFG, num_slots=4, slot_len=16, block_size=4,
                     num_blocks=12, prefix_cache=True)
    A = RNG.integers(0, CFG.vocab_size, (6,)).astype(np.int32)   # 1 + tail
    _admit(pool, A, 12)

    longer = np.concatenate([A, A[:4]]).astype(np.int32)         # 10 toks
    s1 = _admit(pool, longer, 12)
    # only the full leading block matched; the tail digest covers A's
    # whole 6-token prompt, which `longer`'s block 1 does not equal
    assert pool.prefix_stats()["hit_tokens"] == 4
    assert pool._nshared[s1] == 1
    pool.check_invariants()

    exact = _admit(pool, A.copy(), 12)           # full match: tail shared
    assert pool.prefix_stats()["hit_tokens"] == 4 + 6
    assert pool._nshared[exact] == 2
    pool.check_invariants()


def test_prefix_copy_on_write_and_adopt():
    """Appending into a shared partial block copies it while other
    readers remain — and adopts it in place once they are gone."""
    pool = BlockPool(CFG, num_slots=4, slot_len=16, block_size=4,
                     num_blocks=12, prefix_cache=True)
    A = RNG.integers(0, CFG.vocab_size, (6,)).astype(np.int32)
    s0 = _admit(pool, A, 12)                     # owner
    s1 = _admit(pool, A.copy(), 12)              # borrows block 0 + tail
    pool.cache_pos[s0] = pool.cache_pos[s1] = 6

    old = int(pool.block_table[s1, 1])
    pool.prepare_decode([s1])                    # appends INTO shared tail
    new = int(pool.block_table[s1, 1])
    assert new != old and pool.prefix_stats()["cow_copies"] == 1
    assert int(pool.block_table[s0, 1]) == old   # owner untouched
    # the private copy carries the shared content (positions 4..5)
    for leaf in ("k", "v"):
        pooled = np.asarray(pool.cache["pos0"]["attn"][leaf])
        np.testing.assert_allclose(pooled[:, new, :2], pooled[:, old, :2])
    pool.check_invariants()

    # owner appends in place: owners never copy (borrowers only read
    # below the shared span)
    pool.prepare_decode([s0])
    assert int(pool.block_table[s0, 1]) == old
    assert pool.prefix_stats()["cow_copies"] == 1

    # adopt path: a new borrower whose co-readers released
    s2 = _admit(pool, A.copy(), 12)
    shared = int(pool.block_table[s2, 1])
    pool.release(s0), pool.release(s1)
    assert int(pool._ref[shared]) == 1           # sole referent now
    pool.cache_pos[s2] = 6
    pool.prepare_decode([s2])
    assert int(pool.block_table[s2, 1]) == shared      # no copy
    assert pool.prefix_stats()["cow_copies"] == 1
    assert pool._nshared[s2] == 1                # block 0 is still shared
    pool.check_invariants()


def test_prefix_cache_entries_evict_on_reuse():
    """A generic allocation that pops a cached free block drops its index
    entry — the cache can never serve stale content."""
    pool = BlockPool(CFG, num_slots=2, slot_len=16, block_size=4,
                     num_blocks=4, prefix_cache=True)
    A = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
    s0 = _admit(pool, A, 8)                      # 2 blocks cached
    pool.release(s0)
    assert pool.prefix_stats()["cached_blocks"] == 2

    B = (A + 1) % CFG.vocab_size
    big = np.concatenate([B, B]).astype(np.int32)          # 16 tokens
    s1 = _admit(pool, big, 16)                   # needs all 4 blocks
    assert pool.prefix_stats()["evictions"] == 2
    # the evicted entries are gone; big's own 4 blocks are indexed
    assert pool.prefix_stats()["cached_blocks"] == 4
    pool.release(s1)
    s2 = _admit(pool, A, 8)                      # must rewrite, not hit
    assert pool.prefix_stats()["hit_tokens"] == 0
    pool.check_invariants(), pool.release(s2)


def test_prefix_cache_rejects_ring_caches():
    with pytest.raises(ValueError, match="linear cache"):
        BlockPool(tiny_moe(attention_window=6), num_slots=2, slot_len=8,
                  block_size=4, prefix_cache=True)


# ==========================================================================
# swap-out / swap-in (the preemption primitive)
# ==========================================================================

def test_swap_roundtrip_restores_blocks_and_frees_everything():
    pool = BlockPool(CFG, num_slots=2, slot_len=16, block_size=4,
                     num_blocks=8, prefix_cache=True)
    A = RNG.integers(0, CFG.vocab_size, (6,)).astype(np.int32)
    s = _admit(pool, A, 12)
    pool.cache_pos[s] = 6
    before = {leaf: np.asarray(
        pool.cache["pos0"]["attn"][leaf][:, pool.block_table[s, :2]])
        for leaf in ("k", "v")}

    state = pool.swap_out(s)
    assert pool.blocks_in_use == 0 and pool.available_blocks == 8
    assert state["cache_pos"] == 6 and state["n_blocks"] == 2
    pool.check_invariants()

    s2 = pool.allocate()
    pool.reserve(s2, 12)
    pool.swap_in(s2, state)
    assert int(pool.cache_pos[s2]) == 6 and pool.blocks_in_use == 2
    for leaf in ("k", "v"):
        after = np.asarray(
            pool.cache["pos0"]["attn"][leaf][:, pool.block_table[s2, :2]])
        np.testing.assert_allclose(after, before[leaf])
    assert pool.swap_outs == 1 and pool.swap_ins == 1
    pool.check_invariants()

    with pytest.raises(ValueError, match="slot is free"):
        pool.swap_out(s2 + 1 if s2 == 0 else 0)


# ==========================================================================
# scheduler: EDF under per-tier SLO targets
# ==========================================================================

def test_scheduler_slo_policy_orders_by_deadline():
    sched = Scheduler(policy="slo", tier_slo_s={2: 0.1, 1: 10.0})
    eco = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=2,
                  k=1, arrival=0.0)
    prm = Request(rid=1, prompt=np.zeros(4, np.int32), max_new_tokens=2,
                  k=2, arrival=0.5)               # deadline 0.6 < 10.0
    sched.add(eco), sched.add(prm)
    got = sched.admit([0, 1], [1, 2])
    assert [(r.rid, s) for r, s in got] == [(1, 1), (0, 0)] \
        or [r.rid for r, _ in got] == [1, 0]
    assert sched.deadline(prm) == pytest.approx(0.6)
    assert sched.deadline(
        Request(rid=2, prompt=np.zeros(4, np.int32), max_new_tokens=1,
                k=4, arrival=0.0)) == float("inf")   # untargeted tier


def test_scheduler_fifo_default_and_slo_validation():
    sched = Scheduler()                           # FIFO stays the default
    a = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=1,
                k=1, arrival=0.9)
    b = Request(rid=1, prompt=np.zeros(4, np.int32), max_new_tokens=1,
                k=1, arrival=0.1)
    sched.add(a), sched.add(b)
    assert [r.rid for r, _ in sched.admit([0, 1], [1, 1])] == [0, 1]
    with pytest.raises(AssertionError):
        Scheduler(policy="nonsense")
    with pytest.raises(AssertionError, match="tier_slo_s"):
        Scheduler(policy="slo")                   # targets are required


# ==========================================================================
# engine differentials: the traffic stack must be invisible to results
# ==========================================================================

def _shared_prefix_trace(n=8, prefix_len=4, lens=(6, 8), tiers=(1, 2),
                         new=(2, 4, 5), seed=3):
    """Closed-batch trace where every prompt opens with one of two fixed
    prefixes and some prompts repeat exactly."""
    rng = np.random.default_rng(seed)
    prefixes = rng.integers(0, CFG.vocab_size, (2, prefix_len)) \
        .astype(np.int32)
    reqs = []
    for i in range(n):
        L = int(rng.choice(lens))
        p = rng.integers(0, CFG.vocab_size, (L,)).astype(np.int32)
        p[:prefix_len] = prefixes[i % 2]
        if i >= n - 2:                            # exact duplicates too
            p = np.array(reqs[i - 2].prompt, np.int32)
        reqs.append(Request(rid=i, prompt=p,
                            max_new_tokens=int(rng.choice(new)),
                            k=int(tiers[i % len(tiers)])))
    return reqs


def test_traffic_stack_matches_cold_engine_and_slotted():
    """prefix cache + EDF + preemption == cold paged == slotted, token
    for token, on a mixed-tier shared-prefix closed batch."""
    reqs = _shared_prefix_trace()
    kw = dict(num_slots=4, slot_len=16, slot_k=(2, 2, 1, 1))
    cold = ServingEngine(CFG, PARAMS, kv_layout="paged", block_size=4,
                         **kw).run([Request(**vars(r)) for r in reqs])
    slotted = ServingEngine(CFG, PARAMS, kv_layout="slotted", **kw) \
        .run([Request(**vars(r)) for r in reqs])
    traffic_eng = ServingEngine(
        CFG, PARAMS, kv_layout="paged", block_size=4, prefix_cache=True,
        preemption=True, slo_ms={2: 50.0, 1: 5000.0}, **kw)
    traffic = traffic_eng.run([Request(**vars(r)) for r in reqs])

    want = cold.tokens_by_rid()
    for rep in (slotted, traffic):
        got = rep.tokens_by_rid()
        assert got.keys() == want.keys()
        for rid in want:
            np.testing.assert_array_equal(want[rid], got[rid])
    assert traffic.prefix["hit_tokens"] > 0       # sharing really happened
    traffic_eng.pool.check_invariants()
    assert traffic_eng.pool.blocks_in_use == 0    # everything released


def test_preempted_request_resumes_token_identical():
    """An economy decode swapped out for an urgent premium request
    resumes exactly where it stopped — both match their solo runs."""
    eco_prompt = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
    prm_prompt = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
    eco_new, prm_new = 40, 4
    # 14 blocks: the economy request reserves 12, so the premium arrival
    # (3 blocks) is block-starved until the engine swaps economy out
    eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=48,
                        slot_k=(2, 1), kv_layout="paged", block_size=4,
                        num_blocks=14, preemption=True,
                        slo_ms={2: 0.0, 1: 60000.0})
    rep = eng.run([
        Request(rid=0, prompt=eco_prompt, max_new_tokens=eco_new, k=1,
                arrival=0.0),
        Request(rid=1, prompt=prm_prompt, max_new_tokens=prm_new, k=2,
                arrival=0.02),
    ])
    by_rid = {c.rid: c for c in rep.completions}
    assert rep.preemptions >= 1
    assert by_rid[0].preemptions >= 1 and by_rid[1].preemptions == 0
    assert eng.pool.swap_outs == eng.pool.swap_ins == rep.preemptions
    np.testing.assert_array_equal(
        by_rid[0].tokens, naive_decode(CFG, PARAMS, eco_prompt[None],
                                       eco_new, 1)[0])
    np.testing.assert_array_equal(
        by_rid[1].tokens, naive_decode(CFG, PARAMS, prm_prompt[None],
                                       prm_new, 2)[0])
    eng.pool.check_invariants()
    assert eng.pool.blocks_in_use == 0


def test_prefix_cache_with_speculation_parity():
    """Speculative rollback into shared tail blocks goes through
    copy-on-write; duplicate prompts still decode exactly as solo."""
    prompts = [RNG.integers(0, CFG.vocab_size, (6,)).astype(np.int32)
               for _ in range(2)]
    prompts.append(prompts[0].copy())             # exact duplicate
    new = 6
    eng = ServingEngine(CFG, PARAMS, num_slots=3, slot_len=16,
                        slot_k=(2, 2, 2), kv_layout="paged", block_size=4,
                        prefix_cache=True,
                        speculative=SpeculativeConfig(window=3, draft_k=1))
    rep = eng.run([Request(rid=i, prompt=p, max_new_tokens=new, k=2)
                   for i, p in enumerate(prompts)])
    got = rep.tokens_by_rid()
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            got[i], naive_decode(CFG, PARAMS, p[None], new, 2)[0])
    assert rep.prefix["hit_tokens"] > 0
    eng.pool.check_invariants()


def test_suffix_prefill_matches_cold_across_match_shapes():
    """Suffix-only prefill == cold full prefill token-for-token across
    every match shape: cold miss, same-batch duplicate (pending blocks →
    full recompute, skipped write), cross-batch full match on a block
    boundary (1-token suffix), full match through a partial tail block
    (block-rounded suffix), head-only partial match, and a longer prompt
    extending a cached head — with exact hit-token and prefill-token
    accounting."""
    bs = 4
    head = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
    B = RNG.integers(0, CFG.vocab_size, (6,)).astype(np.int32)
    Abig = np.concatenate(
        [head, RNG.integers(0, CFG.vocab_size, (4,))]).astype(np.int32)
    C = np.concatenate(
        [B[:4], RNG.integers(0, CFG.vocab_size, (4,))]).astype(np.int32)
    mk = [                                       # (prompt, k, arrival)
        # sharing pairs sit in the SAME tier: pages are tier-salted
        # (K/V depend on the expert budget), so only same-k requests
        # may alias — test_prefix_cache_is_tier_scoped covers cross-k
        (head, 2, 0.0),          # r0: cold miss, edge-of-block length
        (B, 1, 0.0),             # r1: cold miss, partial-tail length
        (head.copy(), 2, 0.0),   # r2: same-batch dup — pending, suffix 8
        (head.copy(), 2, 0.06),  # r3: full match at boundary — suffix 1
        (B.copy(), 1, 0.06),     # r4: full match incl tail — suffix 2
        (Abig, 2, 0.06),         # r5: extends cached head — suffix 4
        (C, 1, 0.06),            # r6: head-only match (1 block) — suffix 4
    ]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=3, k=k, arrival=t)
            for i, (p, k, t) in enumerate(mk)]
    kw = dict(num_slots=4, slot_len=16, slot_k=(2, 2, 1, 1),
              kv_layout="paged", block_size=bs, num_blocks=32)
    cold = ServingEngine(CFG, PARAMS, **kw) \
        .run([Request(**vars(r)) for r in reqs])
    eng = ServingEngine(CFG, PARAMS, prefix_cache=True, **kw)
    warm = eng.run([Request(**vars(r)) for r in reqs])

    want, got = cold.tokens_by_rid(), warm.tokens_by_rid()
    assert got.keys() == want.keys()
    for rid in want:
        np.testing.assert_array_equal(want[rid], got[rid])
    # real matched tokens, not attached blocks: r2:8 r3:8 r4:6 r5:8 r6:4
    assert warm.prefix["hit_tokens"] == 34
    # computed prefill tokens follow the unmatched suffixes only:
    # cold = sum of prompt lengths; warm = 8+6+8 cold misses, then the
    # block-rounded suffixes 1 (full match, L-1 floor), 2, 4, 4
    assert cold.prefill_tokens == sum(len(p) for p, _, _ in mk) == 56
    assert warm.prefill_tokens == 33
    eng.pool.check_invariants()
    assert eng.pool.blocks_in_use == 0


def test_prefix_cache_is_tier_scoped():
    """The same prompt served at different expert budgets must NOT share
    pages: k changes every layer's hidden states, so k=1 pages are
    numerically wrong for a k=2 reader.  The digest chain is salted with
    the tier, so the 'duplicate' is a clean miss — and both requests
    still match naive greedy decode at their own k."""
    p = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
    eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=16,
                        slot_k=(2, 1), kv_layout="paged", block_size=4,
                        num_blocks=16, prefix_cache=True)
    rep = eng.run([
        Request(rid=0, prompt=p, max_new_tokens=3, k=1, arrival=0.0),
        Request(rid=1, prompt=p.copy(), max_new_tokens=3, k=2,
                arrival=0.05),
    ])
    got = rep.tokens_by_rid()
    for rid, k in ((0, 1), (1, 2)):
        np.testing.assert_array_equal(
            got[rid], naive_decode(CFG, PARAMS, p[None], 3, k)[0])
    assert rep.prefix["hit_tokens"] == 0         # no cross-tier aliasing
    # both prompts prefilled in full — no suffix saving across tiers
    assert rep.prefill_tokens == 16
    eng.pool.check_invariants()
    assert eng.pool.blocks_in_use == 0


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_suffix_prefill_differential_backends(backend):
    """The suffix-prefill differential per kernel backend (the CI slow
    subset runs this): prefix-cached engine == cold paged engine on a
    mixed-tier shared-head trace with cross-batch duplicates."""
    cfg = tiny_moe(kernels=KernelConfig(backend=backend))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _shared_prefix_trace(n=6, lens=(6, 8), new=(2, 3), seed=11)
    for r in reqs[-2:]:
        r.arrival = 0.05         # the exact duplicates arrive a beat
        #                          later: full matches against WRITTEN
        #                          blocks, i.e. real suffix savings
    kw = dict(num_slots=4, slot_len=16, slot_k=(2, 2, 1, 1),
              kv_layout="paged", block_size=4)
    cold = ServingEngine(cfg, params, **kw) \
        .run([Request(**vars(r)) for r in reqs])
    warm = ServingEngine(cfg, params, prefix_cache=True, **kw) \
        .run([Request(**vars(r)) for r in reqs])
    want, got = cold.tokens_by_rid(), warm.tokens_by_rid()
    assert got.keys() == want.keys()
    for rid in want:
        np.testing.assert_array_equal(want[rid], got[rid])
    assert warm.prefix["hit_tokens"] > 0
    assert warm.prefill_tokens < cold.prefill_tokens


def test_suffix_buckets_compile_log_not_linear():
    """A shared-head flash crowd with every distinct prompt length maps
    to O(log max_suffix) compiled suffix-prefill variants — the pow-2
    suffix bucket, not the prompt length, keys the compile cache."""
    head = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
    reqs = [Request(rid=0, prompt=head, max_new_tokens=2, k=1,
                    arrival=0.0)]
    for i, L in enumerate(range(9, 16)):         # 7 distinct lengths
        p = np.concatenate(
            [head, RNG.integers(0, CFG.vocab_size, (L - 8,))]) \
            .astype(np.int32)
        reqs.append(Request(rid=1 + i, prompt=p, max_new_tokens=2, k=1,
                            arrival=0.05))
    eng = ServingEngine(CFG, PARAMS, num_slots=8, slot_len=24,
                        slot_k=(1,) * 8, kv_layout="paged", block_size=4,
                        num_blocks=64, prefix_cache=True)
    rep = eng.run(reqs)
    assert rep.prefix["hit_tokens"] == 7 * 8     # every crowd head hit
    # 8 distinct prompt lengths compiled: seed suffix 8, then crowd
    # suffixes 1..7 → pow-2 buckets {1, 2, 4, 8} across a handful of
    # batch buckets — far below one variant per prompt length, and
    # bounded by O(log max_suffix · log num_slots)
    n_variants = eng._suffix_prefill_fn._cache_size()
    assert n_variants <= 6, n_variants


def test_swap_roundtrip_preserves_shareability():
    """Satellite regression: a preempted-and-resumed request's prompt
    blocks are re-registered in the prefix index on swap-in, so its
    shared head hits exactly as it would have without the swap."""
    pool = BlockPool(CFG, num_slots=2, slot_len=16, block_size=4,
                     num_blocks=8, prefix_cache=True)
    A = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
    s = _admit(pool, A, 12)
    pool.cache_pos[s] = 8
    assert pool.prefix_stats()["hit_tokens"] == 0

    state = pool.swap_out(s)
    s2 = pool.allocate()
    pool.reserve(s2, 12)
    pool.swap_in(s2, state)                      # must re-register
    pool.check_invariants()

    s3 = _admit(pool, A.copy(), 12)              # duplicate after the swap
    assert pool.prefix_stats()["hit_tokens"] == 8     # identical to no-swap
    assert pool._nshared[s3] == 2                # really attached, not rebuilt
    pool.check_invariants()
    pool.release(s2), pool.release(s3)
    assert pool.blocks_in_use == 0


def test_spec_preemption_token_identical():
    """Speculative decoding + preemption == plain greedy decode token for
    token, with at least one real swap-out — the lifted constructor
    guard is safe because an open draft window rolls back before the
    swap captures state."""
    eco_prompt = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
    prm_prompt = RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
    eco_new, prm_new = 40, 4
    eng = ServingEngine(CFG, PARAMS, num_slots=2, slot_len=48,
                        slot_k=(2, 1), kv_layout="paged", block_size=4,
                        num_blocks=14, preemption=True,
                        slo_ms={2: 0.0, 1: 60000.0},
                        speculative=SpeculativeConfig(window=3, draft_k=1))
    rep = eng.run([
        Request(rid=0, prompt=eco_prompt, max_new_tokens=eco_new, k=1,
                arrival=0.0),
        Request(rid=1, prompt=prm_prompt, max_new_tokens=prm_new, k=2,
                arrival=0.02),
    ])
    by_rid = {c.rid: c for c in rep.completions}
    assert rep.preemptions >= 1
    assert rep.spec_rounds >= 1                  # speculation really ran
    np.testing.assert_array_equal(
        by_rid[0].tokens, naive_decode(CFG, PARAMS, eco_prompt[None],
                                       eco_new, 1)[0])
    np.testing.assert_array_equal(
        by_rid[1].tokens, naive_decode(CFG, PARAMS, prm_prompt[None],
                                       prm_new, 2)[0])
    eng.pool.check_invariants()
    assert eng.pool.blocks_in_use == 0


def test_engine_rejects_bad_traffic_combos():
    kw = dict(num_slots=2, slot_len=8, slot_k=(2, 1))
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(CFG, PARAMS, kv_layout="slotted",
                      prefix_cache=True, **kw)
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(CFG, PARAMS, kv_layout="slotted",
                      preemption=True, slo_ms={1: 1.0}, **kw)
    with pytest.raises(ValueError, match="slo_ms"):
        ServingEngine(CFG, PARAMS, kv_layout="paged",
                      preemption=True, **kw)
    # preemption + speculation is now a SUPPORTED combination: a swap-out
    # of a slot with an open draft window rolls back to the last verified
    # token first (SpeculativeDecoder.rollback_open), so construction
    # must succeed (parity: test_spec_preemption_token_identical)
    eng = ServingEngine(CFG, PARAMS, kv_layout="paged", preemption=True,
                        slo_ms={1: 1.0},
                        speculative=SpeculativeConfig(window=2), **kw)
    assert eng._spec is not None and eng._preemption


# ==========================================================================
# per-tier report accounting (hand-built completions: exact numbers)
# ==========================================================================

def _completion(rid, k, arrival, ttft_s, n_tok):
    return Completion(
        rid=rid, prompt_len=4, tokens=np.arange(n_tok, dtype=np.int32),
        k=k, arrival=arrival, admitted=arrival,
        first_token=arrival + ttft_s, finished=arrival + ttft_s + 0.1)


def test_report_per_tier_accounting():
    cs = ([_completion(i, 2, 0.0, 0.010 * (i + 1), 4) for i in range(4)]
          + [_completion(10 + i, 1, 0.0, 0.200, 8) for i in range(2)])
    rep = ServingReport(completions=cs, wall_s=2.0, slo_ms={2: 25.0})
    tiers = rep.per_tier()
    assert set(tiers) == {"1", "2"}
    prm, eco = tiers["2"], tiers["1"]
    assert prm["n_requests"] == 4 and eco["n_requests"] == 2
    assert prm["ttft_p50_ms"] == pytest.approx(25.0)   # 10/20/30/40 ms
    assert prm["ttft_p99_ms"] == pytest.approx(
        float(np.percentile([10.0, 20.0, 30.0, 40.0], 99)))
    assert eco["ttft_p50_ms"] == pytest.approx(200.0)
    assert prm["gen_tokens_per_s"] == pytest.approx(16 / 2.0)
    assert eco["gen_tokens_per_s"] == pytest.approx(16 / 2.0)
    assert prm["slo_attainment"] == pytest.approx(0.5)  # 10,20 <= 25ms
    assert "slo_attainment" not in eco                  # no economy target
    s = rep.summary()
    assert s["per_tier"] == tiers and "ttft_p99_ms" in s


# ==========================================================================
# workload generators
# ==========================================================================

def test_workload_arrival_shapes_deterministic():
    for arrival in ("poisson", "diurnal", "burst"):
        wl = WorkloadConfig(n_requests=32, rate=50.0, arrival=arrival,
                            seed=5)
        a = [r.arrival for r in make_trace(wl)]
        b = [r.arrival for r in make_trace(wl)]
        assert a == b                              # seeded determinism
        assert a == sorted(a) and a[0] == 0.0
        assert all(np.isfinite(a))
    # bursty traffic really clusters: more tight inter-arrivals than
    # the homogeneous process at the same base rate
    gaps = lambda wl: np.diff([r.arrival for r in make_trace(wl)])
    burst = gaps(WorkloadConfig(n_requests=64, rate=20.0, arrival="burst",
                                burst_factor=16.0, seed=5))
    flat = gaps(WorkloadConfig(n_requests=64, rate=20.0, seed=5))
    assert np.median(burst) < np.median(flat)
    with pytest.raises(AssertionError):
        make_trace(WorkloadConfig(arrival="weekly"))


def test_workload_zipf_lengths_and_shared_prefixes():
    wl = WorkloadConfig(n_requests=48, length_dist="zipf",
                        new_tokens=(8, 16), max_new_cap=40,
                        prompt_lens=(12,), shared_prefix_len=8,
                        n_shared_prefixes=2, seed=9)
    trace = make_trace(wl)
    news = [r.max_new_tokens for r in trace]
    assert min(news) >= 8 and max(news) <= 40      # floor = min(new_tokens)
    assert len(set(news)) > 2                      # an actual distribution
    heads = {tuple(r.prompt[:8]) for r in trace}
    assert len(heads) <= 2                         # one of two templates
    tails = {tuple(r.prompt[8:]) for r in trace}
    assert len(tails) > 2                          # private suffixes vary
    with pytest.raises(AssertionError):            # prefix must fit
        make_trace(WorkloadConfig(prompt_lens=(8,), shared_prefix_len=8))
    with pytest.raises(AssertionError):
        make_trace(WorkloadConfig(length_dist="gauss"))


# ==========================================================================
# property suite: random interleavings with shared chains
# ==========================================================================

def _interleave(seed, steps=60):
    """Random admit/decode/truncate/swap/release against a prefix pool;
    every step must preserve the refcount/free-list invariants."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(CFG, num_slots=3, slot_len=16, block_size=4,
                     num_blocks=10, prefix_cache=True)
    templates = [RNG.integers(0, CFG.vocab_size, (L,)).astype(np.int32)
                 for L in (4, 6, 8)]
    pieces = {len(t): _piece(t[None], pool.slot_len) for t in templates}
    active = {}                                    # slot -> (prompt_len, proj)
    swapped = []                                   # (state, proj)
    for _ in range(steps):
        op = rng.choice(["admit", "decode", "truncate", "swap_out",
                         "swap_in", "release"])
        if op == "admit" and pool.num_free:
            t = templates[int(rng.integers(len(templates)))]
            proj = int(rng.integers(len(t) + 1, 17))
            if pool.can_admit(proj):
                s = pool.allocate()
                pool.reserve(s, proj)
                pool.write([s], pieces[len(t)], [len(t)], tokens=[t])
                pool.cache_pos[s] = len(t)
                active[s] = (len(t), proj)
        elif op == "decode" and active:
            s = int(rng.choice(list(active)))
            if int(pool.cache_pos[s]) < active[s][1]:
                pool.prepare_decode([s])
                pool.cache_pos[s] += 1
        elif op == "truncate" and active:
            s = int(rng.choice(list(active)))
            L = active[s][0]
            if int(pool.cache_pos[s]) > L:
                pool.truncate_to(
                    s, int(rng.integers(L, int(pool.cache_pos[s]))))
        elif op == "swap_out" and active:
            s = int(rng.choice(list(active)))
            swapped.append((pool.swap_out(s), active.pop(s)[1]))
        elif op == "swap_in" and swapped and pool.num_free:
            state, proj = swapped[-1]
            if pool.can_admit(proj):
                swapped.pop()
                s = pool.allocate()
                pool.reserve(s, proj)
                pool.swap_in(s, state)
                active[s] = (state["cache_pos"], proj)
        elif op == "release" and active:
            s = int(rng.choice(list(active)))
            pool.release(s)
            del active[s]
        pool.check_invariants()
    for s in list(active):
        pool.release(s)
        pool.check_invariants()
    assert pool.blocks_in_use == 0
    assert pool.available_blocks == pool.num_blocks
    assert len(pool._free_blocks) == pool.num_blocks


@pytest.mark.parametrize("seed", range(6))
def test_prefix_pool_interleavings_seeded(seed):
    _interleave(seed)
