"""Synthetic instruction-tuning corpus.

The container is offline so AlpaGasus/Dolly cannot be downloaded.  What the
FLAME experiments actually need from the data is (a) a *learnable*
next-token structure so fine-tuning moves held-out loss, and (b) *task
heterogeneity* so Dirichlet partitioning produces the skewed per-client
distributions (Figure 2's expert-activation imbalance emerges from this).

We generate both with a seeded cluster-mixture Markov corpus:

  * ``n_clusters`` latent "tasks"; each task owns a random first-order
    Markov transition matrix over the vocabulary (peaked, so there is
    real signal to learn) and a distinct prompt prefix distribution;
  * an example = [BOS, prompt tokens, SEP, response tokens, EOS] with a
    loss mask over the response (instruction-tuning convention — matches
    the paper's Alpaca prompt-template masking);
  * cluster identity is attached to every example so the Dirichlet
    partitioner can distribute *clusters* unevenly across clients
    (exactly how the paper induces heterogeneity with α).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    n_clusters: int = 8
    n_examples: int = 2048
    seq_len: int = 128
    prompt_len: int = 32
    peak: float = 12.0       # Markov sharpness: higher = more learnable
    seed: int = 0
    num_codebooks: int = 0   # >0 -> audio-token layout (B, S, K)

    @property
    def bos(self) -> int:
        return 0

    @property
    def sep(self) -> int:
        return 1

    @property
    def eos(self) -> int:
        return 2

    @property
    def first_content(self) -> int:
        return 3


@dataclass
class Corpus:
    tokens: np.ndarray    # (N, S) or (N, S, K) int32
    labels: np.ndarray    # same shape, shifted targets
    mask: np.ndarray      # (N, S) float32 — 1 on response positions
    clusters: np.ndarray  # (N,) int32 — latent task id


def _cluster_transition(rng: np.random.Generator, vocab: int,
                        peak: float) -> np.ndarray:
    """Row-stochastic transition matrix, sharply peaked per row."""
    logits = rng.normal(size=(vocab, vocab)).astype(np.float32)
    # bias towards a cluster-specific permutation "skeleton"
    perm = rng.permutation(vocab)
    logits[np.arange(vocab), perm] += peak
    z = logits - logits.max(axis=1, keepdims=True)
    p = np.exp(z)
    return p / p.sum(axis=1, keepdims=True)


def _sample_chain(rng, trans, start, length):
    out = np.empty(length, np.int64)
    cur = start
    for i in range(length):
        cur = rng.choice(trans.shape[0], p=trans[cur])
        out[i] = cur
    return out


def make_corpus(cfg: DataConfig) -> Corpus:
    rng = np.random.default_rng(cfg.seed)
    v = cfg.vocab_size
    content = v - cfg.first_content
    trans = [_cluster_transition(rng, content, cfg.peak)
             for _ in range(cfg.n_clusters)]
    # cluster-specific prompt start distributions
    starts = rng.integers(0, content, size=(cfg.n_clusters, 4))

    S = cfg.seq_len
    # clamp the prompt so short sequences still leave room for a response
    prompt_len = min(cfg.prompt_len, max(S // 2 - 2, 1))
    resp_len = S - prompt_len - 3              # BOS, SEP, EOS
    toks = np.empty((cfg.n_examples, S), np.int64)
    mask = np.zeros((cfg.n_examples, S), np.float32)
    clusters = rng.integers(0, cfg.n_clusters, cfg.n_examples)

    for n in range(cfg.n_examples):
        c = int(clusters[n])
        start = int(rng.choice(starts[c]))
        prompt = _sample_chain(rng, trans[c], start, prompt_len)
        resp = _sample_chain(rng, trans[c], int(prompt[-1]), resp_len)
        row = np.concatenate([[cfg.bos - cfg.first_content],
                              prompt, [cfg.sep - cfg.first_content],
                              resp, [cfg.eos - cfg.first_content]])
        toks[n] = row + cfg.first_content
        # loss on response tokens + EOS (prediction targets are shifted)
        mask[n, prompt_len + 1:] = 1.0

    labels = np.roll(toks, -1, axis=1)
    labels[:, -1] = cfg.eos
    mask[:, -1] = 0.0

    if cfg.num_codebooks > 0:
        K = cfg.num_codebooks
        toks_k = np.stack([(toks + k * 7) % cfg.vocab_size
                           for k in range(K)], axis=-1)
        labels_k = np.roll(toks_k, -1, axis=1)
        labels_k[:, -1] = cfg.eos
        return Corpus(toks_k.astype(np.int32), labels_k.astype(np.int32),
                      mask, clusters.astype(np.int32))

    return Corpus(toks.astype(np.int32), labels.astype(np.int32), mask,
                  clusters.astype(np.int32))


def split_corpus(c: Corpus, train: float = 0.8, val: float = 0.1
                 ) -> Tuple[Corpus, Corpus, Corpus]:
    """80/10/10 split (paper §3)."""
    n = len(c.tokens)
    n_tr, n_val = int(n * train), int(n * val)

    def take(sl):
        return Corpus(c.tokens[sl], c.labels[sl], c.mask[sl], c.clusters[sl])

    return (take(slice(0, n_tr)), take(slice(n_tr, n_tr + n_val)),
            take(slice(n_tr + n_val, n)))


def batches(c: Corpus, batch_size: int, *, rng: np.random.Generator,
            drop_last: bool = True):
    """Shuffled minibatch iterator of (tokens, labels, mask)."""
    idx = rng.permutation(len(c.tokens))
    end = (len(idx) // batch_size) * batch_size if drop_last else len(idx)
    for i in range(0, end, batch_size):
        sl = idx[i:i + batch_size]
        yield c.tokens[sl], c.labels[sl], c.mask[sl]
