"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE + SwiGLU + GQA.  [arXiv:2412.08905]"""
from .base import LoRAConfig, ModelConfig

FULL = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    rope_theta=10_000.0,
    lora=LoRAConfig(rank=16),
    source="arXiv:2412.08905",
)

SMOKE = FULL.replace(
    name="phi4-mini-smoke",
    num_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    lora=LoRAConfig(rank=4),
)

SWA_WINDOW = 8192
