"""Span tracer exporting Chrome trace-event JSON (Perfetto-loadable).

Design points:

* **Near-zero cost when disabled.** The engine holds ``NULL_TRACER`` by
  default; every call site guards with ``if tracer.enabled`` (one
  attribute load) so the disabled path does no formatting, no clock
  reads, no allocation.
* **Flight recorder.** Events live in a bounded ``deque(maxlen=ring)``
  — the newest ``ring`` events double as the crash ring buffer. The
  engine calls :meth:`Tracer.flight_dump` from its exception path so a
  stuck or crashing run leaves a postmortem trace on disk.
* **Two clocks.** Callers either pass explicit timestamps in *anchored
  seconds* (the serving engine passes its own engine-relative clock
  after ``anchor(0.0)``) or use the :meth:`span` context manager, which
  reads ``perf_counter`` and lazily anchors at the first event (the
  federated server path).

Track convention (pid/tid): pid 1 = the engine loop (tid 0), pid 2 =
one thread per request rid, pid 3 = federated rounds. Metadata events
(``ph: "M"``) name the tracks; they are kept out of the ring so names
survive arbitrarily long runs.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional, Tuple

PID_ENGINE = 1
PID_REQUESTS = 2
PID_FEDERATED = 3


class Tracer:
    enabled = True

    def __init__(self, ring: int = 65536,
                 flight_path: Optional[str] = None) -> None:
        self.events: deque = deque(maxlen=int(ring))
        self.dropped = 0          # events pushed out of the ring
        self.flight_path = flight_path
        self._meta: Dict[Tuple[int, Optional[int]], dict] = {}
        self._t0: Optional[float] = None   # perf_counter at anchored 0

    # -- clock ------------------------------------------------------------
    def anchor(self, now_s: float = 0.0) -> None:
        """Declare that ``perf_counter()`` *right now* corresponds to
        anchored time ``now_s``. The engine anchors 0.0 at run start and
        then passes its own relative timestamps."""
        self._t0 = time.perf_counter() - now_s

    def now(self) -> float:
        if self._t0 is None:
            self.anchor(0.0)
        return time.perf_counter() - self._t0

    # -- event emission (timestamps in anchored seconds) ------------------
    def _push(self, ev: dict) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(ev)

    def complete(self, name: str, start_s: float, end_s: float, *,
                 pid: int = PID_ENGINE, tid: int = 0, cat: str = "",
                 args: Optional[dict] = None) -> None:
        """A ``ph: "X"`` complete event spanning [start_s, end_s]."""
        ev = {"name": name, "ph": "X", "ts": start_s * 1e6,
              "dur": max(0.0, (end_s - start_s) * 1e6),
              "pid": pid, "tid": tid}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self._push(ev)

    def instant(self, name: str, t_s: float, *, pid: int = PID_ENGINE,
                tid: int = 0, cat: str = "",
                args: Optional[dict] = None) -> None:
        ev = {"name": name, "ph": "i", "ts": t_s * 1e6, "s": "t",
              "pid": pid, "tid": tid}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self._push(ev)

    def counter(self, name: str, t_s: float, values: Dict[str, float], *,
                pid: int = PID_ENGINE, tid: int = 0) -> None:
        """A ``ph: "C"`` counter sample — renders as a track in Perfetto
        (queue depth, free blocks, active slots over time)."""
        self._push({"name": name, "ph": "C", "ts": t_s * 1e6,
                    "pid": pid, "tid": tid,
                    "args": {k: float(v) for k, v in values.items()}})

    @contextmanager
    def span(self, name: str, *, pid: int = PID_ENGINE, tid: int = 0,
             cat: str = "", args: Optional[dict] = None) -> Iterator[None]:
        """Wall-clock span using the tracer's own (lazily anchored)
        clock — the federated-server idiom."""
        t0 = self.now()
        try:
            yield
        finally:
            self.complete(name, t0, self.now(), pid=pid, tid=tid, cat=cat,
                          args=args)

    # -- track naming -----------------------------------------------------
    def process_name(self, pid: int, name: str) -> None:
        self._meta[(pid, None)] = {
            "name": "process_name", "ph": "M", "ts": 0, "pid": pid,
            "tid": 0, "args": {"name": name}}

    def thread_name(self, pid: int, tid: int, name: str) -> None:
        self._meta[(pid, tid)] = {
            "name": "thread_name", "ph": "M", "ts": 0, "pid": pid,
            "tid": tid, "args": {"name": name}}

    # -- export -----------------------------------------------------------
    def to_dict(self) -> dict:
        events = list(self._meta.values()) + sorted(
            self.events, key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def dump(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, allow_nan=False)
        return path

    def flight_dump(self) -> Optional[str]:
        """Write the ring buffer to ``flight_path`` (postmortem). Returns
        the path written, or None when no flight path is configured."""
        if not self.flight_path:
            return None
        return self.dump(self.flight_path)


class _NullTracer:
    """Disabled tracer: every method is a no-op. Hot paths additionally
    guard on ``enabled`` so arguments are never even built."""

    enabled = False
    events: deque = deque(maxlen=0)
    dropped = 0
    flight_path = None

    def anchor(self, now_s: float = 0.0) -> None:
        pass

    def now(self) -> float:
        return 0.0

    def complete(self, *a, **kw) -> None:
        pass

    def instant(self, *a, **kw) -> None:
        pass

    def counter(self, *a, **kw) -> None:
        pass

    @contextmanager
    def span(self, *a, **kw) -> Iterator[None]:
        yield

    def process_name(self, *a, **kw) -> None:
        pass

    def thread_name(self, *a, **kw) -> None:
        pass

    def to_dict(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def dump(self, path: str) -> str:
        raise RuntimeError("cannot dump the null tracer")

    def flight_dump(self) -> Optional[str]:
        return None


NULL_TRACER = _NullTracer()

_REQUIRED = {"name", "ph", "ts", "pid", "tid"}


def validate_chrome_trace(trace: dict) -> List[str]:
    """Structural check of a Chrome trace-event dict: required fields on
    every event, numeric non-negative ts/dur, and — per (pid, tid) track
    — ``X`` spans that nest properly (no partial overlap). Returns a
    list of problems; empty means valid."""
    errors: List[str] = []
    if "traceEvents" not in trace or not isinstance(trace["traceEvents"],
                                                   list):
        return ["missing traceEvents list"]
    spans: Dict[Tuple[int, int], List[Tuple[float, float, str]]] = {}
    for i, ev in enumerate(trace["traceEvents"]):
        missing = _REQUIRED - set(ev)
        if missing:
            errors.append(f"event {i}: missing {sorted(missing)}")
            continue
        if ev["ph"] == "M":
            continue
        ts = ev["ts"]
        if not (isinstance(ts, (int, float)) and ts >= 0):
            errors.append(f"event {i} ({ev['name']}): bad ts {ts!r}")
            continue
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not (isinstance(dur, (int, float)) and dur >= 0):
                errors.append(f"event {i} ({ev['name']}): bad dur {dur!r}")
                continue
            spans.setdefault((ev["pid"], ev["tid"]), []).append(
                (ts, ts + dur, ev["name"]))
    eps = 1e-3  # µs slop for float rounding
    for track, ss in spans.items():
        ss.sort(key=lambda s: (s[0], -s[1]))
        stack: List[Tuple[float, float, str]] = []
        for s in ss:
            while stack and stack[-1][1] <= s[0] + eps:
                stack.pop()
            if stack and s[1] > stack[-1][1] + eps:
                errors.append(
                    f"track {track}: span {s[2]!r} [{s[0]:.1f},{s[1]:.1f}] "
                    f"partially overlaps {stack[-1][2]!r} "
                    f"[{stack[-1][0]:.1f},{stack[-1][1]:.1f}]")
            stack.append(s)
    return errors
