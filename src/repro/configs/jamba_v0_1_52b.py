"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2, Mamba:attention 7:1 interleave.
[arXiv:2403.19887]

Layer layout (period 8, as in the paper): attention at period index 4, every
other layer's FFN is MoE (offset 1).  Hardware adaptation: the original uses
Mamba-1 (d_state=16 sequential scan); we use our Mamba-2/SSD block
(d_state=128 chunked scan) — TPU-native, same O(1) decode state (recorded in
DESIGN.md §10)."""
from .base import LoRAConfig, ModelConfig, MoEConfig, SSMConfig

_PATTERN = ("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm")

FULL = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    rope_theta=10_000.0,
    layer_pattern=_PATTERN,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14_336,
                  moe_every=2, moe_offset=1),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, n_groups=1),
    lora=LoRAConfig(rank=16),
    source="arXiv:2403.19887",
)

SMOKE = FULL.replace(
    name="jamba-smoke",
    num_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    layer_pattern=("ssm", "attn"),
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=512,
                  moe_every=2, moe_offset=1),
    ssm=SSMConfig(d_state=32, head_dim=32, expand=2, conv_width=4,
                  chunk_size=64, n_groups=1),
    lora=LoRAConfig(rank=4),
)

SWA_WINDOW = 8192   # applied to the 4 attention layers for long_500k
