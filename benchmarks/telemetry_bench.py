"""Telemetry overhead guard + per-scenario telemetry rows.

The observability layer (``repro.obs``) is opt-in-pay: an engine built
without a tracer/registry holds ``NULL_TRACER`` and every hot-path call
site guards on one attribute load, so a disabled engine runs the same
decode loop the pre-telemetry engine did.  The pre-PR binary is not
available at bench time, so the guard measures the bound from the other
side: it runs the SAME workload through a default engine ("off") and a
fully instrumented one ("traced": span tracer + metrics registry +
per-step expert-occupancy counts), interleaved min-of-N, and asserts
the *enabled* decode step lands within ``max(2%, 0.1 ms)`` of the
disabled one.  The disabled path's residual cost (the ``if
tracer.enabled`` guards plus one histogram observe per step) is a
strict subset of the enabled path's host work, so holding the enabled
path under the 2% line bounds the disabled path well under it.

Also emits the ``serving_telemetry`` rows the smoke artifact carries
per scenario: decode-step p50, prefix hit rate, expert-occupancy gini.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.models import model as model_lib
from repro.obs import MetricsRegistry, Tracer
from repro.serving import Request, ServingEngine, WorkloadConfig, make_trace

from . import common
from .common import bench_model, emit

OVERHEAD_PCT = 0.02     # relative slack for the enabled/disabled ratio
OVERHEAD_MS = 0.1       # absolute floor: timer + host-sched noise


def _trace(cfg, n, seed):
    # shared 8-token prefixes so the prefix cache has something to hit
    return make_trace(WorkloadConfig(
        n_requests=n, prompt_lens=(16,), new_tokens=(16,),
        shared_prefix_len=8, n_shared_prefixes=2,
        tier_mix=((cfg.moe.top_k, 0.5), (1, 0.5)),
        vocab_size=cfg.vocab_size, seed=seed))


def _clone(reqs):
    return [Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, k=r.k) for r in reqs]


def run(smoke: bool = False) -> None:
    cfg = bench_model(moe=True)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    top_k = cfg.moe.top_k
    n_req = 16 if smoke else 32
    repeats = 3 if smoke else 5
    reqs = _trace(cfg, n_req, seed=3)
    prompt_tokens = sum(r.prompt_len for r in reqs)

    kw = dict(num_slots=8, slot_len=32, slot_k=(top_k,) * 4 + (1,) * 4,
              kv_layout="paged", block_size=8, num_blocks=48,
              prefix_cache=True)
    scenarios = [
        ("off", {}),
        ("traced", {"tracer": Tracer(), "metrics": MetricsRegistry(),
                    "expert_telemetry": True}),
    ]
    engines = {}
    for name, extra in scenarios:
        eng = ServingEngine(cfg, params, **kw, **extra)
        eng.run(_clone(_trace(cfg, n_req, seed=4)))   # compile + warmup
        engines[name] = eng

    # interleaved best-of-N: host noise at bench scale is sustained, so
    # back-to-back blocks would hand whichever engine ran in the quiet
    # minute the win.  Per-repeat mins are kept so the guard can compare
    # ADJACENT off/traced pairs (both saw the same load) instead of two
    # global mins that may come from differently-loaded minutes.
    per_rep = {name: [] for name, _ in scenarios}
    last = {}
    for _ in range(repeats):
        for name, _ in scenarios:
            eng = engines[name]
            for c in ("prefix_hit_blocks", "prefix_hit_tokens",
                      "prefix_cow_copies", "prefix_evictions"):
                setattr(eng.pool, c, 0)
            rep = eng.run(_clone(reqs))
            per_rep[name].append(float(np.min(rep.decode_step_s)) * 1e3)
            last[name] = rep
    step_ms = {name: min(v) for name, v in per_rep.items()}

    rows = []
    stats = {}
    for name, _ in scenarios:
        rep = last[name]
        s = rep.summary()
        el = rep.expert_load or {}
        hit_rate = (rep.prefix.get("hit_tokens", 0) / prompt_tokens
                    if rep.prefix else 0.0)
        tracer = engines[name]._tracer
        row = {"scenario": name,
               "decode_step_ms_min": step_ms[name],
               "decode_step_ms_p50": s["decode_step_ms_p50"],
               "prefix_hit_rate": hit_rate,
               "expert_gini": el.get("gini"),
               "expert_entropy": el.get("entropy"),
               "trace_events": len(tracer.events)}
        rows.append(row)
        stats[name] = {k: v for k, v in row.items() if k != "scenario"}
        # the artifact's "telemetry" block: headline numbers + the full
        # registry snapshot (None for the uninstrumented engine)
        metrics = engines[name]._metrics
        common.TELEMETRY[name] = dict(
            stats[name], registry=metrics.snapshot() if metrics else None)
    emit("serving_telemetry", rows,
         ["scenario", "decode_step_ms_min", "decode_step_ms_p50",
          "prefix_hit_rate", "expert_gini", "expert_entropy",
          "trace_events"])

    # ---- the guard ----
    off_eng = engines["off"]
    if off_eng._tracer.enabled or len(off_eng._tracer.events):
        raise SystemExit("telemetry guard: the default engine must hold "
                         "the null tracer and emit zero events")
    if engines["traced"]._tracer.dropped == 0 \
            and not engines["traced"]._tracer.events:
        raise SystemExit("telemetry guard: the traced engine emitted no "
                         "events — instrumentation is dead")
    # the quietest adjacent pair decides: a loaded CI host inflates both
    # engines of a repeat together, so the per-repeat delta is stable
    # where a global-min comparison flakes
    best_delta = min(t - o for o, t in zip(per_rep["off"],
                                           per_rep["traced"]))
    budget = step_ms["off"] * OVERHEAD_PCT + OVERHEAD_MS
    ok = best_delta <= budget
    ratio = (step_ms["off"] + best_delta) / max(step_ms["off"], 1e-9)
    verdict = "within" if ok else "EXCEEDS"
    print(f"# CLAIM telemetry: fully-enabled tracing+metrics+expert "
          f"counts adds {best_delta:+.3f} ms to the "
          f"{step_ms['off']:.3f} ms disabled decode step "
          f"({ratio:.3f}x, quietest interleaved pair) — {verdict} the "
          f"max({OVERHEAD_PCT:.0%}, {OVERHEAD_MS} ms) budget; the "
          f"disabled path's residual cost is a strict subset, so "
          f"telemetry off costs less still")
    print("# BENCH JSON: " + json.dumps(
        {"bench": "telemetry", "requests": n_req, "repeats": repeats,
         "telemetry": stats, "overhead_ratio": ratio,
         "overhead_ms": best_delta, "budget_ms": budget, "guard_ok": ok}))
    if not ok:
        raise SystemExit(
            f"telemetry overhead guard failed: enabled decode step "
            f"runs {best_delta:.3f} ms over disabled in the quietest "
            f"pair > budget {budget:.3f} ms "
            f"(disabled {step_ms['off']:.3f} ms)")


if __name__ == "__main__":
    t0 = time.time()
    run()
    print(f"# telemetry bench done in {time.time() - t0:.1f}s")
