"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real (single-CPU) device set.

Axes:
  * ``data``  — batch / FSDP weight sharding axis (intra-pod, 16-way);
  * ``model`` — tensor/expert parallel axis (intra-pod, 16-way);
  * ``pod``   — the cross-pod data-parallel axis (2-way on the 512-chip
    2-pod config).  Weights are *replicated* across pods (FSDP gathers stay
    on intra-pod ICI); only the batch and the gradient all-reduce cross the
    pod axis — this matches how real multi-pod v5e jobs are laid out (DCN
    between pods is ~25× slower than ICI).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_mesh_compat(shape: Tuple[int, ...],
                     axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across the supported jax version range.

    ``jax.sharding.AxisType`` (and ``make_mesh``'s ``axis_types`` kwarg)
    only exist in newer jax releases; older builds (e.g. 0.4.37) default
    every axis to the same Auto semantics, so the fallback simply omits
    the kwarg.  All mesh construction in the repo funnels through here so
    the version gate lives in exactly one place.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh() -> jax.sharding.Mesh:
    """Single-device mesh with the production axis names — lets the same
    pjit'd step functions run on CPU for tests/examples."""
    return make_mesh_compat((1, 1), ("data", "model"))


def batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Mesh axes that shard the global batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axis(mesh: jax.sharding.Mesh) -> str:
    return "data"


def model_axis(mesh: jax.sharding.Mesh) -> str:
    return "model"
