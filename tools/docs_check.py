#!/usr/bin/env python
"""Docs smoke-checker (`make docs-check`).

Every dotted ``repro.*`` reference in the given markdown files — inside
fenced code blocks, inline code spans, or prose — must resolve to an
importable module, or to an attribute reachable from one. Keeps the
README / docs honest: renaming or deleting a module/function without
updating the docs fails CI.

Usage:  PYTHONPATH=src python tools/docs_check.py README.md docs/*.md
"""
from __future__ import annotations

import importlib
import re
import sys
from typing import List, Tuple

DOTTED = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")
FROM_IMPORT = re.compile(
    r"^\s*from\s+(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)*)\s+import\s+([\w ,]+)",
    re.MULTILINE)


def resolve(dotted: str) -> Tuple[bool, str]:
    """Import the longest module prefix of ``dotted``, then getattr-walk
    the rest.  Returns (ok, reason)."""
    parts = dotted.split(".")
    obj = None
    depth = 0
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
            depth = i
            break
        except ImportError:
            continue
    if obj is None:
        return False, "no importable module prefix"
    for attr in parts[depth:]:
        if not hasattr(obj, attr):
            return False, (f"module {'.'.join(parts[:depth])!r} has no "
                           f"attribute path {'.'.join(parts[depth:])!r}")
        obj = getattr(obj, attr)
    return True, ""


def check_file(path: str) -> List[str]:
    text = open(path).read()
    errors = []
    refs = set(DOTTED.findall(text))
    for mod, names in FROM_IMPORT.findall(text):
        refs.add(mod)
        refs.update(f"{mod}.{n.strip()}" for n in names.split(",")
                    if n.strip())
    for ref in sorted(refs):
        ok, why = resolve(ref)
        if not ok:
            errors.append(f"{path}: `{ref}` does not resolve ({why})")
    return errors


def main(argv: List[str]) -> int:
    if not argv:
        print("usage: docs_check.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    errors = []
    checked = 0
    for path in argv:
        errs = check_file(path)
        errors.extend(errs)
        checked += 1
    for e in errors:
        print(f"FAIL {e}")
    print(f"docs-check: {checked} file(s), "
          f"{'OK' if not errors else f'{len(errors)} broken reference(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
