"""Server-side federated orchestration: one round per method.

Implements the four compared methods end-to-end:

  * ``flame``    — distribute full-rank per-expert LoRA; clients train with
                   their k_i; aggregate with Eq. 6–7 (activation-aware).
  * ``trivial``  — every client uses the globally smallest rank; plain
                   FedAvg (the paper's "trivial" baseline: small uniform
                   LoRA for all experts).
  * ``hlora``    — distribute rank-truncated adapters per client budget;
                   sparsity-weighted aggregation over rank components.
  * ``flexlora`` — clients train truncated adapters; server aggregates full
                   ΔW = s·A·B and SVD-refactors back to the server rank.

Round execution (``fed.round_engine``):

  * ``"batched"`` (default) — participants are grouped into budget cohorts
    (see federated/cohort.py) and each cohort's local training runs as ONE
    compiled ``client.cohort_update`` call (vmap or lax.map over the client
    axis).  For FLAME the per-cohort stacked adapters and activation counts
    are concatenated along the client axis and fed to ``flame_aggregate``
    directly — device-resident end-to-end.
  * ``"looped"`` — the sequential per-client reference oracle (one
    ``client.local_train`` per participant).  Kept as the correctness
    baseline; tests assert the batched path matches it allclose.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import io as ckpt_io
from ..configs.base import FederatedConfig, ModelConfig, TrainConfig
from ..core import aggregation as agg
from ..core import lora as lora_lib
from ..obs.expert_load import ActivationDriftTracker
from ..obs.metrics import MetricsRegistry
from ..obs.trace import NULL_TRACER, PID_FEDERATED, Tracer
from . import client as client_lib
from .cohort import build_cohorts

PyTree = Any

# the paper's budget grids (Appendix A1)
FLAME_BUDGET_K = {"b1": 8, "b2": 4, "b3": 2, "b4": 1}
MOE_BUDGET_RANKS = {"b1": 20, "b2": 12, "b3": 8, "b4": 6}
DENSE_BUDGET_RANKS = {"b1": 40, "b2": 24, "b3": 16, "b4": 12}


@dataclass
class RoundResult:
    round_idx: int
    client_losses: List[float]
    client_freqs: List[Dict[str, np.ndarray]]
    participating: List[int]
    # per-MoE-position activation telemetry for the round (repro.obs):
    # {pos: {"entropy": [per period], "entropy_mean": f, "l1_drift": f|None}}
    # — l1_drift is None on the first observed round (nothing to diff)
    activation_drift: Optional[Dict[str, Dict[str, Any]]] = None


class FederatedServer:
    """Holds the global LoRA state and runs communication rounds.

    ``tracer``/``metrics`` (optional, repro.obs): per-round spans
    (distribute → cohort_update/local_train → aggregate, on the
    federated track) and round metrics (round counter, mean client
    loss, per-position activation entropy + L1 drift).  Activation
    drift itself is always computed — it is host-side arithmetic on
    arrays each round already produced — and stored on
    :class:`RoundResult`.
    """

    def __init__(self, cfg: ModelConfig, params: PyTree, global_lora: PyTree,
                 clients: Sequence[client_lib.ClientState],
                 fed: FederatedConfig, tc: TrainConfig,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.params = params
        self.global_lora = global_lora
        self.clients = list(clients)
        self.fed = fed
        self.tc = tc
        self.history: List[RoundResult] = []
        self._rng = np.random.default_rng(fed.seed + 999)
        self._round_offset = 0        # rounds completed before a resume
        self._drift = ActivationDriftTracker()
        self._metrics = metrics
        self._set_tracer(tracer)

    def _set_tracer(self, tracer: Optional[Tracer]) -> None:
        self._tracer = tracer if tracer is not None else NULL_TRACER
        if self._tracer.enabled:
            self._tracer.process_name(PID_FEDERATED, "federated")
            self._tracer.thread_name(PID_FEDERATED, 0, "rounds")

    # ----------------------------------------------------------- distribution
    def _dist_rank(self, c: client_lib.ClientState) -> int:
        """Rank of the adapter the server distributes to client ``c`` —
        the shape the cohort builder must group by."""
        m = self.fed.method
        if m == "flame":
            return max(cl.rank for cl in self.clients)   # full rank, always
        if m == "trivial":
            return min(cl.rank for cl in self.clients)
        if m in ("hlora", "flexlora"):
            return c.rank
        raise ValueError(f"unknown method {m!r}")

    def _distribute(self, c: client_lib.ClientState) -> PyTree:
        m = self.fed.method
        if m == "flame":
            return self.global_lora                      # full rank, always
        if m == "trivial":
            r_min = min(cl.rank for cl in self.clients)
            return lora_lib.truncate_rank(self.global_lora, r_min)
        if m in ("hlora", "flexlora"):
            return lora_lib.truncate_rank(self.global_lora, c.rank)
        raise ValueError(f"unknown method {m!r}")

    # ------------------------------------------------------------ aggregation
    def _aggregate(self, loras, freqs, sizes: List[float],
                   parts: List[int]) -> PyTree:
        """``loras``/``freqs`` may be Python lists (looped path) or stacked
        trees with a leading client axis (batched FLAME path)."""
        m = self.fed.method
        r_full = max(cl.rank for cl in self.clients)
        if m == "flame":
            return agg.flame_aggregate(loras, freqs, sizes,
                                       self.fed.temperature)
        if m == "trivial":
            r_min = min(cl.rank for cl in self.clients)
            small = agg.fedavg(loras, sizes)
            # pad the uniformly-small global back to server rank storage
            return lora_lib.pad_rank(small, r_full)
        if m == "hlora":
            ranks = [self.clients[i].rank for i in parts]
            return agg.hlora_aggregate(loras, ranks, sizes, r_full)
        if m == "flexlora":
            return agg.flexlora_aggregate(loras, sizes, r_full,
                                          self.cfg.lora.scale)
        raise ValueError(m)

    # ----------------------------------------------------------------- rounds
    def _sample_participants(self) -> List[int]:
        n = len(self.clients)
        n_part = max(1, int(round(self.fed.participation * n)))
        return sorted(self._rng.choice(n, size=n_part, replace=False)
                      .tolist())

    def run_round(self, round_idx: int) -> RoundResult:
        tr = self._tracer
        t0 = tr.now()
        if self.fed.round_engine == "looped":
            res = self._run_round_looped(round_idx)
        else:
            res = self._run_round_batched(round_idx)
        res.activation_drift = self._round_drift(res)
        if tr.enabled:
            tr.complete(f"round {round_idx}", t0, tr.now(),
                        pid=PID_FEDERATED, cat="federated",
                        args={"participants": len(res.participating),
                              "method": self.fed.method})
        if self._metrics is not None:
            self._metrics.counter("fed.rounds").inc()
            finite = [l for l in res.client_losses if np.isfinite(l)]
            if finite:
                self._metrics.gauge("fed.round.mean_loss").set(
                    float(np.mean(finite)))
            self._metrics.gauge("fed.participants").set(
                len(res.participating))
            self._drift.publish(self._metrics, res.activation_drift)
        return res

    def _round_drift(self, res: RoundResult) -> Dict[str, Dict[str, Any]]:
        """Population activation signal for the round: the unweighted
        mean of participating clients' activation frequencies per MoE
        position (aggregation itself weighs by dataset size; telemetry
        tracks what the cohort as a whole routed), pushed through the
        drift tracker — entropy per period + L1 drift vs the previous
        round."""
        freqs = [f for f in res.client_freqs if f]
        if not freqs:
            return {}
        mean = {pos: np.mean([np.asarray(f[pos], np.float64)
                              for f in freqs], axis=0)
                for pos in freqs[0]}
        return self._drift.update(mean)

    def _run_round_looped(self, round_idx: int) -> RoundResult:
        """Sequential reference path: one local_train call per client."""
        parts = self._sample_participants()
        tr = self._tracer
        loras, freqs, sizes, losses = [], [], [], []
        for i in parts:
            c = self.clients[i]
            with tr.span("distribute", pid=PID_FEDERATED, cat="federated",
                         args={"client": i}):
                dist = self._distribute(c)
            with tr.span("local_train", pid=PID_FEDERATED, cat="federated",
                         args={"client": i, "k": c.k}):
                trained, f, _, info = client_lib.local_train(
                    self.cfg, self.params, dist, c, self.tc,
                    round_seed=self.fed.seed * 1000 + round_idx)
            loras.append(trained)
            freqs.append(f)
            sizes.append(float(c.dataset_size))
            losses.append(info["mean_loss"])

        with tr.span("aggregate", pid=PID_FEDERATED, cat="federated",
                     args={"method": self.fed.method}):
            self.global_lora = self._aggregate(loras, freqs, sizes, parts)
        res = RoundResult(round_idx, losses, freqs, parts)
        self.history.append(res)
        return res

    def _run_round_batched(self, round_idx: int) -> RoundResult:
        """Batched round engine: one compiled cohort_update per budget
        cohort; FLAME aggregation consumes the stacked outputs directly."""
        parts = self._sample_participants()
        round_seed = self.fed.seed * 1000 + round_idx
        part_clients = [self.clients[i] for i in parts]
        cohorts = build_cohorts(part_clients, self.tc,
                                rank_of=self._dist_rank)

        # per-participant results, keyed by position in `parts`
        loras_by_pos: Dict[int, PyTree] = {}
        freqs_by_pos: Dict[int, Dict[str, np.ndarray]] = {}
        losses_by_pos: Dict[int, float] = {}
        # FLAME: cohort-stacked trees, concatenated on the client axis below
        stacked_loras, stacked_freqs, stacked_order = [], [], []

        tr = self._tracer
        for ci, co in enumerate(cohorts):
            members = [part_clients[i] for i in co.members]
            with tr.span("distribute", pid=PID_FEDERATED, cat="federated",
                         args={"cohort": ci, "clients": len(members)}):
                trainables = [lora_lib.make_trainable(self._distribute(c),
                                                      c.rescaler)
                              for c in members]
                stacked_tr = lora_lib.stack_adapters(trainables)
                plan = client_lib.stack_plans(
                    [client_lib.make_batch_plan(c, self.tc, round_seed)
                     for c in members])
            rescaler_trainable = (co.key[4] == "learnable")
            with tr.span("cohort_update", pid=PID_FEDERATED,
                         cat="federated",
                         args={"cohort": ci, "k": co.k,
                               "clients": len(members)}):
                out_tr, counts, tok, loss_sum, n_valid = \
                    client_lib.cohort_update(
                        self.cfg, self.params, stacked_tr,
                        jnp.asarray(plan.tokens), jnp.asarray(plan.labels),
                        jnp.asarray(plan.mask), jnp.asarray(plan.valid),
                        k=co.k, tc=self.tc,
                        rescaler_trainable=rescaler_trainable,
                        backend=self.fed.cohort_backend)

            # stacked activation frequencies {pos: (C, n_periods, E)}
            denom = jnp.maximum(tok, 1.0)[:, None, None]
            freqs = {pos: c / denom for pos, c in counts.items()}

            if "rescaler" in out_tr:
                for c, r in zip(members,
                                lora_lib.unstack_adapters(
                                    out_tr["rescaler"], len(members))):
                    c.rescaler = r                       # persist s_i locally

            # nan (not 0.0) for zero-valid-step clients — the looped
            # reference path reports nan via local_train; the engines must
            # agree on this edge case too
            n_valid_np = np.asarray(n_valid)
            loss_means = np.where(
                n_valid_np > 0,
                np.asarray(loss_sum) / np.maximum(n_valid_np, 1.0),
                np.nan)
            for j, pos in enumerate(co.members):
                losses_by_pos[pos] = float(loss_means[j])
                freqs_by_pos[pos] = {p: np.asarray(f[j])
                                     for p, f in freqs.items()}

            if self.fed.method == "flame":
                stacked_loras.append(out_tr["lora"])
                stacked_freqs.append(freqs)
                stacked_order.extend(co.members)
            else:
                for j, pos in enumerate(co.members):
                    loras_by_pos[pos] = jax.tree.map(lambda l, j=j: l[j],
                                                     out_tr["lora"])

        sizes = [float(c.dataset_size) for c in part_clients]
        with tr.span("aggregate", pid=PID_FEDERATED, cat="federated",
                     args={"method": self.fed.method}):
            if self.fed.method == "flame":
                # concatenate cohorts on the client axis — still
                # device-resident
                cat = (stacked_loras[0] if len(stacked_loras) == 1 else
                       jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                    *stacked_loras))
                cat_freqs = {pos: jnp.concatenate([f[pos]
                                                   for f in stacked_freqs],
                                                  axis=0)
                             for pos in (stacked_freqs[0] if stacked_freqs
                                         else {})}
                cat_sizes = [sizes[pos] for pos in stacked_order]
                self.global_lora = self._aggregate(cat, cat_freqs, cat_sizes,
                                                   parts)
            else:
                loras = [loras_by_pos[i] for i in range(len(parts))]
                freqs_l = [freqs_by_pos[i] for i in range(len(parts))]
                self.global_lora = self._aggregate(loras, freqs_l, sizes,
                                                   parts)

        res = RoundResult(round_idx,
                          [losses_by_pos[i] for i in range(len(parts))],
                          [freqs_by_pos[i] for i in range(len(parts))],
                          parts)
        self.history.append(res)
        return res

    # ------------------------------------------------------------ checkpoints
    def save_checkpoint(self, path: str) -> None:
        """Persist the round-resumable federated state: the global LoRA,
        every client's local rescaler ``s_i`` (client-local state the
        server would otherwise lose), and the next round index."""
        ckpt_io.save(path, {"global_lora": self.global_lora,
                            "rescalers": [c.rescaler for c in self.clients]},
                     meta={"round_idx": self._round_offset + len(self.history),
                           "method": self.fed.method,
                           "num_clients": len(self.clients)})

    def restore_checkpoint(self, path: str) -> int:
        """Load a checkpoint into the server; returns the round to resume
        from.  The participant-sampling RNG is replayed past the completed
        rounds so a resumed run samples the same cohorts a straight-through
        run would."""
        tree, meta = ckpt_io.load(path)
        if (meta is None or "num_clients" not in meta
                or "global_lora" not in tree):
            raise ValueError(
                f"{path} is not a FederatedServer checkpoint (legacy or "
                "foreign format) — re-create it with save_checkpoint / "
                "run(checkpoint_to=...)")
        assert meta["num_clients"] == len(self.clients), \
            (meta["num_clients"], len(self.clients))
        assert meta["method"] == self.fed.method, \
            (meta["method"], self.fed.method)
        self.global_lora = ckpt_io.to_device(tree["global_lora"])
        for c, r in zip(self.clients, tree["rescalers"]):
            c.rescaler = None if r is None else ckpt_io.to_device(r)
        start = int(meta["round_idx"])
        self._round_offset = start
        for _ in range(start):
            self._sample_participants()
        return start

    def run(self, resume_from: Optional[str] = None,
            checkpoint_to: Optional[str] = None,
            metrics_to: Optional[str] = None,
            trace_to: Optional[str] = None) -> List[RoundResult]:
        """Run (the remaining) rounds.

        ``resume_from``: checkpoint path written by :meth:`save_checkpoint`
        (or by a previous ``run(checkpoint_to=...)``) — loads (global LoRA,
        rescalers, round idx) and continues from there;
        ``checkpoint_to``: write a checkpoint after every completed round.

        ``metrics_to``/``trace_to``: observability outputs — a registry
        snapshot (JSON) and a Chrome trace-event file of the round spans,
        written when the rounds finish.  Each creates the corresponding
        repro.obs object on demand when the server was constructed
        without one.
        """
        if metrics_to and self._metrics is None:
            self._metrics = MetricsRegistry()
        if trace_to and not self._tracer.enabled:
            self._set_tracer(Tracer())
        start = self.restore_checkpoint(resume_from) if resume_from else 0
        out = []
        for r in range(start, self.fed.rounds):
            out.append(self.run_round(r))
            if checkpoint_to:
                self.save_checkpoint(checkpoint_to)
        if metrics_to:
            self._metrics.dump(metrics_to)
        if trace_to:
            self._tracer.dump(trace_to)
        return out
