"""Dirichlet client partitioning (paper §3.2).

Examples are distributed across N clients by drawing, for every latent task
cluster, a Dirichlet(α) vector over clients and routing that cluster's
examples accordingly.  α = 5 ⇒ near-uniform; α = 0.5 ⇒ heavily skewed —
matching the paper's heterogeneity settings.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .synthetic import Corpus


def dirichlet_partition(corpus: Corpus, num_clients: int, alpha: float,
                        seed: int = 0, min_per_client: int = 2
                        ) -> List[Corpus]:
    rng = np.random.default_rng(seed)
    n_clusters = int(corpus.clusters.max()) + 1
    assignment = np.empty(len(corpus.tokens), np.int64)

    for c in range(n_clusters):
        idx = np.where(corpus.clusters == c)[0]
        rng.shuffle(idx)
        probs = rng.dirichlet(np.full(num_clients, alpha))
        counts = rng.multinomial(len(idx), probs)
        start = 0
        for client, cnt in enumerate(counts):
            assignment[idx[start:start + cnt]] = client
            start += cnt

    # guarantee a minimum shard size (a client with no data can't train)
    for client in range(num_clients):
        have = np.where(assignment == client)[0]
        if len(have) < min_per_client:
            donors = np.argsort(-np.bincount(assignment,
                                             minlength=num_clients))
            for d in donors:
                pool = np.where(assignment == d)[0]
                need = min_per_client - len(have)
                if len(pool) > min_per_client + need:
                    assignment[pool[:need]] = client
                    break

    shards = []
    for client in range(num_clients):
        sl = np.where(assignment == client)[0]
        shards.append(Corpus(corpus.tokens[sl], corpus.labels[sl],
                             corpus.mask[sl], corpus.clusters[sl]))
    return shards


def heterogeneity_stats(shards: List[Corpus]) -> dict:
    """Per-client sizes and cluster histograms (for EXPERIMENTS.md)."""
    n_clusters = max(int(s.clusters.max(initial=0)) for s in shards) + 1
    hists = np.stack([np.bincount(s.clusters, minlength=n_clusters)
                      for s in shards])
    return {"sizes": [len(s.tokens) for s in shards],
            "cluster_hist": hists}
