"""Observability layer: span tracing, a metrics registry, and
expert-load telemetry.

Three small, dependency-free modules that the serving engine, the
scheduler, the paged KV pool, and the federated server publish into:

* :mod:`repro.obs.trace` — request-lifecycle / federated-round span
  tracer exporting Chrome trace-event JSON (open in Perfetto), with a
  bounded flight-recorder ring buffer dumped on engine exceptions.
* :mod:`repro.obs.metrics` — counters, gauges, and fixed-bucket
  histograms behind a :class:`MetricsRegistry` with a JSON-safe
  ``snapshot()``; pull-style sources let stateful components
  (``BlockPool``, ``Scheduler``) be sampled at snapshot time.
* :mod:`repro.obs.expert_load` — per-decode-step expert occupancy
  derived host-side from router activation counts, plus per-round
  activation-frequency entropy / L1-drift tracking for federated runs.

Everything is opt-in-pay: the engine defaults to ``NULL_TRACER`` and no
registry, and the hot loop guards every telemetry call behind a single
attribute check.
"""
from repro.obs.expert_load import (ActivationDriftTracker, ExpertLoadTracker,
                                   entropy, gini)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               exp_buckets)
from repro.obs.trace import NULL_TRACER, Tracer, validate_chrome_trace

__all__ = [
    "ActivationDriftTracker", "Counter", "ExpertLoadTracker", "Gauge",
    "Histogram", "MetricsRegistry", "NULL_TRACER", "Tracer", "entropy",
    "exp_buckets", "gini", "validate_chrome_trace",
]
